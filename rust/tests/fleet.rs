//! Fleet coordinator invariants (integration surface):
//!
//!  1. **Tenant determinism** — a fleet of N tenants produces
//!     bit-identical per-tenant weights for every `workers` setting, and
//!     those weights match N standalone single-tenant runs driven
//!     sequentially off the same shared artifacts. Tenants only depend
//!     on the shared deployment and their own derived seeds, so the
//!     worker count and sharding must be unobservable.
//!  2. **Session isolation** — sessions spawned off one `ModelArtifacts`
//!     share nothing mutable: training or touching tenant A never moves
//!     tenant B's parameter versions, packs or weights.

use std::sync::Arc;

use tinytrain::config::RunConfig;
use tinytrain::coordinator::fleet::{FleetConfig, FleetCoordinator, TenantSession};
use tinytrain::coordinator::CoordinatorConfig;
use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::exec::{calibrate, FloatParams, LayerParams, ModelArtifacts, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::util::prng::Pcg32;

fn deploy_artifacts() -> (Arc<ModelArtifacts>, Domain) {
    let spec = spec_by_name("cifar10").unwrap();
    let dom = Domain::new(&spec, [3, 12, 12], 5);
    let mut rng = Pcg32::seeded(17);
    let def = models::mnist_cnn(&[3, 12, 12], 10);
    let fp = FloatParams::init(&def, &mut rng);
    let (cal, _) = dom.splits(1, 0, &mut rng);
    let calib = calibrate(&def, &fp, &cal.xs);
    (Arc::new(ModelArtifacts::deploy(def, DnnConfig::Uint8, &fp, &calib)), dom)
}

fn fleet_cfg(tenants: usize) -> FleetConfig {
    FleetConfig::builder()
        .tenants(tenants)
        .arrivals_per_tenant(20)
        .shift_at(10)
        .mean_gap_s(0.05)
        .session(CoordinatorConfig::builder().replay_capacity(16).warmup_samples(3).build())
        .seed(9)
        .build()
}

/// Bit-level fingerprint of one tenant's weights (quantized values plus
/// float bias/weight bit patterns).
fn weight_snapshot(m: &NativeModel) -> (Vec<u8>, Vec<u32>) {
    let mut wbits = Vec::new();
    let mut bbits = Vec::new();
    for p in &m.state.params {
        match p {
            LayerParams::Q { w, bias } => {
                wbits.extend_from_slice(w.values.data());
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::Qp { w, bias } => {
                wbits.extend_from_slice(w.data.data());
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::F { w, bias } => {
                bbits.extend(w.data().iter().map(|v| v.to_bits()));
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::None => {}
        }
    }
    (wbits, bbits)
}

/// Run a fresh fleet (same artifacts, same config) at the given worker
/// count and return every tenant's final weight fingerprint.
fn run_fleet(workers: usize) -> Vec<(Vec<u8>, Vec<u32>)> {
    let (shared, dom) = deploy_artifacts();
    let run_cfg = RunConfig::builder().workers(workers).build();
    let mut fleet =
        FleetCoordinator::new(shared, device::imxrt1062(), dom, run_cfg, fleet_cfg(3));
    let rep = fleet.run();
    assert_eq!(rep.aggregate.arrivals, 60);
    assert!(rep.aggregate.train_steps > 0, "workers={workers}: fleet must train");
    fleet.tenants.iter().map(|t| weight_snapshot(&t.model)).collect()
}

#[test]
fn per_tenant_weights_are_bit_identical_for_any_worker_count() {
    let base = run_fleet(1);
    for workers in [2usize, 4] {
        let got = run_fleet(workers);
        assert_eq!(base.len(), got.len());
        for (id, (want, have)) in base.iter().zip(&got).enumerate() {
            assert_eq!(want, have, "tenant {id} diverged at workers={workers}");
        }
    }
}

#[test]
fn fleet_tenants_match_sequential_standalone_runs() {
    let fleet_snaps = run_fleet(4);

    // The same tenants, spawned and driven one at a time with a private
    // scratch arena — no fleet, no pool.
    let (shared, dom) = deploy_artifacts();
    let cfg = fleet_cfg(3);
    let coord = FleetCoordinator::new(
        Arc::clone(&shared),
        device::imxrt1062(),
        dom,
        RunConfig::default(),
        cfg.clone(),
    );
    let mut scratch = shared.make_scratch();
    for (id, want) in fleet_snaps.iter().enumerate() {
        let mut t = TenantSession::spawn(&shared, id, &cfg);
        t.run_stream(coord.base(), coord.shift_domains(), coord.device(), &cfg, &mut scratch);
        assert_eq!(
            want,
            &weight_snapshot(&t.model),
            "tenant {id}: fleet result differs from a standalone sequential run"
        );
    }
}

#[test]
fn touching_one_session_never_invalidates_another() {
    let (shared, _) = deploy_artifacts();
    let mut a = NativeModel::from_artifacts(Arc::clone(&shared));
    let b = NativeModel::from_artifacts(Arc::clone(&shared));

    let b_versions_before = b.state.param_versions().to_vec();
    for i in 0..a.state.param_versions().len() {
        a.state.touch_layer(i);
    }
    a.state.warm_packs(&shared.def);

    assert_eq!(
        b.state.param_versions(),
        &b_versions_before[..],
        "tenant A's touches must not move tenant B's versions"
    );
    // B's weights still alias the shared base image: zero CoW divergence.
    assert_eq!(
        weight_snapshot(&b),
        weight_snapshot(&NativeModel::from_artifacts(Arc::clone(&shared))),
        "tenant B's weights must still equal the base deployment"
    );
}

#[test]
fn training_one_tenant_leaves_siblings_at_base_cost() {
    let (shared, dom) = deploy_artifacts();
    let cfg = fleet_cfg(2);
    let mut a = TenantSession::spawn(&shared, 0, &cfg);
    let b = TenantSession::spawn(&shared, 1, &cfg);
    let b_fresh_bytes = b.session_bytes();

    let pool: Vec<Domain> = vec![dom.shifted(99)];
    let mut scratch = shared.make_scratch();
    a.run_stream(&dom, &pool, &device::imxrt1062(), &cfg, &mut scratch);

    assert!(a.telemetry.train_steps > 0, "tenant A must actually train");
    assert!(
        a.session_bytes() > b_fresh_bytes,
        "training must CoW-diverge A's weights from the base"
    );
    assert_eq!(
        b.session_bytes(),
        b_fresh_bytes,
        "tenant A's training must not grow tenant B's session"
    );
}
