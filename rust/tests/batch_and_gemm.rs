//! Integration tests for the batched im2col/GEMM execution engine, through
//! the public crate API: bit-exactness of the GEMM conv path against the
//! scalar MCU-faithful reference, and bit-identical training results
//! regardless of worker count (the engine's determinism contract).

use tinytrain::graph::exec::LayerParams;
use tinytrain::graph::DnnConfig;
use tinytrain::harness::{run_full_training, run_full_training_batched, Knobs};
use tinytrain::kernels::{fconv, qconv, ConvGeom, OpCounter};
use tinytrain::memplan::Scratch;
use tinytrain::quant::{QParams, QTensor};
use tinytrain::tensor::TensorF32;
use tinytrain::util::prng::Pcg32;

/// GEMM-routed quantized conv forward must be byte-identical to the scalar
/// reference across a sweep of real model geometries (stem, stride-2,
/// pointwise, wide-channel).
#[test]
fn gemm_conv_bit_exact_across_model_geometries() {
    let mut rng = Pcg32::seeded(2024);
    let mut scratch = Scratch::new();
    let cases = [
        // (cin, cout, k, stride, pad, h) — mnist_cnn stem, mbednet blocks
        (1usize, 16usize, 3usize, 2usize, 1usize, 28usize),
        (16, 32, 3, 2, 1, 14),
        (16, 24, 1, 1, 0, 16), // pointwise
        (48, 64, 1, 1, 0, 4),
        (3, 16, 3, 2, 1, 32),
    ];
    for &(cin, cout, k, stride, pad, h) in &cases {
        let g = ConvGeom {
            cin,
            cout,
            kh: k,
            kw: k,
            stride,
            pad_h: pad,
            pad_w: pad,
            depthwise: false,
        };
        let mut x = TensorF32::zeros(&[cin, h, h]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut w = TensorF32::zeros(&[cout, cin, k, k]);
        rng.fill_normal(w.data_mut(), 0.3);
        let b: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();

        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&w);
        let bq = tinytrain::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        let oqp = QParams::from_min_max(-2.0, 4.0);
        let mut ops = OpCounter::new();
        let ys = qconv::qconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
        let yg = qconv::qconv2d_fwd_gemm(&xq, &wq, &bq, &g, oqp, true, &mut scratch, &mut ops);
        assert_eq!(
            ys.values.data(),
            yg.values.data(),
            "quantized mismatch at {cin}->{cout} k{k} s{stride}"
        );

        let yfs = fconv::fconv2d_fwd(&x, &w, &b, &g, true, &mut ops);
        let yfg = fconv::fconv2d_fwd_gemm(&x, &w, &b, &g, true, &mut scratch, &mut ops);
        assert_eq!(yfs.data(), yfg.data(), "float mismatch at {cin}->{cout} k{k} s{stride}");
    }
}

/// GEMM-routed backward kernels (quantized and float, weight and input
/// gradients) must be byte-identical to the scalar references across the
/// same sweep of model geometries, dense and under sparse channel masks.
#[test]
fn gemm_backward_bit_exact_across_model_geometries() {
    let mut rng = Pcg32::seeded(4048);
    let mut scratch = Scratch::new();
    let cases = [
        (1usize, 16usize, 3usize, 2usize, 1usize, 28usize),
        (16, 32, 3, 2, 1, 14),
        (16, 24, 1, 1, 0, 16), // pointwise
        (3, 16, 3, 2, 1, 32),
    ];
    for &(cin, cout, k, stride, pad, h) in &cases {
        let g = ConvGeom {
            cin,
            cout,
            kh: k,
            kw: k,
            stride,
            pad_h: pad,
            pad_w: pad,
            depthwise: false,
        };
        let (oh, ow) = g.out_hw(h, h);
        let mut x = TensorF32::zeros(&[cin, h, h]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut w = TensorF32::zeros(&[cout, cin, k, k]);
        rng.fill_normal(w.data_mut(), 0.3);
        let mut e = TensorF32::zeros(&[cout, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&w);
        let eq = QTensor::quantize(&e);
        let oqp = QParams::from_min_max(-2.0, 2.0);
        let mask: Vec<bool> = (0..cout).map(|c| c % 3 != 1).collect();
        for keep in [None, Some(&mask[..])] {
            let mut ops = OpCounter::new();
            let (gws, gbs) = qconv::qconv2d_bwd_weight(&eq, &xq, &g, keep, &mut ops);
            let (gwg, gbg) =
                qconv::qconv2d_bwd_weight_gemm(&eq, &xq, &g, keep, &mut scratch, &mut ops);
            assert_eq!(gws.data(), gwg.data(), "q gw at {cin}->{cout} k{k} s{stride}");
            assert_eq!(gbs.data(), gbg.data(), "q gb at {cin}->{cout} k{k} s{stride}");

            let es = qconv::qconv2d_bwd_input(&eq, &wq, &g, h, h, oqp, keep, &mut ops);
            let eg = qconv::qconv2d_bwd_input_gemm(
                &eq,
                &wq,
                &g,
                h,
                h,
                oqp,
                keep,
                &mut scratch,
                &mut ops,
            );
            assert_eq!(es.values.data(), eg.values.data(), "q dx at {cin}->{cout} k{k} s{stride}");

            let (fgws, fgbs) = fconv::fconv2d_bwd_weight(&e, &x, &g, keep, &mut ops);
            let (fgwg, fgbg) =
                fconv::fconv2d_bwd_weight_gemm(&e, &x, &g, keep, &mut scratch, &mut ops);
            assert_eq!(fgws.data(), fgwg.data(), "f gw at {cin}->{cout} k{k} s{stride}");
            assert_eq!(fgbs.data(), fgbg.data(), "f gb at {cin}->{cout} k{k} s{stride}");

            let fes = fconv::fconv2d_bwd_input(&e, &w, &g, h, h, keep, &mut ops);
            let feg = fconv::fconv2d_bwd_input_gemm(&e, &w, &g, h, h, keep, &mut scratch, &mut ops);
            assert_eq!(fes.data(), feg.data(), "f dx at {cin}->{cout} k{k} s{stride}");
        }
    }
}

fn quantized_weight_snapshot(m: &tinytrain::graph::exec::NativeModel) -> (Vec<u8>, Vec<u32>) {
    let mut wbits = Vec::new();
    let mut bbits = Vec::new();
    for p in &m.state.params {
        match p {
            LayerParams::Q { w, bias } => {
                wbits.extend_from_slice(w.values.data());
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::Qp { w, bias } => {
                wbits.extend_from_slice(w.data.data());
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::F { w, bias } => {
                bbits.extend(w.data().iter().map(|v| v.to_bits()));
                bbits.extend(bias.iter().map(|b| b.to_bits()));
            }
            LayerParams::None => {}
        }
    }
    (wbits, bbits)
}

/// One full batched kmnist training run: per-epoch loss bits plus the
/// final weight snapshot — the fingerprint both determinism tests below
/// compare across worker counts.
fn batched_run_fingerprint(
    workers: usize,
    epochs: usize,
    seed: u64,
) -> (Vec<u32>, (Vec<u8>, Vec<u32>)) {
    let mut spec = tinytrain::data::spec_by_name("kmnist").unwrap();
    spec.reduced_shape = [1, 12, 12];
    let knobs = Knobs { epochs, runs: 1, train_pc: 2, test_pc: 1, workers, ..Knobs::default() };
    let (rep, m) = run_full_training_batched(&spec, DnnConfig::Uint8, &knobs, seed);
    let losses: Vec<u32> = rep.epochs.iter().map(|e| e.train_loss.to_bits()).collect();
    (losses, quantized_weight_snapshot(&m))
}

/// End-to-end determinism through the harness: a full batched training run
/// must produce bit-identical weights and losses for 1 vs 4 workers on a
/// fixed seed.
#[test]
fn batched_pipeline_bit_identical_across_worker_counts() {
    let (l1, snap1) = batched_run_fingerprint(1, 2, 11);
    let (l4, snap4) = batched_run_fingerprint(4, 2, 11);
    assert_eq!(snap1, snap4, "weights diverged across worker counts");
    assert_eq!(l1, l4, "per-epoch losses diverged across worker counts");
}

/// CI worker-pool sanity matrix entry: the same harness run must be
/// bit-identical between one worker and whatever `TT_WORKERS` the
/// environment requests (defaults to 2 when unset, so the test is
/// meaningful locally too; a request of 1 is lifted to a 1-vs-3
/// comparison so no matrix leg degenerates to comparing a run against
/// itself). The CI test job runs this at `TT_WORKERS=1,2,4` so
/// persistent-pool regressions can't land silently.
#[test]
fn batched_training_matches_tt_workers_env() {
    let requested: usize =
        std::env::var("TT_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let workers = if requested <= 1 { 3 } else { requested };
    let base = batched_run_fingerprint(1, 1, 23);
    let multi = batched_run_fingerprint(workers, 1, 23);
    assert_eq!(base, multi, "{workers} workers diverged from the one-worker run");
}

/// Depthwise-separable fingerprint for the TT_WORKERS matrix: a fully
/// trainable MbedNet (depthwise + pointwise blocks) batch-trained through
/// the worker pool, so the depthwise engine's forward, dW and dX kernels
/// all sit on the determinism contract.
fn batched_dw_run_fingerprint(workers: usize, seed: u64) -> (Vec<u32>, (Vec<u8>, Vec<u32>)) {
    use tinytrain::graph::exec::{calibrate, FloatParams, NativeModel};
    use tinytrain::train::fqt::FqtSgd;
    use tinytrain::train::loop_;

    let mut spec = tinytrain::data::spec_by_name("cifar10").unwrap();
    spec.reduced_shape = [3, 16, 16];
    let shape = spec.reduced_shape;
    let mut rng = Pcg32::new(seed, 0x77);
    let mut def = tinytrain::graph::models::mbednet(&shape, spec.classes);
    def.set_all_trainable();
    let dom = tinytrain::data::Domain::new(&spec, shape, seed ^ 0x5A5A);
    let (tr, te) = dom.splits(2, 1, &mut rng);
    let fp = FloatParams::init(&def, &mut rng);
    let calib = calibrate(&def, &fp, &tr.xs[..tr.len().min(4)]);
    let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
    let mut opt = FqtSgd::new(&m, 0.01, 4);
    let rep = loop_::train_batched(&mut m, &mut opt, &tr, &te, 1, 4, workers, &mut rng);
    let losses: Vec<u32> = rep.epochs.iter().map(|e| e.train_loss.to_bits()).collect();
    (losses, quantized_weight_snapshot(&m))
}

/// The CI TT_WORKERS matrix leg for the depthwise-separable workload: the
/// batched run over a fully trainable MbedNet must be bit-identical
/// between one worker and the environment's worker count (same lifting
/// rule as [`batched_training_matches_tt_workers_env`]).
#[test]
fn batched_training_matches_tt_workers_depthwise() {
    let requested: usize =
        std::env::var("TT_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let workers = if requested <= 1 { 3 } else { requested };
    let base = batched_dw_run_fingerprint(1, 31);
    let multi = batched_dw_run_fingerprint(workers, 31);
    assert_eq!(base, multi, "{workers} workers diverged on the depthwise-separable model");
}

/// The sequential reference path must still work next to the batched one
/// (same harness, same spec) — guarding against accidental coupling.
#[test]
fn sequential_and_batched_paths_coexist() {
    let mut spec = tinytrain::data::spec_by_name("kmnist").unwrap();
    spec.reduced_shape = [1, 12, 12];
    let knobs =
        Knobs { epochs: 1, runs: 1, train_pc: 2, test_pc: 1, workers: 2, ..Knobs::default() };
    let (rep_seq, _) = run_full_training(&spec, DnnConfig::Uint8, &knobs, 11);
    let (rep_bat, _) = run_full_training_batched(&spec, DnnConfig::Uint8, &knobs, 11);
    assert_eq!(rep_seq.samples_seen, rep_bat.samples_seen);
    assert!(rep_seq.fwd_ops.total_macs() > 0);
    // identical sample streams and MAC-exact kernels: the forward op count
    // is engine-independent
    assert_eq!(rep_seq.fwd_ops.int_macs, rep_bat.fwd_ops.int_macs);
}
