//! Golden parity of the compiled layer-op plan against the straight-line
//! reference executor, plus the arena contracts of the plan:
//!
//!  * all three models × all three DNN configurations, random inputs —
//!    bit-identical logits, activations, argmaxes, gradients, sparse-mask
//!    accounting, error-observer updates and `OpCounter` totals;
//!  * a full training step performs zero scratch-arena growth after plan
//!    construction (the arena-capacity assertion), for every
//!    configuration;
//!  * `Flatten` is a zero-copy view in the planned executor.

use tinytrain::graph::exec::{calibrate, Act, DenseUpdates, FloatParams, NativeModel};
use tinytrain::graph::reference::{backward_reference, forward_reference};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::kernels::{softmax, OpCounter};
use tinytrain::memplan::Scratch;
use tinytrain::tensor::TensorF32;
use tinytrain::train::sparse::DynamicSparse;
use tinytrain::util::prng::Pcg32;

const CASES: [(&str, [usize; 3], usize); 3] =
    [("mnist_cnn", [1, 12, 12], 4), ("mbednet", [3, 16, 16], 5), ("mcunet5fps", [3, 32, 32], 4)];

fn build(
    name: &str,
    shape: &[usize; 3],
    classes: usize,
    cfg: DnnConfig,
    seed: u64,
) -> (NativeModel, Vec<TensorF32>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::by_name(name, shape, classes).expect("known model");
    let fp = FloatParams::init(&def, &mut rng);
    let xs: Vec<TensorF32> = (0..3)
        .map(|_| {
            let mut x = TensorF32::zeros(shape);
            rng.fill_normal(x.data_mut(), 1.0);
            x
        })
        .collect();
    let calib = calibrate(&def, &fp, &xs[..2]);
    (NativeModel::build(def, cfg, &fp, &calib), xs)
}

/// Bit-level fingerprint of an activation (payload bytes + qparams bits).
fn act_bits(a: &Act) -> (Vec<u8>, Vec<u32>) {
    match a {
        Act::Q(t) => {
            (t.values.data().to_vec(), vec![t.qp.scale.to_bits(), t.qp.zero_point as u32])
        }
        Act::F(t) => (Vec::new(), t.data().iter().map(|v| v.to_bits()).collect()),
    }
}

fn assert_forward_parity(m: &NativeModel, x: &TensorF32, tag: &str) {
    let mut s1 = Scratch::new();
    let mut s2 = Scratch::new();
    let mut o1 = OpCounter::new();
    let mut o2 = OpCounter::new();
    let t1 = m.forward_in(x, &mut s1, &mut o1);
    let t2 = forward_reference(m, x, &mut s2, &mut o2);
    assert_eq!(o1, o2, "{tag}: forward op counts diverged");
    let l1: Vec<u32> = t1.logits.iter().map(|v| v.to_bits()).collect();
    let l2: Vec<u32> = t2.logits.iter().map(|v| v.to_bits()).collect();
    assert_eq!(l1, l2, "{tag}: logits diverged");
    assert_eq!(t1.acts.len(), t2.acts.len(), "{tag}");
    assert_eq!(act_bits(&t1.input), act_bits(&t2.input), "{tag}: input act diverged");
    for (i, (a, b)) in t1.acts.iter().zip(t2.acts.iter()).enumerate() {
        assert_eq!(a.shape(), b.shape(), "{tag}: act {i} shape diverged");
        assert_eq!(act_bits(a), act_bits(b), "{tag}: act {i} diverged");
    }
    assert_eq!(t1.argmax, t2.argmax, "{tag}: pool argmax diverged");
}

fn assert_backward_parity(m: &NativeModel, x: &TensorF32, sparse: bool, tag: &str) {
    let mut s1 = Scratch::new();
    let mut s2 = Scratch::new();
    let mut o1 = OpCounter::new();
    let mut o2 = OpCounter::new();
    let t1 = m.forward_in(x, &mut s1, &mut o1);
    let t2 = forward_reference(m, x, &mut s2, &mut o2);
    let mut throwaway = OpCounter::new();
    let (loss, _, err) = softmax::softmax_ce(&t1.logits, 0, &mut throwaway);
    let mut obs1 = m.state.err_obs.clone();
    let mut obs2 = m.state.err_obs.clone();
    let (b1, b2) = if sparse {
        // two identical deterministic controllers, identical call sequences
        let mut ctl1 = DynamicSparse::new(0.4, 1.0);
        let mut ctl2 = DynamicSparse::new(0.4, 1.0);
        ctl1.seed_max_loss(loss * 4.0 + 1.0);
        ctl2.seed_max_loss(loss * 4.0 + 1.0);
        ctl1.begin_sample(loss);
        ctl2.begin_sample(loss);
        let b1 = m.backward_with(&t1, err.clone(), &mut ctl1, &mut obs1, &mut s1, &mut o1);
        let b2 = backward_reference(m, &t2, err, &mut ctl2, &mut obs2, &mut s2, &mut o2);
        assert_eq!(ctl1.kept, ctl2.kept, "{tag}: controller kept totals diverged");
        assert_eq!(ctl1.total, ctl2.total, "{tag}: controller totals diverged");
        (b1, b2)
    } else {
        let b1 = m.backward_with(&t1, err.clone(), &mut DenseUpdates, &mut obs1, &mut s1, &mut o1);
        let b2 = backward_reference(m, &t2, err, &mut DenseUpdates, &mut obs2, &mut s2, &mut o2);
        (b1, b2)
    };
    assert_eq!(o1, o2, "{tag}: fwd+bwd op counts diverged");
    assert_eq!(b1.grads.len(), b2.grads.len(), "{tag}");
    for (i, (ga, gb)) in b1.grads.iter().zip(b2.grads.iter()).enumerate() {
        match (ga, gb) {
            (Some(ga), Some(gb)) => {
                let wa: Vec<u32> = ga.gw.data().iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = gb.gw.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(wa, wb, "{tag}: layer {i} weight grads diverged");
                let ba: Vec<u32> = ga.gb.data().iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = gb.gb.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(ba, bb, "{tag}: layer {i} bias grads diverged");
                assert_eq!(ga.kept, gb.kept, "{tag}: layer {i} kept accounting diverged");
            }
            (None, None) => {}
            _ => panic!("{tag}: layer {i} gradient presence diverged"),
        }
    }
    for (i, (a, b)) in obs1.iter().zip(obs2.iter()).enumerate() {
        assert_eq!(a.range(), b.range(), "{tag}: observer {i} diverged");
    }
}

/// Golden-parity property test: every model × configuration, dense
/// updates, random inputs — forward and backward bit-identical between the
/// planned executor and the reference.
#[test]
fn plan_matches_reference_all_models_and_configs() {
    for (name, shape, classes) in CASES {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (m, xs) = build(name, &shape, classes, cfg, 0xA11CE);
            for (k, x) in xs.iter().enumerate() {
                let tag = format!("{name}/{cfg:?}/sample{k}");
                assert_forward_parity(&m, x, &tag);
                assert_backward_parity(&m, x, false, &tag);
            }
        }
    }
}

/// Parity must also hold under §III-B sparse-update masks: the planned
/// executor calls the controller with the same norms in the same order, so
/// the masks — and everything downstream of them — stay bit-identical.
/// `mbednet` puts the depthwise engine's whole-channel skip (and its
/// masked consumption of the cached flipped pack) under the same contract.
#[test]
fn plan_matches_reference_under_sparse_masks() {
    for (name, shape, classes) in
        [("mnist_cnn", [1usize, 12, 12], 4usize), ("mbednet", [3, 16, 16], 5)]
    {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (m, xs) = build(name, &shape, classes, cfg, 0xB0B);
            for (k, x) in xs.iter().enumerate() {
                let tag = format!("{name}/{cfg:?}/sparse/sample{k}");
                assert_backward_parity(&m, x, true, &tag);
            }
        }
    }
}

/// The arena-capacity assertion: a full training step (forward with range
/// adaptation, loss, backward) performs zero scratch-arena growth after
/// plan construction — for every model and every configuration, because
/// the plan pre-sizes the exact buffer set its ops request (float twins
/// included).
#[test]
fn training_step_performs_zero_arena_growth_after_plan() {
    for (name, shape, classes) in CASES {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (mut m, xs) = build(name, &shape, classes, cfg, 0xC0DE);
            let mut scratch = m.make_scratch();
            let before = scratch.reserved_bytes();
            assert!(before > 0, "{name}/{cfg:?}: plan must pre-size the arena");
            let mut ops = OpCounter::new();
            for x in &xs {
                let trace = m.forward_adapt_in(x, &mut scratch, &mut ops);
                let (_, _, err) = softmax::softmax_ce(&trace.logits, 0, &mut ops);
                let _ = m.backward_in(&trace, err, &mut DenseUpdates, &mut scratch, &mut ops);
            }
            assert_eq!(
                scratch.reserved_bytes(),
                before,
                "{name}/{cfg:?}: scratch arena grew during the training step"
            );
        }
    }
}

/// Pack-cache invalidation (stale-pack regression): optimizer steps dirty
/// the plan-owned backward weight packs, and sparse-mask flips bypass
/// them — in every one of those states a forward+backward step through
/// the planned executor must stay bit-identical to the straight-line
/// reference executor, which never uses the cache (i.e. behaves like a
/// freshly compiled deployment of the current weights). A stale pack
/// served after an update would diverge here.
#[test]
fn pack_cache_invalidation_stays_bit_identical() {
    use tinytrain::train::fqt::FqtSgd;
    use tinytrain::train::Optimizer;

    let (mut m, xs) = build("mnist_cnn", &[1, 12, 12], 4, DnnConfig::Uint8, 0xD1);
    // Drive optimizer steps so every trainable layer is touched and the
    // deployment-time packs go stale.
    let mut opt = FqtSgd::new(&m, 0.05, 2);
    let mut scratch = m.make_scratch();
    let mut ops = OpCounter::new();
    for (k, x) in xs.iter().enumerate() {
        let trace = m.forward_adapt_in(x, &mut scratch, &mut ops);
        let (_, _, err) = softmax::softmax_ce(&trace.logits, k % 4, &mut ops);
        let bwd = m.backward_in(&trace, err, &mut DenseUpdates, &mut scratch, &mut ops);
        opt.accumulate(&mut m, &bwd, &mut ops);
    }
    opt.finish(&mut m, &mut ops);

    // (a) stale cache, no warm: the dense backward must bypass the stale
    // entry (counted as a miss) and still match the reference bit-for-bit.
    let s0 = m.pack_stats();
    assert_backward_parity(&m, &xs[0], false, "stale-pack/stale-fallback");
    let s1 = m.pack_stats();
    assert!(s1.misses > s0.misses, "stale pack must be bypassed, not served");

    // (b) after re-warming, the dense backward must hit the fresh pack —
    // and remain bit-identical to the cache-free reference.
    m.warm_packs();
    let h0 = m.pack_stats().hits;
    assert_forward_parity(&m, &xs[0], "stale-pack/warmed");
    assert_backward_parity(&m, &xs[0], false, "stale-pack/warmed");
    assert!(m.pack_stats().hits > h0, "dense backward must hit the warmed pack");

    // (c) a DynamicSparse mask flip bypasses the cache per sample; parity
    // must hold under the mask, and the following dense step must hit the
    // (still fresh) packs bit-identically again.
    assert_backward_parity(&m, &xs[1], true, "stale-pack/sparse-flip");
    assert_backward_parity(&m, &xs[2], false, "stale-pack/dense-after-sparse");
}

/// Sparse scratch-growth contract: dense steps perform zero growth (the
/// plan-owned pack cache serves them); a sparse run's masked fallback
/// reserves the flipped-weight buffer at its **dense bound** on the first
/// masked pack, so the arena grows at most once and is stable afterwards
/// — regardless of how the per-sample kept counts fluctuate.
#[test]
fn sparse_training_scratch_growth_is_one_shot() {
    let (m, xs) = build("mnist_cnn", &[1, 12, 12], 4, DnnConfig::Uint8, 0xE2);
    let mut scratch = m.make_scratch();
    let mut ops = OpCounter::new();
    let run_sparse = |x: &TensorF32, scratch: &mut Scratch, ops: &mut OpCounter| {
        let trace = m.forward_in(x, scratch, ops);
        let (loss, _, err) = softmax::softmax_ce(&trace.logits, 0, ops);
        let mut ctl = DynamicSparse::new(0.4, 1.0);
        ctl.seed_max_loss(loss * 4.0 + 1.0);
        ctl.begin_sample(loss);
        let mut obs = m.state.err_obs.clone();
        let _ = m.backward_with(&trace, err, &mut ctl, &mut obs, scratch, ops);
    };
    run_sparse(&xs[0], &mut scratch, &mut ops);
    let after_first = scratch.reserved_bytes();
    for x in &xs {
        run_sparse(x, &mut scratch, &mut ops);
    }
    assert_eq!(
        scratch.reserved_bytes(),
        after_first,
        "masked fallback must reserve its dense bound once, then stay stable"
    );
}

/// Flatten in the planned executor is a zero-copy view: the flattened
/// activation aliases its input's buffer and allocates nothing.
#[test]
fn flatten_is_allocation_free_view() {
    let (m, xs) = build("mnist_cnn", &[1, 12, 12], 4, DnnConfig::Uint8, 0xF1A7);
    let mut ops = OpCounter::new();
    let t = m.forward(&xs[0], &mut ops);
    let i = m
        .shared
        .def
        .layers
        .iter()
        .position(|l| matches!(l.kind, tinytrain::graph::LayerKind::Flatten))
        .expect("mnist_cnn has a flatten layer");
    match (&t.acts[i - 1], &t.acts[i]) {
        (Act::Q(a), Act::Q(b)) => {
            assert!(b.values.shares_data(&a.values), "flatten must alias its input buffer");
            assert_eq!(b.shape(), &[a.len()]);
        }
        _ => panic!("mnist_cnn uint8: expected quantized activations around flatten"),
    }
}

/// The planned peak reported by the plan is consistent with the memory
/// planner's report (same liveness lowering).
#[test]
fn planned_peak_consistent_between_plan_and_memplan() {
    let def = models::mnist_cnn(&[1, 12, 12], 4);
    let rep = tinytrain::memplan::plan(&def, DnnConfig::Uint8, true);
    let plan = tinytrain::graph::plan::ExecPlan::compile(&def, DnnConfig::Uint8);
    assert_eq!(rep.planned_peak_bytes, plan.planned_peak_bytes);
    assert!(plan.planned_peak_bytes > 0);
}

// ---------------------------------------------------------------------------
// Fused-epilogue plan vs the retained unfused oracle
// ---------------------------------------------------------------------------

/// Deploy the same float masters twice — once with the fused-epilogue plan,
/// once with the unfused oracle plan — from one calibration.
fn build_pair(
    name: &str,
    shape: &[usize; 3],
    classes: usize,
    cfg: DnnConfig,
    seed: u64,
) -> (NativeModel, NativeModel, Vec<TensorF32>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::by_name(name, shape, classes).expect("known model");
    let fp = FloatParams::init(&def, &mut rng);
    let xs: Vec<TensorF32> = (0..3)
        .map(|_| {
            let mut x = TensorF32::zeros(shape);
            rng.fill_normal(x.data_mut(), 1.0);
            x
        })
        .collect();
    let calib = calibrate(&def, &fp, &xs[..2]);
    let fused = NativeModel::build_with_fusion(def.clone(), cfg, &fp, &calib, true);
    let unfused = NativeModel::build_with_fusion(def, cfg, &fp, &calib, false);
    (fused, unfused, xs)
}

fn assert_pair_forward(mf: &NativeModel, mu: &NativeModel, x: &TensorF32, tag: &str) {
    let mut s1 = Scratch::new();
    let mut s2 = Scratch::new();
    let mut o1 = OpCounter::new();
    let mut o2 = OpCounter::new();
    let t1 = mf.forward_in(x, &mut s1, &mut o1);
    let t2 = mu.forward_in(x, &mut s2, &mut o2);
    assert_eq!(o1, o2, "{tag}: fused forward op counts diverged from oracle");
    let l1: Vec<u32> = t1.logits.iter().map(|v| v.to_bits()).collect();
    let l2: Vec<u32> = t2.logits.iter().map(|v| v.to_bits()).collect();
    assert_eq!(l1, l2, "{tag}: fused logits diverged from oracle");
    assert_eq!(act_bits(&t1.input), act_bits(&t2.input), "{tag}: input act diverged");
    for (i, (a, b)) in t1.acts.iter().zip(t2.acts.iter()).enumerate() {
        assert_eq!(a.shape(), b.shape(), "{tag}: act {i} shape diverged");
        assert_eq!(act_bits(a), act_bits(b), "{tag}: act {i} diverged");
    }
    assert_eq!(t1.argmax, t2.argmax, "{tag}: pool argmax diverged");
    // The oracle plan never records kernel saturation counts.
    assert!(t2.sat.iter().all(|s| s.is_none()), "{tag}: oracle trace must carry no sat counts");
}

fn assert_pair_backward(
    mf: &NativeModel,
    mu: &NativeModel,
    x: &TensorF32,
    sparse: bool,
    tag: &str,
) {
    let mut s1 = Scratch::new();
    let mut s2 = Scratch::new();
    let mut o1 = OpCounter::new();
    let mut o2 = OpCounter::new();
    let t1 = mf.forward_in(x, &mut s1, &mut o1);
    let t2 = mu.forward_in(x, &mut s2, &mut o2);
    let mut throwaway = OpCounter::new();
    let (loss, _, err) = softmax::softmax_ce(&t1.logits, 0, &mut throwaway);
    let mut obs1 = mf.state.err_obs.clone();
    let mut obs2 = mu.state.err_obs.clone();
    let (b1, b2) = if sparse {
        let mut ctl1 = DynamicSparse::new(0.4, 1.0);
        let mut ctl2 = DynamicSparse::new(0.4, 1.0);
        ctl1.seed_max_loss(loss * 4.0 + 1.0);
        ctl2.seed_max_loss(loss * 4.0 + 1.0);
        ctl1.begin_sample(loss);
        ctl2.begin_sample(loss);
        let b1 = mf.backward_with(&t1, err.clone(), &mut ctl1, &mut obs1, &mut s1, &mut o1);
        let b2 = mu.backward_with(&t2, err, &mut ctl2, &mut obs2, &mut s2, &mut o2);
        assert_eq!(ctl1.kept, ctl2.kept, "{tag}: controller kept totals diverged");
        assert_eq!(ctl1.total, ctl2.total, "{tag}: controller totals diverged");
        (b1, b2)
    } else {
        let b1 = mf.backward_with(&t1, err.clone(), &mut DenseUpdates, &mut obs1, &mut s1, &mut o1);
        let b2 = mu.backward_with(&t2, err, &mut DenseUpdates, &mut obs2, &mut s2, &mut o2);
        (b1, b2)
    };
    assert_eq!(o1, o2, "{tag}: fused fwd+bwd op counts diverged from oracle");
    assert_eq!(b1.grads.len(), b2.grads.len(), "{tag}");
    for (i, (ga, gb)) in b1.grads.iter().zip(b2.grads.iter()).enumerate() {
        match (ga, gb) {
            (Some(ga), Some(gb)) => {
                let wa: Vec<u32> = ga.gw.data().iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u32> = gb.gw.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(wa, wb, "{tag}: layer {i} weight grads diverged");
                let ba: Vec<u32> = ga.gb.data().iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = gb.gb.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(ba, bb, "{tag}: layer {i} bias grads diverged");
                assert_eq!(ga.kept, gb.kept, "{tag}: layer {i} kept accounting diverged");
            }
            (None, None) => {}
            _ => panic!("{tag}: layer {i} gradient presence diverged"),
        }
    }
    for (i, (a, b)) in obs1.iter().zip(obs2.iter()).enumerate() {
        assert_eq!(a.range(), b.range(), "{tag}: observer {i} diverged");
    }
}

/// The fused-epilogue plan is bit-identical to the retained unfused oracle:
/// every model × configuration, dense updates and §III-B sparse masks —
/// logits, activations, argmaxes, gradients, observer states and
/// `OpCounter` totals all match exactly.
#[test]
fn fused_plan_matches_unfused_oracle() {
    for (name, shape, classes) in CASES {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (mf, mu, xs) = build_pair(name, &shape, classes, cfg, 0xF00D);
            assert!(mf.plan().fused(), "{name}/{cfg:?}: pair must compile one fused plan");
            assert!(!mu.plan().fused(), "{name}/{cfg:?}: pair must compile one oracle plan");
            for (k, x) in xs.iter().enumerate() {
                let tag = format!("{name}/{cfg:?}/fused-vs-oracle/sample{k}");
                assert_pair_forward(&mf, &mu, x, &tag);
                assert_pair_backward(&mf, &mu, x, false, &tag);
                assert_pair_backward(&mf, &mu, x, true, &tag);
            }
        }
    }
}

/// Folding the boundary ops and dropping the i32 accumulator strips
/// shrinks the liveness-planned arena: the fused plan's
/// `planned_peak_bytes` is strictly smaller for every quantized
/// configuration, and exactly equal for the float32 configuration (which
/// has no quantized GEMMs to fuse).
#[test]
fn fused_plan_shrinks_planned_peak() {
    for (name, shape, classes) in CASES {
        let def = models::by_name(name, &shape, classes).expect("known model");
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed] {
            let uf = tinytrain::graph::plan::ExecPlan::compile_with(&def, cfg, false);
            let f = tinytrain::graph::plan::ExecPlan::compile_with(&def, cfg, true);
            assert!(
                f.planned_peak_bytes < uf.planned_peak_bytes,
                "{name}/{cfg:?}: fused peak {} must be strictly below unfused peak {}",
                f.planned_peak_bytes,
                uf.planned_peak_bytes
            );
        }
        let uf = tinytrain::graph::plan::ExecPlan::compile_with(&def, DnnConfig::Float32, false);
        let f = tinytrain::graph::plan::ExecPlan::compile_with(&def, DnnConfig::Float32, true);
        assert_eq!(
            f.planned_peak_bytes, uf.planned_peak_bytes,
            "{name}/Float32: fusion must not change the float arena"
        );
    }
}

// ---------------------------------------------------------------------------
// Packed sub-byte deployments vs the retained u8 oracle
// ---------------------------------------------------------------------------

/// Deploy the same float masters twice — once packed at the given widths,
/// once on the plain-u8 path — from one calibration. Both use explicit
/// [`BitSpec`]s so the pair is independent of the `TT_WBITS` environment.
fn build_bits_pair(
    name: &str,
    shape: &[usize; 3],
    classes: usize,
    seed: u64,
    bits: &tinytrain::graph::plan::BitSpec,
) -> (NativeModel, NativeModel, Vec<TensorF32>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::by_name(name, shape, classes).expect("known model");
    let fp = FloatParams::init(&def, &mut rng);
    let xs: Vec<TensorF32> = (0..3)
        .map(|_| {
            let mut x = TensorF32::zeros(shape);
            rng.fill_normal(x.data_mut(), 1.0);
            x
        })
        .collect();
    let calib = calibrate(&def, &fp, &xs[..2]);
    let cfg = DnnConfig::Uint8;
    // The plain twin deploys unfused so the pair helpers' no-sat oracle
    // assertion holds; fused↔unfused bit-identity is already pinned by
    // `fused_plan_matches_unfused_oracle`, so the cross costs nothing.
    let packed = NativeModel::build_with_bits(def.clone(), cfg, &fp, &calib, true, bits);
    let plain = NativeModel::build_with_bits(
        def,
        cfg,
        &fp,
        &calib,
        false,
        &tinytrain::graph::plan::BitSpec::default(),
    );
    (packed, plain, xs)
}

/// The packed-representation bit-exactness oracle: a deployment forced to
/// 8-bit *packed* storage must be bit-identical to the plain-u8 path —
/// logits, activations, gradients, observers and `OpCounter` totals, dense
/// and sparse — and must also match the straight-line reference executor
/// (which unpacks once and runs the unchanged u8 kernels). Any divergence
/// here means the in-kernel unpack changed arithmetic, not just storage.
#[test]
fn packed8_plan_matches_u8_oracle() {
    use tinytrain::quant::subbyte::WBits;
    let spec = tinytrain::graph::plan::BitSpec { force: Some(WBits::W8), budget: None };
    for (name, shape, classes) in CASES {
        let (mp, mu, xs) = build_bits_pair(name, &shape, classes, 0x8B17, &spec);
        let bp = mp.plan().bit_plan();
        assert!(
            mp.shared.def.layers.iter().enumerate().all(|(i, l)| {
                bp.packed(i).is_some() == l.has_weights()
            }),
            "{name}: every quantized weighted layer must deploy packed"
        );
        for (k, x) in xs.iter().enumerate() {
            let tag = format!("{name}/packed8/sample{k}");
            assert_pair_forward(&mp, &mu, x, &tag);
            assert_pair_backward(&mp, &mu, x, false, &tag);
            assert_pair_backward(&mp, &mu, x, true, &tag);
            assert_forward_parity(&mp, x, &tag);
            assert_backward_parity(&mp, x, false, &tag);
        }
    }
}

/// Full-training-loop twin of the packed-8 oracle: the FQT optimizer's
/// quantize-on-write into the packed representation must track the plain
/// path bit-for-bit across optimizer steps (same weights, same op totals,
/// same logits afterwards).
#[test]
fn packed8_training_matches_u8_oracle() {
    use tinytrain::quant::subbyte::WBits;
    use tinytrain::train::fqt::FqtSgd;
    use tinytrain::train::Optimizer;
    let spec = tinytrain::graph::plan::BitSpec { force: Some(WBits::W8), budget: None };
    let (mut mp, mut mu, xs) = build_bits_pair("mnist_cnn", &[1, 12, 12], 4, 0x8B2E, &spec);
    let mut op_p = FqtSgd::new(&mp, 0.05, 2);
    let mut op_u = FqtSgd::new(&mu, 0.05, 2);
    let mut cp = OpCounter::new();
    let mut cu = OpCounter::new();
    for round in 0..2 {
        for (k, x) in xs.iter().enumerate() {
            let y = (round + k) % 4;
            let (_, _, bp) = mp.train_sample(x, y, &mut DenseUpdates, &mut cp);
            op_p.accumulate(&mut mp, &bp, &mut cp);
            let (_, _, bu) = mu.train_sample(x, y, &mut DenseUpdates, &mut cu);
            op_u.accumulate(&mut mu, &bu, &mut cu);
        }
        op_p.finish(&mut mp, &mut cp);
        op_u.finish(&mut mu, &mut cu);
    }
    assert_eq!(cp, cu, "packed8 training op totals diverged from the u8 oracle");
    for (i, (pp, pu)) in mp.state.params.iter().zip(mu.state.params.iter()).enumerate() {
        use tinytrain::graph::exec::LayerParams;
        match (pp, pu) {
            (LayerParams::Qp { w: wp, bias: bp }, LayerParams::Q { w: wu, bias: bu }) => {
                let lanes = wp.to_qtensor();
                assert_eq!(lanes.values.data(), wu.values.data(), "layer {i} weights diverged");
                assert_eq!(wp.qp.scale.to_bits(), wu.qp.scale.to_bits(), "layer {i} scale");
                assert_eq!(wp.qp.zero_point, wu.qp.zero_point, "layer {i} zero point");
                let ba: Vec<u32> = bp.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = bu.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ba, bb, "layer {i} biases diverged");
            }
            (LayerParams::None, LayerParams::None) => {}
            (a, b) => panic!("layer {i}: param flavors {}/{} unexpected", a.flavor(), b.flavor()),
        }
    }
    for (k, x) in xs.iter().enumerate() {
        let tag = format!("packed8/post-train/sample{k}");
        assert_pair_forward(&mp, &mu, x, &tag);
    }
}

/// Sub-byte deployments (INT4/INT2) run end to end: planned executor
/// matches the straight-line reference bit-for-bit at every width (the
/// reference unpacks fully, so this pins the in-kernel panel unpack), and
/// the weight memory reported for the packed deployment shrinks by the
/// packing factor.
#[test]
fn subbyte_plan_matches_reference_at_every_width() {
    use tinytrain::quant::subbyte::WBits;
    for wb in [WBits::W4, WBits::W2] {
        let spec = tinytrain::graph::plan::BitSpec { force: Some(wb), budget: None };
        for (name, shape, classes) in CASES {
            let (mp, _, xs) = build_bits_pair(name, &shape, classes, 0x5B17, &spec);
            for (k, x) in xs.iter().enumerate() {
                let tag = format!("{name}/{wb:?}/sample{k}");
                assert_forward_parity(&mp, x, &tag);
                assert_backward_parity(&mp, x, false, &tag);
                assert_backward_parity(&mp, x, true, &tag);
            }
        }
    }
}

/// Telemetry parity (op-count regression): the training-path forward with
/// activation-range adaptation consumes the fused kernels' saturation
/// counts instead of re-sweeping activations, and must report the same
/// `OpCounter` totals, the same adapted quantization parameters and the
/// same logits as the unfused oracle across a drifting multi-sample run.
#[test]
fn fused_telemetry_matches_unfused_oracle() {
    for (name, shape, classes) in CASES {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed] {
            let (mut mf, mut mu, xs) = build_pair(name, &shape, classes, cfg, 0xADA7);
            let mut of = OpCounter::new();
            let mut ou = OpCounter::new();
            for (k, x) in xs.iter().enumerate() {
                let tf = mf.forward_adapt(x, &mut of);
                let tu = mu.forward_adapt(x, &mut ou);
                let lf: Vec<u32> = tf.logits.iter().map(|v| v.to_bits()).collect();
                let lu: Vec<u32> = tu.logits.iter().map(|v| v.to_bits()).collect();
                assert_eq!(lf, lu, "{name}/{cfg:?}/adapt/sample{k}: logits diverged");
                assert!(
                    tf.sat.iter().any(|s| s.is_some()),
                    "{name}/{cfg:?}: fused trace must record kernel saturation counts"
                );
            }
            assert_eq!(of, ou, "{name}/{cfg:?}: adaptation op totals diverged");
            for (i, (a, b)) in mf.state.act_qp.iter().zip(mu.state.act_qp.iter()).enumerate() {
                assert_eq!(
                    a.scale.to_bits(),
                    b.scale.to_bits(),
                    "{name}/{cfg:?}: adapted scale {i} diverged"
                );
                assert_eq!(
                    a.zero_point, b.zero_point,
                    "{name}/{cfg:?}: adapted zero point {i} diverged"
                );
            }
        }
    }
}
