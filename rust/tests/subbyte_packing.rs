//! Sub-byte packed-weight integration contracts:
//!
//!  * byte accounting — a 4-bit deployment reports ~half (and a 2-bit
//!    deployment ~a quarter of) the 8-bit weight bytes through every
//!    reporting path that feeds the fleet report: per-layer `byte_size`,
//!    `ModelArtifacts::shared_bytes`, `SessionState::delta_bytes`;
//!  * the `TT_WEIGHT_BUDGET` demotion pass produces a deployment that
//!    actually fits the budget and still trains;
//!  * the accuracy-vs-memory frontier: training runs end to end at
//!    8/4/2-bit with finite accuracy and the expected 2×/4× weight-memory
//!    reduction (the fig. 4/5-style sweep recorded in EXPERIMENTS.md).

use tinytrain::graph::exec::{calibrate, DenseUpdates, FloatParams, LayerParams, NativeModel};
use tinytrain::graph::plan::BitSpec;
use tinytrain::graph::{models, DnnConfig};
use tinytrain::kernels::OpCounter;
use tinytrain::quant::subbyte::WBits;
use tinytrain::tensor::TensorF32;
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::Optimizer;
use tinytrain::util::prng::Pcg32;

fn deploy(
    bits: &BitSpec,
    seed: u64,
) -> (NativeModel, Vec<TensorF32>, Vec<usize>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::mnist_cnn(&[1, 12, 12], 2);
    let fp = FloatParams::init(&def, &mut rng);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..16 {
        let y = i % 2;
        let mut x = TensorF32::zeros(&[1, 12, 12]);
        rng.fill_normal(x.data_mut(), 0.4);
        for v in x.data_mut().iter_mut() {
            *v += y as f32;
        }
        xs.push(x);
        ys.push(y);
    }
    let calib = calibrate(&def, &fp, &xs[..4]);
    (NativeModel::build_with_bits(def, DnnConfig::Uint8, &fp, &calib, true, bits), xs, ys)
}

/// Per-layer quantized weight bytes as the accounting reports them.
fn weight_bytes_per_layer(m: &NativeModel) -> Vec<usize> {
    m.state
        .params
        .iter()
        .map(|p| match p {
            LayerParams::Q { w, .. } => w.len(),
            LayerParams::Qp { w, .. } => w.packed_bytes(),
            _ => 0,
        })
        .collect()
}

/// The byte-accounting regression: a 4-bit model reports ~half the 8-bit
/// weight bytes layer for layer, and the reduction is visible in
/// `shared_bytes` (which feeds `FleetReport::shared_bytes`) and in the
/// post-update `delta_bytes` (which feeds `FleetReport::session_bytes`).
#[test]
fn four_bit_model_reports_half_the_weight_bytes() {
    let w8 = BitSpec::default();
    let w4 = BitSpec { force: Some(WBits::W4), budget: None };
    let w2 = BitSpec { force: Some(WBits::W2), budget: None };
    let (m8, xs, ys) = deploy(&w8, 31);
    let (m4, ..) = deploy(&w4, 31);
    let (m2, ..) = deploy(&w2, 31);

    let b8 = weight_bytes_per_layer(&m8);
    let b4 = weight_bytes_per_layer(&m4);
    let b2 = weight_bytes_per_layer(&m2);
    assert!(b8.iter().sum::<usize>() > 0);
    for (i, ((&n8, &n4), &n2)) in b8.iter().zip(&b4).zip(&b2).enumerate() {
        // Exact packing arithmetic: ceil(n/2) and ceil(n/4) lanes per byte.
        assert_eq!(n4, n8.div_ceil(2), "layer {i}: 4-bit bytes");
        assert_eq!(n2, n8.div_ceil(4), "layer {i}: 2-bit bytes");
    }

    // Shared (deployment) accounting shrinks by exactly the packing saving.
    let saved4: usize = b8.iter().sum::<usize>() - b4.iter().sum::<usize>();
    assert!(saved4 > 0);
    assert!(
        m8.shared.shared_bytes() >= m4.shared.shared_bytes() + saved4,
        "shared_bytes must reflect packed weight storage ({} vs {})",
        m8.shared.shared_bytes(),
        m4.shared.shared_bytes()
    );

    // Per-tenant delta accounting after an optimizer step rewrites every
    // trainable layer: the 4-bit session owns ~half the weight delta.
    let step = |mut m: NativeModel| -> (usize, NativeModel) {
        let mut opt = FqtSgd::new(&m, 0.05, 4);
        let mut ops = OpCounter::new();
        for (x, &y) in xs.iter().zip(&ys).take(4) {
            let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
            opt.accumulate(&mut m, &bwd, &mut ops);
        }
        opt.finish(&mut m, &mut ops);
        (m.state.delta_bytes(&m.shared), m)
    };
    let (d8, m8t) = step(m8);
    let (d4, _) = step(m4);
    let w8_total: usize = weight_bytes_per_layer(&m8t).iter().sum();
    let saved = w8_total - w8_total.div_ceil(2);
    assert!(
        d8 >= d4 + saved.saturating_sub(b8.len()),
        "delta_bytes must count packed widths: 8-bit {d8} vs 4-bit {d4}"
    );
}

/// The `TT_WEIGHT_BUDGET` demotion pass through the full deployment path:
/// the compiled plan fits the budget, the deployed params respect the
/// per-layer plan, and the model still trains.
#[test]
fn weight_budget_deployment_fits_and_trains() {
    let (m8, ..) = deploy(&BitSpec::default(), 32);
    let full: usize = weight_bytes_per_layer(&m8).iter().sum();
    let budget = full * 6 / 10;
    let spec = BitSpec { force: None, budget: Some(budget) };
    let (mut m, xs, ys) = deploy(&spec, 32);

    let spent: usize = weight_bytes_per_layer(&m).iter().sum();
    assert!(spent <= budget, "deployment spends {spent} bytes over budget {budget}");
    let bp = m.plan().bit_plan();
    assert!(
        (0..m.state.params.len()).any(|i| bp.packed(i).is_some()),
        "a budget below the full size must demote at least one layer"
    );
    // Deployed representations follow the plan layer for layer.
    for (i, p) in m.state.params.iter().enumerate() {
        match (p, bp.packed(i)) {
            (LayerParams::Qp { w, .. }, Some(b)) => assert_eq!(w.bits, b, "layer {i}"),
            (LayerParams::Q { .. }, None) | (LayerParams::None, None) => {}
            (p, b) => panic!("layer {i}: params {} vs plan {b:?}", p.flavor()),
        }
    }

    let acc0 = m.evaluate(&xs, &ys);
    let mut opt = FqtSgd::new(&m, 0.02, 4);
    let mut ops = OpCounter::new();
    for _ in 0..15 {
        for (x, &y) in xs.iter().zip(&ys) {
            let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
            opt.accumulate(&mut m, &bwd, &mut ops);
        }
        opt.finish(&mut m, &mut ops);
    }
    let acc1 = m.evaluate(&xs, &ys);
    assert!(acc1.is_finite() && (0.0..=1.0).contains(&acc1));
    assert!(acc1 >= acc0.max(0.6), "budgeted model must still learn: {acc0} -> {acc1}");
}

/// The accuracy-vs-memory frontier smoke (fig. 4/5-style): FQT training
/// runs end to end at every storage width; 4-bit weights cost ~half and
/// 2-bit ~a quarter of the 8-bit bytes, and accuracy stays a valid
/// fraction at every point of the frontier.
#[test]
fn training_frontier_runs_at_every_width() {
    let mut frontier = Vec::new();
    let mut weighted_layers = 0;
    for (wb, divisor) in [(None, 1), (Some(WBits::W4), 2), (Some(WBits::W2), 4)] {
        let spec = BitSpec { force: wb, budget: None };
        let (mut m, xs, ys) = deploy(&spec, 33);
        let per_layer = weight_bytes_per_layer(&m);
        weighted_layers = per_layer.iter().filter(|&&b| b > 0).count();
        let bytes: usize = per_layer.iter().sum();
        let mut opt = FqtSgd::new(&m, 0.02, 4);
        let mut ops = OpCounter::new();
        for _ in 0..10 {
            for (x, &y) in xs.iter().zip(&ys) {
                let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                opt.accumulate(&mut m, &bwd, &mut ops);
            }
            opt.finish(&mut m, &mut ops);
        }
        let acc = m.evaluate(&xs, &ys);
        assert!(acc.is_finite() && (0.0..=1.0).contains(&acc), "{wb:?}: acc {acc}");
        frontier.push((divisor, bytes, acc));
    }
    let (_, full, _) = frontier[0];
    // ≤ one byte of packing rounding per weight tensor
    let ceil_slack = weighted_layers;
    for &(divisor, bytes, _) in &frontier[1..] {
        assert!(
            bytes <= full / divisor + ceil_slack && bytes >= full / (divisor + 1),
            "width /{divisor}: {bytes} bytes vs full {full}"
        );
    }
}
