//! Integration tests over the PJRT runtime: the AOT Pallas/JAX artifacts
//! must agree with the native Rust kernels — bit-exactly on integer paths.
//!
//! The whole suite is compiled only under the `pjrt` cargo feature (the
//! default, offline toolchain has neither the `xla` crate nor the PJRT
//! plugin); a stand-in test announces the skip otherwise. With the feature
//! on, the suite additionally requires `make artifacts` (skipped with a
//! message when they are absent, so `cargo test --features pjrt` stays
//! green on a fresh checkout).

#[cfg(not(feature = "pjrt"))]
#[test]
fn xla_cross_validation_skipped_without_pjrt_feature() {
    eprintln!(
        "skipping xla_cross_validation: built without the `pjrt` feature \
         (enable the xla dependency in rust/Cargo.toml and pass --features pjrt)"
    );
}

#[cfg(feature = "pjrt")]
mod pjrt_suite {
    use std::path::PathBuf;

    use tinytrain::kernels::{qlinear, OpCounter};
    use tinytrain::quant::{QParams, QTensor};
    use tinytrain::runtime::{lit_f32, lit_u8, Runtime};
    use tinytrain::tensor::{TensorF32, TensorU8};
    use tinytrain::util::prng::Pcg32;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("qmatmul_demo.hlo.txt").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
            None
        }
    }

    /// The Pallas qmatmul (via PJRT) and the native Rust quantized linear
    /// kernel must produce byte-identical results.
    #[test]
    fn pallas_qmatmul_bit_exact_with_native() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let art = rt.load_artifact(&dir, "qmatmul_demo").unwrap();

        let (m, k, n) = (16usize, 32usize, 8usize);
        let mut rng = Pcg32::seeded(42);
        let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
        let (za, zb, zo) = (7i32, 250i32, 13i32);
        let mult = 0.0173f32;

        let outs = art
            .execute(&[
                lit_u8(&[m, k], &a).unwrap(),
                lit_u8(&[k, n], &b).unwrap(),
                lit_f32(&[4], &[za as f32, zb as f32, mult, zo as f32]).unwrap(),
            ])
            .unwrap();
        let y_xla = outs[0].to_vec::<u8>().unwrap();
        let acc_xla = outs[1].to_vec::<i32>().unwrap();

        // native: drive the same math through qlinear_fwd per column of b
        // (a is [m,k] "weights", each b column is an input vector)
        let wq = QTensor {
            values: TensorU8::from_vec(&[m, k], a.clone()),
            qp: QParams { scale: 1.0, zero_point: za },
        };
        let mut ops = OpCounter::new();
        for col in 0..n {
            let xcol: Vec<u8> = (0..k).map(|r| b[r * n + col]).collect();
            let xq = QTensor {
                values: TensorU8::from_vec(&[k], xcol),
                qp: QParams { scale: mult, zero_point: zb }, // mult = s_a*s_b/s_o with s_o=1
            };
            let out_qp = QParams { scale: 1.0, zero_point: zo };
            let y = qlinear::qlinear_fwd(&xq, &wq, &vec![0i32; m], out_qp, false, &mut ops);
            for row in 0..m {
                assert_eq!(y.values.data()[row], y_xla[row * n + col], "mismatch at ({row},{col})");
            }
            // and the raw accumulator path
            for row in 0..m {
                let acc: i32 = (0..k)
                    .map(|i| {
                        (a[row * k + i] as i32 - za) * (b[i * n + col] as i32 - zb)
                    })
                    .sum();
                assert_eq!(acc, acc_xla[row * n + col]);
            }
        }
    }

    /// The float32 train-step artifact must match the native float backend
    /// on logits (within f32 reduction-order noise) for identical weights.
    #[test]
    fn float_artifact_matches_native_forward() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let art = rt.load_artifact(&dir, "mnist_cnn_float32_train").unwrap();

        use tinytrain::graph::exec::{calibrate, FloatParams, NativeModel};
        use tinytrain::graph::{models, DnnConfig};

        let mut rng = Pcg32::seeded(7);
        let def = models::mnist_cnn(&[1, 28, 28], 10);
        let fp = FloatParams::init(&def, &mut rng);
        let mut x = TensorF32::zeros(&[1, 28, 28]);
        rng.fill_normal(x.data_mut(), 0.5);
        let calib = calibrate(&def, &fp, &[x.clone()]);
        let native = NativeModel::build(def, DnnConfig::Float32, &fp, &calib);
        let mut ops = OpCounter::new();
        let native_logits = native.forward(&x, &mut ops).logits;

        // weight layer order in the artifact: conv1, conv2, fc1, fc2
        let w = |i: usize| fp.layers[i].as_ref().unwrap();
        let mut onehot = vec![0f32; 10];
        onehot[3] = 1.0;
        let flat =
            |t: &TensorF32, r: usize, c: usize| lit_f32(&[r, c], t.data()).unwrap();
        let outs = art
            .execute(&[
                lit_f32(&[1, 28, 28], x.data()).unwrap(),
                lit_f32(&[10], &onehot).unwrap(),
                flat(&w(0).0, 16, 9),
                lit_f32(&[16], &w(0).1).unwrap(),
                flat(&w(1).0, 32, 144),
                lit_f32(&[32], &w(1).1).unwrap(),
                flat(&w(4).0, 64, 288),
                lit_f32(&[64], &w(4).1).unwrap(),
                flat(&w(5).0, 10, 64),
                lit_f32(&[10], &w(5).1).unwrap(),
            ])
            .unwrap();
        let xla_logits = outs[1].to_vec::<f32>().unwrap();
        for (a, b) in xla_logits.iter().zip(&native_logits) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// End-to-end XLA-backend sanity: a few FQT steps through the artifact
    /// must reduce the loss on a separable toy stream.
    #[test]
    fn xla_fqt_trainer_learns_toy() {
        let Some(dir) = artifacts() else { return };
        let mut trainer =
            tinytrain::runtime::xla_trainer::load_fqt_trainer(&dir, (-2.0, 4.0), 0.01, 4, 3)
                .unwrap();
        let mut rng = Pcg32::seeded(11);
        let mut mk = |y: usize, rng: &mut Pcg32| {
            let mut x = TensorF32::zeros(&[1, 28, 28]);
            rng.fill_normal(x.data_mut(), 0.4);
            for v in x.data_mut().iter_mut() {
                *v += y as f32 * 0.6;
            }
            x
        };
        let data: Vec<(TensorF32, usize)> =
            (0..24).map(|i| (mk(i % 3, &mut rng), i % 3)).collect();
        let mut first = 0.0;
        let mut last = 0.0;
        for epoch in 0..6 {
            let mut tot = 0.0;
            for (x, y) in &data {
                let (loss, _) = trainer.train_step(x, *y).unwrap();
                tot += loss;
            }
            trainer.finish();
            if epoch == 0 {
                first = tot;
            }
            last = tot;
        }
        assert!(last < first * 0.8, "loss did not drop: {first} -> {last}");
        // weight ranges must have adapted (Eqs. 6–7)
        let xs: Vec<TensorF32> = data.iter().map(|(x, _)| x.clone()).collect();
        let ys: Vec<usize> = data.iter().map(|(_, y)| *y).collect();
        let acc = trainer.evaluate(&xs, &ys).unwrap();
        assert!(acc > 0.6, "acc={acc}");
    }
}
