//! Cross-validation of the WGSL compute backend (`backend::gpu`) against
//! the native engine: for a grid of models × DNN configurations, every
//! quantized activation coming back from the GPU must be **byte-identical**
//! to the CPU oracle's, and every float activation must agree within the
//! same tolerance tier the XLA suite uses (WGSL may contract mul-adds to
//! fma, so float paths are not bit-stable across drivers).
//!
//! The whole suite is compiled only under the `gpu` cargo feature (the
//! default offline toolchain has no `wgpu`); a stand-in test announces the
//! skip otherwise, and a second default-build test pins the feature's
//! zero-dependency contract. With the feature on, the suite additionally
//! requires a usable adapter — it clean-skips with a printed notice on
//! machines without any Vulkan/GL stack (CI installs Mesa lavapipe).

#[cfg(not(feature = "gpu"))]
mod default_build {
    #[test]
    fn gpu_cross_validation_skipped_without_gpu_feature() {
        eprintln!(
            "skipping gpu_cross_validation: built without the `gpu` feature \
             (enable the wgpu dependency in rust/Cargo.toml and pass --features gpu)"
        );
    }

    /// The `gpu` feature must compile out completely: the default build's
    /// dependency graph carries no `wgpu` — the dependency line ships
    /// commented out, exactly like `xla`, so an offline `cargo build`
    /// never touches the network.
    #[test]
    fn default_dep_graph_has_no_wgpu() {
        let manifest = concat!(env!("CARGO_MANIFEST_DIR"), "/Cargo.toml");
        let text = std::fs::read_to_string(manifest).expect("read Cargo.toml");
        for line in text.lines() {
            let t = line.trim_start();
            assert!(
                !(t.starts_with("wgpu =") || t.starts_with("wgpu=")),
                "wgpu must stay commented out in the default build: `{line}`"
            );
        }
        assert!(
            text.lines().any(|l| l.trim_start().starts_with("# wgpu = ")),
            "the commented-out wgpu dependency line must stay documented in Cargo.toml"
        );
    }
}

#[cfg(feature = "gpu")]
mod gpu_suite {
    use tinytrain::backend::gpu::{GpuAct, GpuContext, GpuPlan};
    use tinytrain::graph::act::Act;
    use tinytrain::graph::exec::{calibrate, FloatParams, NativeModel};
    use tinytrain::graph::plan::{arena_items_with, BitSpec};
    use tinytrain::graph::{DnnConfig, ModelDef};
    use tinytrain::harness;
    use tinytrain::kernels::OpCounter;
    use tinytrain::memplan::{align_up, allocate_arena};
    use tinytrain::quant::subbyte::WBits;
    use tinytrain::tensor::TensorF32;
    use tinytrain::util::bench::ResultSink;
    use tinytrain::util::json::Json;
    use tinytrain::util::prng::Pcg32;

    /// Batch size of every GPU forward — deliberately > 1 so the
    /// per-sample arena striding is exercised, small enough for lavapipe.
    const BATCH: usize = 3;

    /// Relative tolerance for float layers (same tier as the XLA suite:
    /// reduction order and fma contraction differ across backends).
    const FTOL: f32 = 1e-3;

    fn context() -> Option<GpuContext> {
        let ctx = GpuContext::try_new();
        if ctx.is_none() {
            eprintln!(
                "skipping gpu_cross_validation: no usable GPU adapter \
                 (install a Vulkan/GL driver, e.g. Mesa lavapipe, to run this suite)"
            );
        }
        ctx
    }

    fn inputs(def: &ModelDef, n: usize, rng: &mut Pcg32) -> Vec<TensorF32> {
        (0..n)
            .map(|_| {
                let mut x = TensorF32::zeros(&def.input_shape);
                rng.fill_normal(x.data_mut(), 0.5);
                x
            })
            .collect()
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= FTOL * b.abs().max(1.0)
    }

    fn assert_layer(tag: &str, sample: usize, layer: usize, cpu: &Act, gpu: &GpuAct) {
        match (cpu, gpu) {
            (Act::Q(t), GpuAct::Q(bytes, qp)) => {
                assert_eq!(t.qp.zero_point, qp.zero_point, "{tag} s{sample} L{layer} zero_point");
                assert_eq!(
                    t.qp.scale.to_bits(),
                    qp.scale.to_bits(),
                    "{tag} s{sample} L{layer} scale"
                );
                assert_eq!(t.values.data(), &bytes[..], "{tag} s{sample} L{layer} bytes");
            }
            (Act::F(t), GpuAct::F(v)) => {
                assert_eq!(t.len(), v.len(), "{tag} s{sample} L{layer} length");
                for (i, (a, b)) in v.iter().zip(t.data()).enumerate() {
                    assert!(close(*a, *b), "{tag} s{sample} L{layer}[{i}]: gpu {a} vs cpu {b}");
                }
            }
            _ => panic!("{tag} s{sample} L{layer}: precision mismatch between backends"),
        }
    }

    /// Build one (model, config) case, run both backends over the same
    /// batch, and compare every layer plus the logits. Also re-derives the
    /// liveness placement the GPU plan claims to use and checks its arena
    /// accounting against it.
    fn run_case(ctx: &GpuContext, sink: &mut ResultSink, model: NativeModel, tag: &str) {
        let gpu = GpuPlan::new(ctx, &model, BATCH);

        // Arena accounting: per-sample footprint must equal an independent
        // run of the same liveness placement (word-aligned inference
        // items), stay within the CPU plan's training-arena bound, and —
        // on these multi-layer models — beat the no-reuse sum of slots.
        let mut items = arena_items_with(&model.shared.def, model.shared.cfg, false, true);
        for it in &mut items {
            it.bytes = align_up(it.bytes, 4);
        }
        let no_reuse: usize = items.iter().map(|it| it.bytes).sum();
        let placed = allocate_arena(items);
        assert_eq!(gpu.arena_bytes_per_sample(), placed.total_bytes, "{tag} arena accounting");
        assert_eq!(gpu.slot_bytes_total(), no_reuse, "{tag} slot accounting");
        assert!(
            gpu.arena_bytes_per_sample() < gpu.slot_bytes_total(),
            "{tag}: liveness reuse should beat the no-reuse slot sum"
        );
        assert!(
            gpu.arena_bytes_per_sample() <= model.plan().planned_peak_bytes,
            "{tag}: inference arena exceeds the plan's training-arena bound"
        );

        let mut rng = Pcg32::new(0xD06F00D, 0x9);
        let xs = inputs(&model.shared.def, BATCH, &mut rng);
        let mut ops = OpCounter::new();
        let traces: Vec<_> = xs.iter().map(|x| model.forward(x, &mut ops)).collect();
        let gpu_acts = gpu.forward_batch_captured(ctx, &xs);
        let gpu_logits = gpu.forward_batch(ctx, &xs);

        assert_eq!(gpu_acts.len(), BATCH, "{tag} batch arity");
        for (s, (trace, acts)) in traces.iter().zip(&gpu_acts).enumerate() {
            assert_eq!(acts.len(), trace.acts.len(), "{tag} s{s} layer arity");
            for (l, (cpu, dev)) in trace.acts.iter().zip(acts).enumerate() {
                assert_layer(tag, s, l, cpu, dev);
            }
            let logits = &gpu_logits[s];
            assert_eq!(logits.len(), trace.logits.len(), "{tag} s{s} logit arity");
            for (i, (a, b)) in logits.iter().zip(&trace.logits).enumerate() {
                assert!(close(*a, *b), "{tag} s{s} logit[{i}]: gpu {a} vs cpu {b}");
            }
        }

        sink.push(Json::obj(vec![
            ("kernel", Json::str("gpu_forward_parity")),
            ("case", Json::str(tag)),
            ("batch", Json::Num(BATCH as f64)),
            ("dispatches", Json::Num(gpu.num_dispatches() as f64)),
            ("arena_bytes_per_sample", Json::Num(gpu.arena_bytes_per_sample() as f64)),
            ("slot_bytes_no_reuse", Json::Num(gpu.slot_bytes_total() as f64)),
        ]));
    }

    /// The full parity grid: three model families × three DNN configs,
    /// all built **unfused** (the repository's bit-parity oracle mode).
    #[test]
    fn gpu_matches_native_across_models_and_configs() {
        let Some(ctx) = context() else { return };
        eprintln!("gpu_cross_validation adapter: {}", ctx.adapter_info);
        let mut sink = ResultSink::new("gpu_cross_validation");
        sink.push(Json::obj(vec![
            ("kernel", Json::str("gpu_adapter")),
            ("info", Json::str(&ctx.adapter_info)),
            ("batch", Json::Num(BATCH as f64)),
        ]));
        let mut rng = Pcg32::new(0x6D0, 0x11);
        for def in harness::parity_models() {
            for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
                let tag = format!("{}/{:?}", def.name, cfg);
                let fp = FloatParams::init(&def, &mut rng);
                let xs = inputs(&def, 2, &mut rng);
                let calib = calibrate(&def, &fp, &xs);
                let model = NativeModel::build_with_fusion(def.clone(), cfg, &fp, &calib, false);
                run_case(&ctx, &mut sink, model, &tag);
            }
        }
        let path = sink.flush().expect("write gpu_cross_validation report");
        eprintln!("gpu_cross_validation report: {}", path.display());
    }

    /// Packed sub-byte weights unpack host-side into the exact same lanes
    /// the CPU kernels see, so a W4 deployment must stay byte-identical
    /// on the GPU too.
    #[test]
    fn gpu_matches_native_with_packed_w4_weights() {
        let Some(ctx) = context() else { return };
        let mut sink = ResultSink::new("gpu_cross_validation_w4");
        let def = harness::parity_models().remove(0);
        let mut rng = Pcg32::new(0xBEEF, 0x2);
        let fp = FloatParams::init(&def, &mut rng);
        let xs = inputs(&def, 2, &mut rng);
        let calib = calibrate(&def, &fp, &xs);
        let bits = BitSpec { force: Some(WBits::W4), budget: None };
        let model =
            NativeModel::build_with_bits(def, DnnConfig::Uint8, &fp, &calib, false, &bits);
        run_case(&ctx, &mut sink, model, "mnist_cnn/Uint8/w4");
        sink.flush().expect("write gpu_cross_validation_w4 report");
    }
}
