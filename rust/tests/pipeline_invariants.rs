//! Cross-module integration tests: invariants of the full on-device
//! pipeline (data → deploy → train → plan → price) that no single module's
//! unit tests can see.

use tinytrain::data::{spec_by_name, transfer_specs, Domain};
use tinytrain::device;
use tinytrain::graph::exec::{calibrate, DenseUpdates, FloatParams, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::harness::{self, Knobs};
use tinytrain::kernels::OpCounter;
use tinytrain::memplan;
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::sparse::DynamicSparse;
use tinytrain::train::Optimizer;
use tinytrain::util::prng::Pcg32;
use tinytrain::util::proptest::Prop;

fn knobs() -> Knobs {
    Knobs { epochs: 2, runs: 1, train_pc: 2, test_pc: 1, ..Knobs::default() }
}

/// In-place property: a training step must not change the *inference*
/// representation shape or precision — the same weights serve both.
#[test]
fn training_preserves_inference_representation() {
    let spec = spec_by_name("cifar10").unwrap();
    let mut rng = Pcg32::seeded(1);
    let dom = Domain::new(&spec, [3, 12, 12], 1);
    let (tr, _) = dom.splits(2, 0, &mut rng);
    let def = models::mnist_cnn(&[3, 12, 12], 10);
    let fp = FloatParams::init(&def, &mut rng);
    let calib = calibrate(&def, &fp, &tr.xs[..2]);
    let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);

    let bytes_before: usize = m.state.params.iter().map(|p| p.byte_size()).sum();
    let mut opt = FqtSgd::new(&m, 0.01, 2);
    let mut ops = OpCounter::new();
    for (x, &y) in tr.xs.iter().zip(&tr.ys) {
        let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
        opt.accumulate(&mut m, &bwd, &mut ops);
    }
    opt.finish(&mut m, &mut ops);
    let bytes_after: usize = m.state.params.iter().map(|p| p.byte_size()).sum();
    assert_eq!(bytes_before, bytes_after, "weight memory layout must be stable");
    // inference still works on the same object
    let _ = m.predict(&tr.xs[0], &mut ops);
}

/// The memory planner's training plan must dominate its inference plan for
/// every dataset × config of the evaluation (Fig. 4c premise).
#[test]
fn training_plan_dominates_inference_plan_everywhere() {
    for spec in transfer_specs() {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let def = harness::mbednet_for(&spec, &spec.paper_shape);
            let t = memplan::plan(&def, cfg, true);
            let i = memplan::plan(&def, cfg, false);
            assert!(
                t.total_ram() >= i.total_ram(),
                "{} {:?}: train {} < infer {}",
                spec.name,
                cfg,
                t.total_ram(),
                i.total_ram()
            );
            assert!(t.flash <= i.flash, "trainable weights must leave flash");
        }
    }
}

/// Device pricing is monotone in op counts — more work never costs less,
/// on any device (property test over random op bundles).
#[test]
fn device_cost_is_monotone() {
    Prop::new(64).check(
        |r| {
            (r.below(1_000_000) as u64, r.below(1_000_000) as u64, r.below(100_000) as u64)
        },
        |_| vec![],
        |&(im, fm, by)| {
            for d in device::all_devices() {
                let a = OpCounter { int_macs: im, float_macs: fm, bytes: by, ..Default::default() };
                let b = OpCounter {
                    int_macs: im + 1000,
                    float_macs: fm + 1000,
                    bytes: by + 1000,
                    ..Default::default()
                };
                if d.cost(&b).seconds < d.cost(&a).seconds {
                    return Err(format!("{} non-monotone", d.name));
                }
            }
            Ok(())
        },
    );
}

/// Sparse updates must never *increase* measured backward cost, and the
/// steady-state rate must approach λ_min (Eq. 9 limit behaviour).
#[test]
fn sparse_bwd_cost_monotone_in_lambda() {
    let spec = spec_by_name("cifar10").unwrap();
    let mut small = spec.clone();
    small.reduced_shape = [3, 16, 16];
    let k = knobs();
    let src = Domain::new(&small, small.reduced_shape, 5);
    let def = harness::mbednet_for(&small, &small.reduced_shape);
    let (fp, _) = harness::pretrain(&def, &src, 1, &k, 6);
    let mut scen = harness::tl_scenario(&small, DnnConfig::Uint8, &fp, &src, &k, 7);
    let dev = device::imxrt1062();
    let (_, b10) = harness::step_costs(&mut scen.model, &scen.train, &dev, 1.0);
    let (_, b05) = harness::step_costs(&mut scen.model, &scen.train, &dev, 0.5);
    let (_, b01) = harness::step_costs(&mut scen.model, &scen.train, &dev, 0.1);
    assert!(b05.seconds <= b10.seconds * 1.001);
    assert!(b01.seconds <= b05.seconds * 1.001);
    assert!(b01.seconds < b10.seconds * 0.8, "λ=0.1 must cut backward cost substantially");
}

/// Eq. 9 steady state: with max_loss seeded large, the controller's rate
/// equals λ_min and the kept fraction follows.
#[test]
fn eq9_steady_state_rate_is_lambda_min() {
    let mut ctl = DynamicSparse::new(0.25, 1.0);
    ctl.seed_max_loss(1e9);
    ctl.begin_sample(0.01);
    assert!((ctl.rate() - 0.25).abs() < 1e-4);
}

/// Determinism: the same seeds must produce the identical training report
/// (the whole stack is PRNG-driven — any hidden nondeterminism breaks
/// reproducibility of EXPERIMENTS.md).
#[test]
fn end_to_end_determinism() {
    let run = || {
        let spec = spec_by_name("cwru").unwrap();
        let mut small = spec.clone();
        small.reduced_shape = [1, 1, 64];
        let k = knobs();
        let src = Domain::new(&small, small.reduced_shape, 9);
        let def = harness::mbednet_for(&small, &small.reduced_shape);
        let (fp, _) = harness::pretrain(&def, &src, 1, &k, 10);
        let mut scen = harness::tl_scenario(&small, DnnConfig::Uint8, &fp, &src, &k, 11);
        let rep = harness::run_tl(&mut scen, 0.5, &k, 12);
        (
            rep.final_test_acc(),
            rep.epochs.last().unwrap().train_loss,
            rep.bwd_ops.int_macs,
            rep.kept_fraction,
        )
    };
    assert_eq!(run(), run());
}

/// Full-training uint8 deployment of the §IV-D net fits every Tab. II MCU
/// including its optimizer state and a minimal replay buffer — the
/// end-to-end feasibility claim of the paper.
#[test]
fn full_training_deployment_fits_all_mcus_with_optimizer_state() {
    let def = models::mnist_cnn(&[1, 28, 28], 10);
    let plan = memplan::plan(&def, DnnConfig::Uint8, true);
    let mut rng = Pcg32::seeded(2);
    let fp = FloatParams::init(&def, &mut rng);
    let calib = calibrate(&def, &fp, &[tinytrain::tensor::TensorF32::zeros(&[1, 28, 28])]);
    let m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
    let opt = FqtSgd::new(&m, 0.01, 8);
    let replay_bytes = 16 * 28 * 28; // 16 uint8 samples
    let total = plan.total_ram() + opt.state_bytes() + replay_bytes;
    for d in device::all_devices() {
        assert!(
            total <= d.ram_bytes,
            "{}: {} B needed, {} B available",
            d.name,
            total,
            d.ram_bytes
        );
    }
}
