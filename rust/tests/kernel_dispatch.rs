//! Forced-dispatch parity: `TT_KERNEL=scalar` and `TT_KERNEL=simd` must
//! be bit-identical end to end — the SIMD micro-kernels exist purely as a
//! host-side accelerator over the MCU-faithful scalar oracle, never as an
//! approximation of it.
//!
//!  * the whole-model matrix (every model × every DNN configuration,
//!    forward with range adaptation, dense and §III-B sparse backward)
//!    runs once per forced mode and compares logits, activations,
//!    saturation counts, gradients, adapted quantization parameters and
//!    error-observer ranges bit for bit;
//!  * kernel-level property tests sweep the GEMM tile edges (`MR`/`NR`
//!    ± 1, ragged K) and the depthwise row widths around the vector lane
//!    counts, comparing the explicit `KernelSel::Scalar` and
//!    `KernelSel::Simd` twins directly — no global state involved.
//!
//! On a host without a vector ISA the SIMD arms skip cleanly (the scalar
//! oracle is the only path, so there is nothing to compare).

use tinytrain::graph::exec::{calibrate, Act, DenseUpdates, FloatParams, NativeModel};
use tinytrain::graph::{models, DnnConfig};
use tinytrain::kernels::simd::{self, KernelMode, KernelSel};
use tinytrain::kernels::{dwconv, gemm, softmax, ConvGeom, OpCounter};
use tinytrain::quant::{QParams, QTensor};
use tinytrain::tensor::TensorF32;
use tinytrain::train::sparse::DynamicSparse;
use tinytrain::util::prng::Pcg32;

const CASES: [(&str, [usize; 3], usize); 3] =
    [("mnist_cnn", [1, 12, 12], 4), ("mbednet", [3, 16, 16], 5), ("mcunet5fps", [3, 32, 32], 4)];

fn build(
    name: &str,
    shape: &[usize; 3],
    classes: usize,
    cfg: DnnConfig,
    seed: u64,
) -> (NativeModel, Vec<TensorF32>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::by_name(name, shape, classes).expect("known model");
    let fp = FloatParams::init(&def, &mut rng);
    let xs: Vec<TensorF32> = (0..3)
        .map(|_| {
            let mut x = TensorF32::zeros(shape);
            rng.fill_normal(x.data_mut(), 1.0);
            x
        })
        .collect();
    let calib = calibrate(&def, &fp, &xs[..2]);
    (NativeModel::build(def, cfg, &fp, &calib), xs)
}

fn act_bits(a: &Act) -> (Vec<u8>, Vec<u32>) {
    match a {
        Act::Q(t) => {
            (t.values.data().to_vec(), vec![t.qp.scale.to_bits(), t.qp.zero_point as u32])
        }
        Act::F(t) => (Vec::new(), t.data().iter().map(|v| v.to_bits()).collect()),
    }
}

/// Everything one forced-mode run produces, reduced to exact bits.
#[derive(PartialEq, Debug, Default)]
struct Fingerprint {
    logits: Vec<Vec<u32>>,
    acts: Vec<Vec<(Vec<u8>, Vec<u32>)>>,
    sat: Vec<Vec<Option<(usize, usize)>>>,
    grads: Vec<Vec<Option<(Vec<u32>, Vec<u32>, (usize, usize))>>>,
    sparse_kept: (u64, u64),
    act_qp: Vec<(u32, i32)>,
    obs_ranges: Vec<Option<(u32, u32)>>,
}

/// Run a fresh deployment of the same float masters under one forced
/// dispatch mode: adaptive forwards and dense backwards over every
/// sample, then one sparse-masked backward. A fresh model per mode is
/// essential — range adaptation mutates the session, so sharing one
/// model across modes would compare different observer states, not
/// different kernels.
fn fingerprint(
    mode: KernelMode,
    name: &str,
    shape: &[usize; 3],
    classes: usize,
    cfg: DnnConfig,
) -> Fingerprint {
    simd::set_mode(mode);
    let (mut m, xs) = build(name, shape, classes, cfg, 0x51D);
    let mut fp = Fingerprint::default();
    let mut scratch = m.make_scratch();
    let mut ops = OpCounter::new();
    for (k, x) in xs.iter().enumerate() {
        let trace = m.forward_adapt_in(x, &mut scratch, &mut ops);
        fp.logits.push(trace.logits.iter().map(|v| v.to_bits()).collect());
        fp.acts.push(trace.acts.iter().map(act_bits).collect());
        fp.sat.push(trace.sat.clone());
        let (_, _, err) = softmax::softmax_ce(&trace.logits, k % classes, &mut ops);
        let bwd = m.backward_in(&trace, err, &mut DenseUpdates, &mut scratch, &mut ops);
        fp.grads.push(
            bwd.grads
                .iter()
                .map(|g| {
                    g.as_ref().map(|g| {
                        (
                            g.gw.data().iter().map(|v| v.to_bits()).collect(),
                            g.gb.data().iter().map(|v| v.to_bits()).collect(),
                            g.kept,
                        )
                    })
                })
                .collect(),
        );
    }
    // one §III-B sparse-masked backward (the depthwise whole-channel
    // skip and the masked GEMMs under the same contract)
    let trace = m.forward_in(&xs[0], &mut scratch, &mut ops);
    let (loss, _, err) = softmax::softmax_ce(&trace.logits, 0, &mut ops);
    let mut ctl = DynamicSparse::new(0.4, 1.0);
    ctl.seed_max_loss(loss * 4.0 + 1.0);
    ctl.begin_sample(loss);
    let mut obs = m.state.err_obs.clone();
    let bwd = m.backward_with(&trace, err, &mut ctl, &mut obs, &mut scratch, &mut ops);
    fp.sparse_kept = (ctl.kept, ctl.total);
    fp.grads.push(
        bwd.grads
            .iter()
            .map(|g| {
                g.as_ref().map(|g| {
                    (
                        g.gw.data().iter().map(|v| v.to_bits()).collect(),
                        g.gb.data().iter().map(|v| v.to_bits()).collect(),
                        g.kept,
                    )
                })
            })
            .collect(),
    );
    fp.act_qp = m.state.act_qp.iter().map(|qp| (qp.scale.to_bits(), qp.zero_point)).collect();
    fp.obs_ranges = m
        .state
        .err_obs
        .iter()
        .map(|o| o.range().map(|(lo, hi)| (lo.to_bits(), hi.to_bits())))
        .collect();
    fp
}

/// The whole-model dispatch matrix. One test function on purpose: the
/// forced mode is process-wide (`simd::set_mode`), so splitting the
/// matrix across `#[test]`s would race the modes across the test
/// harness's worker threads. The kernel-level tests below use explicit
/// `KernelSel` arguments and never read the global mode.
#[test]
fn forced_scalar_and_simd_runs_are_bit_identical() {
    let prev = simd::mode();
    if simd::isa().is_none() {
        eprintln!("kernel_dispatch: no vector ISA on this host, parity trivially holds; skipped");
        return;
    }
    for (name, shape, classes) in CASES {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let fs = fingerprint(KernelMode::Scalar, name, &shape, classes, cfg);
            let fv = fingerprint(KernelMode::Simd, name, &shape, classes, cfg);
            assert_eq!(fs, fv, "{name}/{cfg:?}: forced scalar vs forced simd diverged");
        }
    }
    simd::set_mode(prev);
}

// ---------------------------------------------------------------------------
// Kernel-level tile-edge property tests (explicit KernelSel, no globals)
// ---------------------------------------------------------------------------

fn fill_u8(rng: &mut Pcg32, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// GEMM at the register-tile edges: every (m, n) within ±1 of the MR×NR
/// tile (plus far-out ragged columns) over ragged K, forced-SIMD output
/// equal to the scalar oracle bit for bit — including the partial-tile
/// remainders the vector path must hand back to scalar code.
#[test]
fn gemm_u8_simd_matches_scalar_at_tile_edges() {
    let Some(isa) = simd::isa() else {
        eprintln!("kernel_dispatch: no vector ISA, gemm edge sweep skipped");
        return;
    };
    let mut rng = Pcg32::seeded(0xED6E);
    let ms = [gemm::MR - 1, gemm::MR, gemm::MR + 1, 2 * gemm::MR + 1];
    let ns = [1, gemm::NR - 1, gemm::NR, gemm::NR + 1, 2 * gemm::NR + 1];
    for &m in &ms {
        for &n in &ns {
            for &k in &[1usize, 7, 16, 33] {
                let a = fill_u8(&mut rng, m * k);
                let b = fill_u8(&mut rng, k * n);
                let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                let mut out_s = vec![0i32; m * n];
                let mut out_v = vec![0i32; m * n];
                gemm::gemm_u8_i32_sel(KernelSel::Scalar, &a, 3, &b, 5, &init, m, k, n, &mut out_s);
                gemm::gemm_u8_i32_sel(
                    KernelSel::Simd(isa),
                    &a,
                    3,
                    &b,
                    5,
                    &init,
                    m,
                    k,
                    n,
                    &mut out_v,
                );
                assert_eq!(out_s, out_v, "gemm m={m} k={k} n={n} ({isa:?})");
            }
        }
    }
}

/// The fused quantized epilogue under forced SIMD: u8 output bytes AND
/// the saturation count must match the scalar oracle exactly at the same
/// tile edges (the epilogue runs inside the register tile, so a lane
/// ordering bug would show up here first).
#[test]
fn gemm_fused_epilogue_simd_matches_scalar_at_tile_edges() {
    let Some(isa) = simd::isa() else {
        eprintln!("kernel_dispatch: no vector ISA, fused edge sweep skipped");
        return;
    };
    let mut rng = Pcg32::seeded(0xFED);
    let epi = gemm::QEpilogue { mult: 0.0134, qp: QParams::from_min_max(0.0, 4.0), relu: true };
    for &m in &[gemm::MR - 1, gemm::MR, gemm::MR + 1] {
        for &n in &[gemm::NR - 1, gemm::NR, gemm::NR + 1] {
            for &k in &[1usize, 9, 27] {
                let a = fill_u8(&mut rng, m * k);
                let b = fill_u8(&mut rng, k * n);
                let init = vec![7i32; m];
                let mut out_s = vec![0u8; m * n];
                let mut out_v = vec![0u8; m * n];
                let mut dq_s = vec![0f32; m * n];
                let mut dq_v = vec![0f32; m * n];
                let sat_s = gemm::gemm_u8_i32_fused_sel(
                    KernelSel::Scalar,
                    &a,
                    3,
                    &b,
                    5,
                    &init,
                    m,
                    k,
                    n,
                    &epi,
                    &mut out_s,
                    Some(&mut dq_s),
                );
                let sat_v = gemm::gemm_u8_i32_fused_sel(
                    KernelSel::Simd(isa),
                    &a,
                    3,
                    &b,
                    5,
                    &init,
                    m,
                    k,
                    n,
                    &epi,
                    &mut out_v,
                    Some(&mut dq_v),
                );
                assert_eq!(out_s, out_v, "fused m={m} k={k} n={n} ({isa:?})");
                assert_eq!(sat_s, sat_v, "fused sat m={m} k={k} n={n}");
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                assert_eq!(bits(&dq_s), bits(&dq_v), "fused dequant m={m} k={k} n={n}");
            }
        }
    }
}

fn rand_q(rng: &mut Pcg32, shape: &[usize]) -> QTensor {
    let mut t = TensorF32::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    QTensor::quantize(&t)
}

/// Depthwise rows around the vector lane widths: forward (plain and
/// fused) and backward-input at widths straddling the 4/8/16-lane
/// boundaries must be bit-identical between the forced arms, qparams and
/// saturation included.
#[test]
fn dwconv_simd_matches_scalar_at_lane_edges() {
    let Some(isa) = simd::isa() else {
        eprintln!("kernel_dispatch: no vector ISA, dwconv edge sweep skipped");
        return;
    };
    let mut rng = Pcg32::seeded(0xD0);
    let oqp = QParams::from_min_max(0.0, 4.0);
    for &w_in in &[3usize, 7, 8, 9, 15, 16, 17, 33] {
        let geom = ConvGeom {
            cin: 6,
            cout: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: true,
        };
        let x = rand_q(&mut rng, &[6, 5, w_in]);
        let w = rand_q(&mut rng, &[6, 1, 3, 3]);
        let bias: Vec<i32> = (0..6).map(|_| rng.below(64) as i32 - 32).collect();
        let fwd = |sel: KernelSel| {
            let mut ops = OpCounter::new();
            dwconv::qdwconv2d_fwd_sel(sel, &x, &w, &bias, &geom, oqp, true, &mut ops)
        };
        let ys = fwd(KernelSel::Scalar);
        let yv = fwd(KernelSel::Simd(isa));
        assert_eq!(ys.values.data(), yv.values.data(), "dw fwd w={w_in} ({isa:?})");
        assert_eq!(ys.qp.scale.to_bits(), yv.qp.scale.to_bits(), "dw fwd qp w={w_in}");

        let fused = |sel: KernelSel| {
            let mut ops = OpCounter::new();
            dwconv::qdwconv2d_fwd_fused_sel(sel, &x, &w, &bias, &geom, oqp, true, &mut ops)
        };
        let (fs, sat_s) = fused(KernelSel::Scalar);
        let (fv, sat_v) = fused(KernelSel::Simd(isa));
        assert_eq!(fs.values.data(), fv.values.data(), "dw fused fwd w={w_in}");
        assert_eq!(sat_s, sat_v, "dw fused sat w={w_in}");

        let e = rand_q(&mut rng, &[6, 5, w_in]);
        let bwd = |sel: KernelSel| {
            let mut ops = OpCounter::new();
            let mut scratch = tinytrain::memplan::Scratch::new();
            dwconv::qdwconv2d_bwd_input_sel(
                sel,
                &e,
                &w,
                &geom,
                5,
                w_in,
                oqp,
                None,
                &mut scratch,
                &mut ops,
            )
        };
        let gs = bwd(KernelSel::Scalar);
        let gv = bwd(KernelSel::Simd(isa));
        assert_eq!(gs.values.data(), gv.values.data(), "dw bwd_input w={w_in} ({isa:?})");

        let bwd_w = |sel: KernelSel| {
            let mut ops = OpCounter::new();
            dwconv::qdwconv2d_bwd_weight_sel(sel, &e, &x, &geom, None, &mut ops)
        };
        let (gws, gbs) = bwd_w(KernelSel::Scalar);
        let (gwv, gbv) = bwd_w(KernelSel::Simd(isa));
        let bits = |t: &TensorF32| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&gws), bits(&gwv), "dw bwd_weight w={w_in} ({isa:?})");
        assert_eq!(bits(&gbs), bits(&gbv), "dw bwd_weight bias w={w_in}");
    }
}
