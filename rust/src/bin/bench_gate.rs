//! CI perf-regression gate: diff a fresh quick-mode `perf_kernels` output
//! against the checked-in `BENCH_kernels.json` baseline and fail the job
//! when any row regresses beyond a generous noise tolerance.
//!
//! Usage: `bench_gate <baseline.json> <fresh.json>`
//!
//! Both files may be either the repo-root `BENCH_kernels.json` shape (an
//! object whose `"kernels"` field holds the row array) or the raw
//! `rust/results/perf_kernels.json` row array. The gate applies three
//! layers of checks, strictest first:
//!
//!  1. **schema** — the fresh rows must pass
//!     [`check_perf_rows`](tinytrain::util::bench::check_perf_rows)
//!     (every row an object with a `"kernel"` name, all numbers finite);
//!     malformed input fails the gate outright.
//!  2. **internal ratio floors** — machine-independent: every `*speedup*`
//!     field of the fresh run must be ≥ `1/tol` (a fast path falling to
//!     less than half its reference at the default tolerance means the
//!     engine regressed, whatever the hardware).
//!  3. **fused-epilogue floor** — machine-independent: the geometric mean
//!     of `fused_speedup_vs_unfused` over the `gemm_fused_epilogue` rows
//!     must be ≥ `TT_BENCH_GATE_FUSED_FLOOR` (default 1.0). The fused
//!     tile writeout does strictly less memory traffic than the retained
//!     GEMM + requantization sweep, so parity-on-average is the floor on
//!     any hardware; no absolute times are involved.
//!     Likewise **fleet sharing floor** — every `fleet_session` row with
//!     ≥ 100 tenants must report `memory_ratio_vs_independent` ≥
//!     `TT_BENCH_GATE_FLEET_FLOOR` (default 1.5): per-tenant memory is
//!     session deltas + replay, so N independent deployments must cost a
//!     healthy multiple of the shared-artifact fleet (byte accounting,
//!     no wall clock).
//!     Likewise **SIMD dispatch floor** — the geometric mean of
//!     `simd_speedup_vs_scalar` over the `gemm_simd_vs_scalar` and
//!     `dwconv_simd_vs_scalar` rows must be ≥ `TT_BENCH_GATE_SIMD_FLOOR`
//!     (default 1.0): the vector path only exists to beat the scalar
//!     oracle, so parity-on-average is the floor. The rows are emitted
//!     only when the host exposes a vector ISA, so the check self-skips
//!     elsewhere.
//!     Likewise **sub-byte floors** — the geometric mean of
//!     `packed_relative_speed` over the `subbyte_unpack_overhead` rows
//!     must be ≥ `TT_BENCH_GATE_SUBBYTE_FLOOR` (default 0.5): the
//!     in-kernel unpack is a per-panel pass over the packed A image, so
//!     the packed GEMM may trail the u8 kernel, but falling under half
//!     its speed means the unpack stopped being amortized. And every
//!     `subbyte_model_bytes` row must report `w4_ratio` ≤ 0.6 and
//!     `w2_ratio` ≤ 0.35 — pure packing arithmetic, so a drift means the
//!     byte accounting broke. Both self-skip when the rows are absent.
//!     Every self-skipping floor (SIMD, sub-byte, fleet, fused) announces
//!     its skip with a `bench_gate: SKIP …` line naming the missing row
//!     table, so a gate that silently stopped checking is visible in the
//!     CI log instead of reading as a pass.
//!  4. **baseline diff** — per matching row key, `*seconds*` fields may
//!     grow at most `tol`× over the baseline and `*speedup*` fields may
//!     shrink at most `tol`× under it. Rows present on only one side are
//!     reported but do not fail (the bench grows across PRs).
//!
//! A missing baseline file is not a failure: the gate prints how to seed
//! it and passes on the internal checks alone (first-PR bootstrap).
//!
//! Knobs: `TT_BENCH_GATE_TOL` (default 2.0 — generous; CI runners are
//! noisy), `TT_BENCH_GATE_FUSED_FLOOR` (default 1.0) for the
//! fused-epilogue geometric-mean floor, `TT_BENCH_GATE_FLEET_FLOOR`
//! (default 1.5) for the fleet sharing floor, `TT_BENCH_GATE_SIMD_FLOOR`
//! (default 1.0) for the SIMD-vs-scalar geometric-mean floor,
//! `TT_BENCH_GATE_SUBBYTE_FLOOR` (default 0.5) for the packed-GEMM
//! relative-speed geometric-mean floor, and
//! `TT_BENCH_GATE_ABS=0` to skip the absolute `*seconds*` comparisons
//! when diffing runs from incomparable hardware.
//!
//! Refreshing the baseline: run the bench in quick mode exactly as CI
//! does (`cd rust && TT_PERF_REPS=3 TT_PERF_BATCH=4 TT_WORKERS=2 cargo
//! bench --bench perf_kernels`), inspect the regenerated repo-root
//! `BENCH_kernels.json`, and commit it.

use std::process::ExitCode;

use tinytrain::util::bench::{check_perf_rows, geomean};
use tinytrain::util::json::Json;

fn tolerance() -> f64 {
    std::env::var("TT_BENCH_GATE_TOL")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(2.0)
        .max(1.0)
}

/// Floor on the geometric mean of `fused_speedup_vs_unfused` across the
/// `gemm_fused_epilogue` rows (machine-independent: both arms of each
/// ratio ran on the same machine in the same process).
fn fused_floor() -> f64 {
    std::env::var("TT_BENCH_GATE_FUSED_FLOOR")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.0)
        .max(0.0)
}

/// Floor on `memory_ratio_vs_independent` for every `fleet_session` row
/// with ≥ 100 tenants (machine-independent: the ratio is pure byte
/// accounting — N independent deployments over the shared-artifact
/// fleet). At scale the shared weights + activation plan must be
/// amortized, so the ratio sits well above 1; a collapse toward 1 means
/// per-tenant sessions started duplicating shared deployment state.
fn fleet_floor() -> f64 {
    std::env::var("TT_BENCH_GATE_FLEET_FLOOR")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.5)
        .max(0.0)
}

/// Floor on the geometric mean of `simd_speedup_vs_scalar` across the
/// `gemm_simd_vs_scalar` / `dwconv_simd_vs_scalar` rows
/// (machine-independent: both arms ran on the same machine in the same
/// process). The vector path exists purely as a host-side accelerator, so
/// parity-on-average with the scalar oracle is the floor: a dispatcher
/// that picks SIMD where it loses to scalar is a plan-compiler bug.
fn simd_floor() -> f64 {
    std::env::var("TT_BENCH_GATE_SIMD_FLOOR")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.0)
        .max(0.0)
}

/// Floor on the geometric mean of `packed_relative_speed` across the
/// `subbyte_unpack_overhead` rows (machine-independent: the packed and
/// plain-u8 GEMM arms ran on the same machine in the same process). The
/// in-kernel unpack is a per-panel pass over the packed A image ahead of
/// the identical u8 body, so the packed path may trail plain u8 — but at
/// less than half speed the unpack stopped being amortized by the GEMM.
fn subbyte_floor() -> f64 {
    std::env::var("TT_BENCH_GATE_SUBBYTE_FLOOR")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.5)
        .max(0.0)
}

/// Extract the row array from either supported file shape.
fn rows_of(doc: &Json) -> Option<&[Json]> {
    if let Some(a) = doc.as_arr() {
        return Some(a);
    }
    doc.get("kernels").as_arr()
}

/// Stable identity of a row: the kernel name plus every identifying
/// discriminator field the bench emits next to its metrics.
fn row_key(row: &Json) -> String {
    let mut key = row.get("kernel").as_str().unwrap_or("?").to_string();
    for field in ["shape", "model"] {
        if let Some(s) = row.get(field).as_str() {
            key.push_str(&format!(" {field}={s}"));
        }
    }
    for field in ["kept_fraction", "batch", "workers", "layers", "bits"] {
        if let Some(n) = row.get(field).as_f64() {
            key.push_str(&format!(" {field}={n}"));
        }
    }
    key
}

fn load_rows(path: &str) -> Result<Vec<Json>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))?;
    let rows = rows_of(&doc).ok_or_else(|| format!("{path}: no bench row array found"))?;
    Ok(rows.to_vec())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_gate <baseline.json> <fresh.json>");
        return ExitCode::from(2);
    }
    let (baseline_path, fresh_path) = (&args[1], &args[2]);
    let tol = tolerance();
    let compare_abs = std::env::var("TT_BENCH_GATE_ABS").ok().as_deref() != Some("0");

    let fresh = match load_rows(fresh_path) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mut failures: Vec<String> = Vec::new();

    // 1. schema: the gate refuses to reason about malformed rows.
    if let Err(e) = check_perf_rows(&fresh) {
        eprintln!("bench_gate: fresh rows failed the schema check: {e}");
        return ExitCode::FAILURE;
    }
    println!("bench_gate: {} fresh rows, schema OK (tolerance {tol}x)", fresh.len());

    // 2. internal ratio floors (machine-independent).
    for row in &fresh {
        let key = row_key(row);
        if let Some(obj) = row.as_obj() {
            for (name, v) in obj {
                if !name.contains("speedup") {
                    continue;
                }
                if let Some(ratio) = v.as_f64() {
                    if ratio < 1.0 / tol {
                        failures.push(format!(
                            "[{key}] {name} = {ratio:.3} below the {:.3} internal floor",
                            1.0 / tol
                        ));
                    }
                }
            }
        }
    }

    // 3. fused-epilogue floor: the fused tile writeout must hold at
    // least geomean parity with the retained GEMM + requantization
    // sweep. A per-row dip rides on the generic 1/tol floor above; the
    // geometric mean smooths single-shape noise while still refusing a
    // systematically slower fused path.
    let fused_speedups: Vec<f64> = fresh
        .iter()
        .filter(|row| row.get("kernel").as_str() == Some("gemm_fused_epilogue"))
        .filter_map(|row| row.get("fused_speedup_vs_unfused").as_f64())
        .collect();
    if let Some(g) = geomean(&fused_speedups) {
        let floor = fused_floor();
        println!(
            "bench_gate: fused-epilogue geomean speedup {g:.3} over {} rows (floor {floor})",
            fused_speedups.len()
        );
        if g < floor {
            failures.push(format!(
                "fused-epilogue geomean speedup {g:.3} below the {floor} floor \
                 (TT_BENCH_GATE_FUSED_FLOOR)"
            ));
        }
    } else {
        println!("bench_gate: SKIP fused-epilogue floor — no gemm_fused_epilogue rows");
    }

    // 3c. SIMD dispatch floor: wherever the autotuned plan elects the
    // vector path, it must hold at least geomean parity with the scalar
    // oracle on the same shapes. The rows exist only when the host
    // exposes a vector ISA, so the block self-skips on plain scalar
    // machines (and on any baseline predating the rows).
    let simd_speedups: Vec<f64> = fresh
        .iter()
        .filter(|row| {
            matches!(
                row.get("kernel").as_str(),
                Some("gemm_simd_vs_scalar") | Some("dwconv_simd_vs_scalar")
            )
        })
        .filter_map(|row| row.get("simd_speedup_vs_scalar").as_f64())
        .collect();
    if let Some(g) = geomean(&simd_speedups) {
        let floor = simd_floor();
        println!(
            "bench_gate: simd-vs-scalar geomean speedup {g:.3} over {} rows (floor {floor})",
            simd_speedups.len()
        );
        if g < floor {
            failures.push(format!(
                "simd-vs-scalar geomean speedup {g:.3} below the {floor} floor \
                 (TT_BENCH_GATE_SIMD_FLOOR)"
            ));
        }
    } else {
        println!(
            "bench_gate: SKIP simd-vs-scalar floor — no gemm_simd_vs_scalar / \
             dwconv_simd_vs_scalar rows"
        );
    }

    // 3d. sub-byte floors. First the unpack-overhead geomean: the packed
    // GEMM (in-kernel unpack + identical u8 body) must hold at least the
    // configured fraction of the plain u8 kernel's speed. Then the model
    // byte ratios: pure packing arithmetic, so the 4-bit and 2-bit
    // storage of every model must land near 1/2 and 1/4 of the 8-bit
    // bytes (slack covers per-tensor ceil rounding). Both self-skip when
    // a run (or an old baseline) predates the rows.
    let subbyte_speeds: Vec<f64> = fresh
        .iter()
        .filter(|row| row.get("kernel").as_str() == Some("subbyte_unpack_overhead"))
        .filter_map(|row| row.get("packed_relative_speed").as_f64())
        .collect();
    if let Some(g) = geomean(&subbyte_speeds) {
        let floor = subbyte_floor();
        println!(
            "bench_gate: sub-byte packed-gemm geomean relative speed {g:.3} over {} rows \
             (floor {floor})",
            subbyte_speeds.len()
        );
        if g < floor {
            failures.push(format!(
                "sub-byte packed-gemm geomean relative speed {g:.3} below the {floor} floor \
                 (TT_BENCH_GATE_SUBBYTE_FLOOR)"
            ));
        }
    } else {
        println!("bench_gate: SKIP sub-byte unpack floor — no subbyte_unpack_overhead rows");
    }
    let byte_rows: Vec<&Json> = fresh
        .iter()
        .filter(|row| row.get("kernel").as_str() == Some("subbyte_model_bytes"))
        .collect();
    if byte_rows.is_empty() {
        println!("bench_gate: SKIP sub-byte packing ceilings — no subbyte_model_bytes rows");
    }
    for row in byte_rows {
        let model = row.get("model").as_str().unwrap_or("?");
        for (field, ceiling) in [("w4_ratio", 0.6), ("w2_ratio", 0.35)] {
            if let Some(ratio) = row.get(field).as_f64() {
                println!(
                    "bench_gate: sub-byte bytes {model}: {field} {ratio:.3} (ceiling {ceiling})"
                );
                if ratio > ceiling {
                    failures.push(format!(
                        "subbyte_model_bytes model={model}: {field} {ratio:.3} above the \
                         {ceiling} packing ceiling"
                    ));
                }
            }
        }
    }

    // 3b. fleet per-tenant-overhead floor: at ≥ 100 tenants the shared
    // deployment must actually be shared — per-tenant memory is session
    // deltas + replay, so N independent full deployments have to cost a
    // healthy multiple of the fleet. Byte accounting, no wall clock.
    let fleet_ratios: Vec<(f64, f64)> = fresh
        .iter()
        .filter(|row| row.get("kernel").as_str() == Some("fleet_session"))
        .filter_map(|row| {
            let tenants = row.get("tenants").as_f64()?;
            let ratio = row.get("memory_ratio_vs_independent").as_f64()?;
            (tenants >= 100.0).then_some((tenants, ratio))
        })
        .collect();
    if fleet_ratios.is_empty() {
        println!(
            "bench_gate: SKIP fleet sharing floor — no fleet_session rows with >= 100 tenants"
        );
    } else {
        let floor = fleet_floor();
        for &(tenants, ratio) in &fleet_ratios {
            println!(
                "bench_gate: fleet {tenants:.0} tenants — memory ratio {ratio:.3} vs \
                 independent (floor {floor})"
            );
            if ratio < floor {
                failures.push(format!(
                    "fleet_session tenants={tenants:.0}: memory_ratio_vs_independent \
                     {ratio:.3} below the {floor} floor (TT_BENCH_GATE_FLEET_FLOOR)"
                ));
            }
        }
    }

    // 4. baseline diff, when a baseline exists.
    match load_rows(baseline_path) {
        Err(e) => {
            println!(
                "bench_gate: no usable baseline ({e}); internal checks only.\n\
                 To seed the gate, run the quick-mode bench (TT_PERF_REPS=3 TT_PERF_BATCH=4 \
                 TT_WORKERS=2 cargo bench --bench perf_kernels) and commit the repo-root \
                 BENCH_kernels.json."
            );
        }
        Ok(base_rows) => {
            let mut base: std::collections::BTreeMap<String, &Json> =
                std::collections::BTreeMap::new();
            for row in &base_rows {
                base.insert(row_key(row), row);
            }
            let mut compared = 0usize;
            let mut unmatched = 0usize;
            let mut seen: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
            for row in &fresh {
                let key = row_key(row);
                seen.insert(key.clone());
                let Some(brow) = base.get(&key) else {
                    unmatched += 1;
                    continue;
                };
                let Some(obj) = row.as_obj() else { continue };
                for (name, v) in obj {
                    let (Some(f), Some(b)) = (v.as_f64(), brow.get(name).as_f64()) else {
                        continue;
                    };
                    if name.contains("seconds") && compare_abs && b > 1e-7 && f > b * tol {
                        failures.push(format!(
                            "[{key}] {name} regressed {f:.3e}s vs baseline {b:.3e}s (> {tol}x)"
                        ));
                        compared += 1;
                    } else if name.contains("seconds") {
                        compared += 1;
                    }
                    if name.contains("speedup") && f < b / tol {
                        failures.push(format!(
                            "[{key}] {name} fell to {f:.3} vs baseline {b:.3} (> {tol}x drop)"
                        ));
                    }
                }
            }
            println!(
                "bench_gate: compared {compared} timing fields against {} baseline rows \
                 ({unmatched} fresh rows without a baseline counterpart)",
                base_rows.len()
            );
            // Baseline rows the fresh run no longer produces: reported so
            // silently dropped coverage is visible, but not a failure —
            // the bench legitimately reshuffles across PRs (refresh the
            // baseline to clear the notice).
            for key in base.keys() {
                if !seen.contains(key) {
                    println!("bench_gate: note — baseline row [{key}] missing from fresh run");
                }
            }
        }
    }

    if failures.is_empty() {
        println!("bench_gate: PASS");
        ExitCode::SUCCESS
    } else {
        eprintln!("bench_gate: FAIL — {} regression(s):", failures.len());
        for f in &failures {
            eprintln!("  {f}");
        }
        eprintln!(
            "If this is expected (bench reshuffle, intentional trade-off), refresh the \
             baseline: re-run the quick-mode bench and commit BENCH_kernels.json; to loosen \
             or tighten the gate set TT_BENCH_GATE_TOL (current: {tol})."
        );
        ExitCode::FAILURE
    }
}
