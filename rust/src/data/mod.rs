//! Synthetic dataset substrates.
//!
//! The paper evaluates on 11 public datasets (Tab. I, Tab. III) plus the 8
//! MCUNet transfer-learning datasets of Tab. IV. None are redistributable
//! inside this offline harness, so each is replaced by a *class-conditional
//! generator* matched in class count, input shape, and modality
//! (DESIGN.md §7). The generators exercise the identical code paths —
//! shapes, memory plan, layer schedule, quantized numerics — and preserve
//! the orderings the paper's claims rest on (fp32 ≥ mixed ≥ uint8, etc.),
//! which are properties of the optimizer rather than of the data.
//!
//! Vision: each class owns a smooth random prototype (low-resolution grid
//! bilinearly upsampled); samples are the prototype plus pixel noise and a
//! global brightness jitter. Time series: each class owns a mixture of
//! sinusoids with class-specific frequencies/phases; samples add noise.
//!
//! Transfer learning needs *two related domains*: a source domain for
//! pretraining and a shifted target domain for on-device retraining. The
//! target's prototypes are a blend of the source prototypes with fresh
//! patterns (`DOMAIN_SHIFT` fraction new), emulating the distribution shift
//! of e.g. ImageNet → flowers.

use crate::tensor::TensorF32;
use crate::train::loop_::Split;
use crate::util::prng::Pcg32;

/// Modality of a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Vision,
    TimeSeries,
}

/// One dataset of the evaluation, with both the paper's native shape (used
/// for memory/latency analysis) and the reduced shape used for the
/// accuracy simulations (DESIGN.md §7: the two are decoupled — memory and
/// latency come from the analytic planner/cost model at full shape).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub classes: usize,
    pub paper_shape: [usize; 3],
    pub reduced_shape: [usize; 3],
    pub kind: Kind,
}

impl DatasetSpec {
    const fn vision(
        name: &'static str,
        classes: usize,
        paper: [usize; 3],
        reduced: [usize; 3],
    ) -> DatasetSpec {
        DatasetSpec {
            name,
            classes,
            paper_shape: paper,
            reduced_shape: reduced,
            kind: Kind::Vision,
        }
    }

    const fn ts(name: &'static str, classes: usize, len: usize, reduced: usize) -> DatasetSpec {
        DatasetSpec {
            name,
            classes,
            paper_shape: [1, 1, len],
            reduced_shape: [1, 1, reduced],
            kind: Kind::TimeSeries,
        }
    }
}

/// Tab. I — the seven transfer-learning datasets.
pub fn transfer_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::ts("cwru", 9, 512, 512),
        DatasetSpec::ts("daliac", 13, 1024, 1024),
        DatasetSpec::ts("speech", 36, 16000, 2048),
        DatasetSpec::vision("animals", 10, [3, 128, 128], [3, 32, 32]),
        DatasetSpec::vision("cifar10", 10, [3, 32, 32], [3, 32, 32]),
        DatasetSpec::vision("cifar100", 100, [3, 32, 32], [3, 32, 32]),
        DatasetSpec::vision("flowers", 102, [3, 128, 128], [3, 32, 32]),
    ]
}

/// Tab. III — the four full-on-device-training datasets.
pub fn full_training_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::vision("fmnist", 10, [1, 28, 28], [1, 28, 28]),
        DatasetSpec::vision("kmnist", 10, [1, 28, 28], [1, 28, 28]),
        DatasetSpec::vision("emnist-letters", 26, [1, 28, 28], [1, 28, 28]),
        DatasetSpec::vision("emnist-digits", 10, [1, 28, 28], [1, 28, 28]),
    ]
}

/// Tab. IV — the eight MCUNet transfer-learning comparison datasets.
pub fn mcunet_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::vision("cars", 196, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("cf10", 10, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("cf100", 100, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("cub", 200, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("flowers", 102, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("food", 101, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("pets", 37, [3, 160, 160], [3, 32, 32]),
        DatasetSpec::vision("vww", 2, [3, 160, 160], [3, 32, 32]),
    ]
}

/// Find a spec by name across all registries.
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    transfer_specs()
        .into_iter()
        .chain(full_training_specs())
        .chain(mcunet_specs())
        .find(|s| s.name == name)
}

/// Fraction of the target-domain prototype replaced by fresh patterns when
/// deriving a transfer-learning target domain from a source domain.
pub const DOMAIN_SHIFT: f32 = 0.45;

/// The class prototypes of one domain.
pub struct Domain {
    pub spec: DatasetSpec,
    pub shape: [usize; 3],
    protos: Vec<TensorF32>,
}

impl Domain {
    /// Fresh domain from a seed.
    pub fn new(spec: &DatasetSpec, shape: [usize; 3], seed: u64) -> Domain {
        let mut rng = Pcg32::new(seed, 0xD0);
        let protos = (0..spec.classes).map(|_| prototype(spec.kind, &shape, &mut rng)).collect();
        Domain { spec: spec.clone(), shape, protos }
    }

    /// Shifted domain: blend of this domain's prototypes with fresh ones
    /// (transfer-learning target).
    pub fn shifted(&self, seed: u64) -> Domain {
        let mut rng = Pcg32::new(seed, 0xD1);
        let protos = self
            .protos
            .iter()
            .map(|p| {
                let fresh = prototype(self.spec.kind, &self.shape, &mut rng);
                let mut blend = p.clone();
                for (b, f) in blend.data_mut().iter_mut().zip(fresh.data()) {
                    *b = (1.0 - DOMAIN_SHIFT) * *b + DOMAIN_SHIFT * f;
                }
                blend
            })
            .collect();
        Domain { spec: self.spec.clone(), shape: self.shape, protos }
    }

    /// Draw one sample of class `y`.
    pub fn sample(&self, y: usize, rng: &mut Pcg32) -> TensorF32 {
        let mut x = self.protos[y].clone();
        let brightness = rng.uniform(-0.15, 0.15);
        let noise = match self.spec.kind {
            Kind::Vision => 0.22,
            Kind::TimeSeries => 0.30,
        };
        for v in x.data_mut().iter_mut() {
            *v += rng.normal() * noise + brightness;
        }
        x
    }

    /// Build class-balanced train/test splits.
    pub fn splits(
        &self,
        per_class_train: usize,
        per_class_test: usize,
        rng: &mut Pcg32,
    ) -> (Split, Split) {
        let mk = |per_class: usize, rng: &mut Pcg32| {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for y in 0..self.spec.classes {
                for _ in 0..per_class {
                    xs.push(self.sample(y, rng));
                    ys.push(y);
                }
            }
            Split { xs, ys }
        };
        (mk(per_class_train, rng), mk(per_class_test, rng))
    }
}

/// Generate a class prototype.
fn prototype(kind: Kind, shape: &[usize; 3], rng: &mut Pcg32) -> TensorF32 {
    match kind {
        Kind::Vision => vision_prototype(shape, rng),
        Kind::TimeSeries => ts_prototype(shape, rng),
    }
}

/// Vision prototype: per-channel low-res grid, bilinearly upsampled — a
/// smooth "shape" the conv stack can actually extract features from.
fn vision_prototype(shape: &[usize; 3], rng: &mut Pcg32) -> TensorF32 {
    let (c, h, w) = (shape[0], shape[1], shape[2]);
    let gh = 5.min(h);
    let gw = 5.min(w);
    let mut out = TensorF32::zeros(&[c, h, w]);
    for ci in 0..c {
        let grid: Vec<f32> = (0..gh * gw).map(|_| rng.normal()).collect();
        for y in 0..h {
            for x in 0..w {
                // bilinear sample of the coarse grid
                let fy = y as f32 / (h.max(2) - 1) as f32 * (gh - 1) as f32;
                let fx = x as f32 / (w.max(2) - 1) as f32 * (gw - 1) as f32;
                let (y0, x0) = (fy as usize, fx as usize);
                let (y1, x1) = ((y0 + 1).min(gh - 1), (x0 + 1).min(gw - 1));
                let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                let v = grid[y0 * gw + x0] * (1.0 - dy) * (1.0 - dx)
                    + grid[y0 * gw + x1] * (1.0 - dy) * dx
                    + grid[y1 * gw + x0] * dy * (1.0 - dx)
                    + grid[y1 * gw + x1] * dy * dx;
                out.data_mut()[(ci * h + y) * w + x] = v;
            }
        }
    }
    out
}

/// Time-series prototype: mixture of 4 sinusoids with class-specific
/// frequencies, amplitudes and phases.
fn ts_prototype(shape: &[usize; 3], rng: &mut Pcg32) -> TensorF32 {
    let n = shape[2];
    let mut out = TensorF32::zeros(&[1, 1, n]);
    for _ in 0..4 {
        let freq = rng.uniform(1.0, 24.0);
        let amp = rng.uniform(0.4, 1.2);
        let phase = rng.uniform(0.0, core::f32::consts::TAU);
        for (t, v) in out.data_mut().iter_mut().enumerate() {
            *v += amp * (core::f32::consts::TAU * freq * t as f32 / n as f32 + phase).sin();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registries_match_paper_tables() {
        let t = transfer_specs();
        assert_eq!(t.len(), 7);
        assert_eq!(t.iter().filter(|s| s.kind == Kind::TimeSeries).count(), 3);
        let cifar100 = t.iter().find(|s| s.name == "cifar100").unwrap();
        assert_eq!(cifar100.classes, 100);
        assert_eq!(cifar100.paper_shape, [3, 32, 32]);

        let f = full_training_specs();
        assert_eq!(f.len(), 4);
        assert!(f.iter().all(|s| s.paper_shape == [1, 28, 28]));
        assert_eq!(f.iter().find(|s| s.name == "emnist-letters").unwrap().classes, 26);

        let m = mcunet_specs();
        assert_eq!(m.len(), 8);
        assert_eq!(m.iter().find(|s| s.name == "cub").unwrap().classes, 200);
    }

    #[test]
    fn spec_lookup() {
        assert!(spec_by_name("speech").is_some());
        assert_eq!(spec_by_name("speech").unwrap().paper_shape, [1, 1, 16000]);
        assert!(spec_by_name("imagenet").is_none());
    }

    #[test]
    fn splits_are_balanced_and_shaped() {
        let spec = spec_by_name("cifar10").unwrap();
        let dom = Domain::new(&spec, spec.reduced_shape, 7);
        let mut rng = Pcg32::seeded(1);
        let (tr, te) = dom.splits(3, 2, &mut rng);
        assert_eq!(tr.len(), 30);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.xs[0].shape(), &[3, 32, 32]);
        for y in 0..10 {
            assert_eq!(tr.ys.iter().filter(|&&v| v == y).count(), 3);
        }
    }

    #[test]
    fn same_seed_same_data() {
        let spec = spec_by_name("cwru").unwrap();
        let d1 = Domain::new(&spec, spec.reduced_shape, 42);
        let d2 = Domain::new(&spec, spec.reduced_shape, 42);
        let mut r1 = Pcg32::seeded(5);
        let mut r2 = Pcg32::seeded(5);
        assert_eq!(d1.sample(0, &mut r1).data(), d2.sample(0, &mut r2).data());
    }

    #[test]
    fn classes_are_separable_from_prototypes() {
        // nearest-prototype classification on clean prototypes must be
        // perfect; on noisy samples, well above chance.
        let spec = spec_by_name("cifar10").unwrap();
        let dom = Domain::new(&spec, [3, 16, 16], 9);
        let mut rng = Pcg32::seeded(2);
        let mut correct = 0;
        let n = 100;
        for i in 0..n {
            let y = i % 10;
            let x = dom.sample(y, &mut rng);
            let mut best = (f32::INFINITY, 0usize);
            for (c, p) in dom.protos.iter().enumerate() {
                let d: f32 = x.data().iter().zip(p.data()).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == y {
                correct += 1;
            }
        }
        assert!(correct > 80, "nearest-prototype acc {correct}/100");
    }

    #[test]
    fn shifted_domain_is_related_but_different() {
        let spec = spec_by_name("cifar10").unwrap();
        let src = Domain::new(&spec, [3, 8, 8], 11);
        let tgt = src.shifted(12);
        // correlation between source and target prototypes must be positive
        // but well below 1
        let (a, b) = (&src.protos[0], &tgt.protos[0]);
        let dot: f32 = a.data().iter().zip(b.data()).map(|(x, y)| x * y).sum();
        let na: f32 = a.data().iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.data().iter().map(|x| x * x).sum::<f32>().sqrt();
        let corr = dot / (na * nb);
        assert!(corr > 0.2 && corr < 0.95, "corr={corr}");
    }

    #[test]
    fn time_series_shape_and_variety() {
        let spec = spec_by_name("daliac").unwrap();
        let dom = Domain::new(&spec, spec.reduced_shape, 3);
        let mut rng = Pcg32::seeded(4);
        let x = dom.sample(5, &mut rng);
        assert_eq!(x.shape(), &[1, 1, 1024]);
        // different classes differ substantially
        let x2 = dom.sample(6, &mut rng);
        let diff: f32 = x.data().iter().zip(x2.data()).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff / x.len() as f32 > 0.3);
    }
}
