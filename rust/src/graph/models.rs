//! The three architectures of the evaluation.
//!
//! All support both image inputs `[C,H,W]` and time-series inputs mapped to
//! one spatial dimension `[1,1,L]` (the paper maps the time dimension of a
//! sample onto a spatial input dimension, §IV-A), because the builder emits
//! 1×k kernels whenever the running height is 1.

use crate::graph::{ModelBuilder, ModelDef};

/// §IV-D full-training network: 2 convolutional layers, max pooling, and
/// 2 linear layers, ReLU activations, BatchNorm folded (Fig. 2b). Sized so
/// the uint8 configuration fits the RAM of all three MCUs of Tab. II.
pub fn mnist_cnn(input_shape: &[usize], num_classes: usize) -> ModelDef {
    let mut b = ModelBuilder::new("mnist_cnn", input_shape, num_classes);
    b.conv(16, 3, 2, true) // 28x28 -> 14x14
        .conv(32, 3, 2, true) // -> 7x7
        .maxpool(2) // -> 3x3
        .flatten()
        .linear(64, true)
        .linear(num_classes, false);
    let mut m = b.build();
    m.set_all_trainable();
    m
}

/// *MbedNet* (§IV-A): MobileNetV3-style depthwise-separable stack scaled
/// down for MCUs. The design property the paper leans on is **expensive
/// early layers, compact final layers** — feature extraction front-loads
/// the compute so the trainable tail is cheap to update (Figs. 4b, 9).
pub fn mbednet(input_shape: &[usize], num_classes: usize) -> ModelDef {
    let mut b = ModelBuilder::new("mbednet", input_shape, num_classes);
    b.conv(16, 3, 2, true); // stem
    b.dwconv(3, 1, true).pwconv(24, true);
    b.dwconv(3, 2, true).pwconv(32, true);
    b.dwconv(3, 1, true).pwconv(32, true);
    b.dwconv(3, 2, true).pwconv(48, true);
    b.dwconv(3, 1, true).pwconv(64, true);
    b.gap();
    b.linear(96, true);
    b.linear(num_classes, false);
    let mut m = b.build();
    // Transfer-learning default: retrain the last five weighted layers
    // (§IV-A resets exactly those to random before on-device training).
    m.set_trainable_tail(5);
    m
}

/// MCUNet-5FPS stand-in (Tab. IV / Fig. 9 comparator), matched to the
/// paper's reported backbone budget (~23 M MACs, ~0.48 M params at
/// 160×160×3) with deliberately *large final blocks* — the property that
/// makes it more expensive than MbedNet to retrain on-device.
pub fn mcunet5fps(input_shape: &[usize], num_classes: usize) -> ModelDef {
    let mut b = ModelBuilder::new("mcunet5fps", input_shape, num_classes);
    b.conv(16, 3, 2, true); // stem
    b.dwconv(3, 1, true).pwconv(24, true);
    b.dwconv(3, 2, true).pwconv(40, true);
    b.dwconv(3, 1, true).pwconv(40, true);
    b.dwconv(3, 2, true).pwconv(80, true);
    b.dwconv(3, 1, true).pwconv(80, true);
    b.dwconv(3, 2, true).pwconv(96, true);
    b.dwconv(3, 1, true).pwconv(160, true);
    b.dwconv(3, 2, true).pwconv(480, true);
    b.pwconv(768, true); // wide head conv — the "large final layers"
    b.gap();
    b.linear(num_classes, false);
    let mut m = b.build();
    // "updating the last two blocks" (Tab. IV): the final dw+pw block, the
    // head conv, and the classifier.
    m.set_trainable_tail(5);
    m
}

/// Look a model up by name (CLI / config entry point).
pub fn by_name(name: &str, input_shape: &[usize], num_classes: usize) -> Option<ModelDef> {
    match name {
        "mnist_cnn" => Some(mnist_cnn(input_shape, num_classes)),
        "mbednet" => Some(mbednet(input_shape, num_classes)),
        "mcunet5fps" => Some(mcunet5fps(input_shape, num_classes)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_cnn_is_paper_shape() {
        let m = mnist_cnn(&[1, 28, 28], 10);
        // 2 conv + pool + flatten + 2 linear
        assert_eq!(m.layers.len(), 6);
        assert_eq!(m.shapes().last().unwrap(), &vec![10]);
        // all weighted layers trainable (full on-device training)
        assert_eq!(m.first_trainable(), Some(0));
        // must fit tight MCU RAM in uint8: weights under 64 KB
        assert!(m.total_params() < 64 * 1024, "params={}", m.total_params());
    }

    #[test]
    fn mbednet_has_compact_tail() {
        let m = mbednet(&[3, 32, 32], 10);
        assert_eq!(m.shapes().last().unwrap(), &vec![10]);
        let params = m.params_per_layer();
        let macs = m.fwd_macs_per_layer();
        // early layers dominate compute
        let first_half: u64 = macs[..macs.len() / 2].iter().sum();
        let second_half: u64 = macs[macs.len() / 2..].iter().sum();
        assert!(first_half > second_half, "{first_half} vs {second_half}");
        // trainable tail is small relative to the model
        let trainable: usize = m
            .layers
            .iter()
            .zip(&params)
            .filter(|(l, _)| l.trainable)
            .map(|(_, p)| *p)
            .sum();
        assert!(trainable * 2 < m.total_params() * 3, "tail too heavy");
    }

    #[test]
    fn mbednet_supports_time_series() {
        let m = mbednet(&[1, 1, 512], 9); // cwru shape
        assert_eq!(m.shapes().last().unwrap(), &vec![9]);
        let m2 = mbednet(&[1, 1, 1024], 13); // daliac shape
        assert_eq!(m2.shapes().last().unwrap(), &vec![13]);
    }

    #[test]
    fn mcunet_matches_paper_budget() {
        let m = mcunet5fps(&[3, 160, 160], 10);
        let params = m.total_params();
        let macs = m.total_fwd_macs();
        // paper: 23M MACs, 0.48M params — allow a generous band for the
        // stand-in (DESIGN.md §7)
        assert!((300_000..700_000).contains(&params), "params={params}");
        assert!((15_000_000..35_000_000).contains(&macs), "macs={macs}");
    }

    #[test]
    fn mcunet_tail_heavier_than_mbednet_tail() {
        // Fig. 9's premise: MCUNet's trainable tail costs more than
        // MbedNet's, in both parameters and backward MACs.
        let mb = mbednet(&[3, 32, 32], 10);
        let mc = mcunet5fps(&[3, 32, 32], 10);
        let tail = |m: &ModelDef| -> usize {
            m.layers
                .iter()
                .zip(m.params_per_layer())
                .filter(|(l, _)| l.trainable)
                .map(|(_, p)| p)
                .sum()
        };
        assert!(tail(&mc) > 3 * tail(&mb), "mcunet={} mbednet={}", tail(&mc), tail(&mb));
    }

    #[test]
    fn by_name_dispatch() {
        assert!(by_name("mbednet", &[3, 32, 32], 10).is_some());
        assert!(by_name("nope", &[3, 32, 32], 10).is_none());
    }
}
