//! Native model executor: the MCU-faithful forward and backward passes.
//!
//! This is the Rust port of what the paper's C framework runs on-device.
//! A [`NativeModel`] owns the deployed state exactly as the MCU would hold
//! it: quantized weight tensors (uint8 + per-tensor params) for quantized
//! layers, float weights for float layers, fixed activation quantization
//! parameters from PTQ calibration, and online min/max observers for the
//! backpropagated error tensors (see `quant::observer`).
//!
//! The forward pass doubles as inference (the paper's in-place property:
//! the same representation serves both, §III-A); the backward pass
//! implements Eqs. 1–4 with optional per-structure masks from the dynamic
//! sparse update controller (§III-B).

use crate::graph::{DnnConfig, LayerDef, LayerKind, ModelDef, Precision};
use crate::kernels::{fconv, flinear, kept_count, pool, qconv, qlinear, softmax, OpCounter};
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::quant::{quantize_bias, QParams, QTensor};
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

/// An activation value flowing through the graph — quantized or float
/// depending on the layer precision (mixed configurations cross the
/// boundary exactly once, after the last conv).
#[derive(Clone, Debug)]
pub enum Act {
    Q(QTensor),
    F(TensorF32),
}

impl Act {
    pub fn shape(&self) -> &[usize] {
        match self {
            Act::Q(t) => t.shape(),
            Act::F(t) => t.shape(),
        }
    }

    pub fn to_float(&self) -> TensorF32 {
        match self {
            Act::Q(t) => t.dequantize(),
            Act::F(t) => t.clone(),
        }
    }

    fn reshaped(&self, shape: &[usize]) -> Act {
        match self {
            Act::Q(t) => Act::Q(QTensor { values: t.values.reshape(shape), qp: t.qp }),
            Act::F(t) => Act::F(t.reshape(shape)),
        }
    }

    /// Bytes this activation occupies in the on-device arena.
    pub fn byte_size(&self) -> usize {
        match self {
            Act::Q(t) => t.len(),
            Act::F(t) => t.len() * 4,
        }
    }
}

/// Deployed per-layer parameters. The float bias master is kept for both
/// flavors: quantized kernels consume it re-quantized to i32 at the current
/// input/weight scales (cheap, `Cout` values), and the bias SGD step runs
/// in float either way.
#[derive(Clone, Debug)]
pub enum LayerParams {
    Q { w: QTensor, bias: Vec<f32> },
    F { w: TensorF32, bias: Vec<f32> },
    None,
}

impl LayerParams {
    pub fn byte_size(&self) -> usize {
        match self {
            LayerParams::Q { w, bias } => w.len() + bias.len() * 4,
            LayerParams::F { w, bias } => (w.len() + bias.len()) * 4,
            LayerParams::None => 0,
        }
    }

    /// Human-readable parameter flavor, for mismatch diagnostics.
    pub fn flavor(&self) -> &'static str {
        match self {
            LayerParams::Q { .. } => "quantized (uint8)",
            LayerParams::F { .. } => "float32",
            LayerParams::None => "none",
        }
    }
}

/// Float master weights used before deployment (pretraining on the source
/// domain and PTQ calibration both run on these).
#[derive(Clone, Debug)]
pub struct FloatParams {
    /// `(weights, bias)` for weighted layers; `None` for pools etc.
    pub layers: Vec<Option<(TensorF32, Vec<f32>)>>,
}

impl FloatParams {
    /// He-initialized random parameters.
    pub fn init(def: &ModelDef, rng: &mut Pcg32) -> FloatParams {
        let layers = def.layers.iter().map(|l| init_layer(l, rng)).collect();
        FloatParams { layers }
    }
}

fn init_layer(l: &LayerDef, rng: &mut Pcg32) -> Option<(TensorF32, Vec<f32>)> {
    match &l.kind {
        LayerKind::Conv { geom, .. } => {
            let cf = if geom.depthwise { 1 } else { geom.cin };
            let fan_in = (cf * geom.kh * geom.kw) as f32;
            let std = (2.0 / fan_in).sqrt();
            let mut w = TensorF32::zeros(&[geom.cout, cf, geom.kh, geom.kw]);
            rng.fill_normal(w.data_mut(), std);
            Some((w, vec![0.0; geom.cout]))
        }
        LayerKind::Linear { n_in, n_out, .. } => {
            let std = (2.0 / *n_in as f32).sqrt();
            let mut w = TensorF32::zeros(&[*n_out, *n_in]);
            rng.fill_normal(w.data_mut(), std);
            Some((w, vec![0.0; *n_out]))
        }
        _ => None,
    }
}

/// PTQ calibration result: input range plus per-layer activation ranges.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub input_qp: QParams,
    pub act_qp: Vec<QParams>,
}

/// Run `samples` through the float model and record every layer's output
/// range (post-training quantization calibration).
pub fn calibrate(def: &ModelDef, fp: &FloatParams, samples: &[TensorF32]) -> Calibration {
    let mut in_obs = MinMaxObserver::calibration();
    let mut obs: Vec<MinMaxObserver> =
        def.layers.iter().map(|_| MinMaxObserver::calibration()).collect();
    let mut ops = OpCounter::new();
    for x in samples {
        in_obs.observe(x.data());
        let mut cur = x.clone();
        for (i, l) in def.layers.iter().enumerate() {
            cur = float_layer_fwd(l, &cur, fp.layers[i].as_ref(), &mut ops).0;
            obs[i].observe(cur.data());
        }
    }
    Calibration { input_qp: in_obs.qparams(), act_qp: obs.iter().map(|o| o.qparams()).collect() }
}

fn float_layer_fwd(
    l: &LayerDef,
    x: &TensorF32,
    p: Option<&(TensorF32, Vec<f32>)>,
    ops: &mut OpCounter,
) -> (TensorF32, Option<Vec<u32>>) {
    match &l.kind {
        LayerKind::Conv { geom, relu } => {
            let (w, b) = p.expect("conv params");
            (fconv::fconv2d_fwd(x, w, b, geom, *relu, ops), None)
        }
        LayerKind::Linear { relu, .. } => {
            let (w, b) = p.expect("linear params");
            (flinear::flinear_fwd(x, w, b, *relu, ops), None)
        }
        LayerKind::MaxPool { k } => {
            let o = pool::fmaxpool_fwd(x, *k, ops);
            (o.y, Some(o.argmax))
        }
        LayerKind::GlobalAvgPool => (pool::fgap_fwd(x, ops), None),
        LayerKind::Flatten => (x.reshape(&[x.len()]), None),
    }
}

/// Saved forward-pass state needed by backprop (the data dependencies of
/// Fig. 1: layer inputs, post-activation outputs, pool argmaxes).
pub struct FwdTrace {
    pub input: Act,
    pub acts: Vec<Act>,
    pub argmax: Vec<Option<Vec<u32>>>,
    pub logits: Vec<f32>,
}

/// Per-layer gradient output of one backward pass.
pub struct LayerGrads {
    pub gw: TensorF32,
    pub gb: TensorF32,
    /// (kept structures, total structures) under the sparse mask.
    pub kept: (usize, usize),
}

/// Result of one backward pass.
pub struct BwdResult {
    /// Aligned with `def.layers`; `Some` only for trainable layers.
    pub grads: Vec<Option<LayerGrads>>,
}

/// Result of one batched training pass ([`NativeModel::train_batch`]):
/// per-sample outputs in sample order plus fwd/bwd op totals.
pub struct BatchResult {
    pub losses: Vec<f32>,
    pub preds: Vec<usize>,
    /// Per-sample gradients, in sample order. Feed them to the optimizer in
    /// this order — gradient accumulation then stays bit-identical to the
    /// one-worker path regardless of how samples were sharded.
    pub grads: Vec<BwdResult>,
    pub fwd_ops: OpCounter,
    pub bwd_ops: OpCounter,
}

/// One sample's worth of work inside a batch (worker-side record; merged
/// deterministically on the coordinating thread).
struct SamplePass {
    loss: f32,
    pred: usize,
    grads: BwdResult,
    err_obs: Vec<MinMaxObserver>,
    sat: Vec<Option<(usize, usize)>>,
    fwd_ops: OpCounter,
    bwd_ops: OpCounter,
}

/// Mask provider interface implemented by the dynamic sparse update
/// controller (`train::sparse`). `None` = update everything.
pub trait MaskProvider {
    fn mask(&mut self, layer: usize, structure_norms: &[f32]) -> Option<Vec<bool>>;
}

/// The always-dense provider (λ_min = λ_max = 1).
pub struct DenseUpdates;

impl MaskProvider for DenseUpdates {
    fn mask(&mut self, _layer: usize, _norms: &[f32]) -> Option<Vec<bool>> {
        None
    }
}

/// A deployed model: the exact state the MCU holds in RAM/Flash.
pub struct NativeModel {
    pub def: ModelDef,
    pub cfg: DnnConfig,
    pub prec: Vec<Precision>,
    pub params: Vec<LayerParams>,
    pub input_qp: QParams,
    pub act_qp: Vec<QParams>,
    pub err_obs: Vec<MinMaxObserver>,
}

impl NativeModel {
    /// Deploy: quantize float master weights per the configuration, using
    /// PTQ calibration ranges for activations.
    pub fn build(def: ModelDef, cfg: DnnConfig, fp: &FloatParams, calib: &Calibration) -> Self {
        let prec = def.precisions(cfg);
        let params = def
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| match (&fp.layers[i], prec[i]) {
                (Some((w, b)), Precision::Uint8) if l.has_weights() => {
                    LayerParams::Q { w: QTensor::quantize(w), bias: b.clone() }
                }
                (Some((w, b)), _) if l.has_weights() => {
                    LayerParams::F { w: w.clone(), bias: b.clone() }
                }
                _ => LayerParams::None,
            })
            .collect();
        let err_obs = def.layers.iter().map(|_| MinMaxObserver::online()).collect();
        NativeModel {
            prec,
            params,
            input_qp: calib.input_qp,
            act_qp: calib.act_qp.clone(),
            err_obs,
            def,
            cfg,
        }
    }

    /// Re-randomize the trainable layers (§IV-A: "we set the last five
    /// layers of each DNN to random values, thereby resetting its
    /// classification capabilities").
    pub fn reset_trainable(&mut self, rng: &mut Pcg32) {
        for i in 0..self.def.layers.len() {
            if !self.def.layers[i].trainable {
                continue;
            }
            if let Some((w, b)) = init_layer(&self.def.layers[i], rng) {
                self.params[i] = match self.prec[i] {
                    Precision::Uint8 => LayerParams::Q { w: QTensor::quantize(&w), bias: b },
                    Precision::Float32 => LayerParams::F { w, bias: b },
                };
            }
        }
    }

    /// Extract float masters (only valid for `Float32` models; used to pull
    /// pretrained weights out for deployment under other configs).
    pub fn to_float_params(&self) -> FloatParams {
        let layers = self
            .params
            .iter()
            .map(|p| match p {
                LayerParams::F { w, bias } => Some((w.clone(), bias.clone())),
                LayerParams::Q { w, bias } => Some((w.dequantize(), bias.clone())),
                LayerParams::None => None,
            })
            .collect();
        FloatParams { layers }
    }

    /// Quantization parameters of the input to layer `i`.
    fn in_qp(&self, i: usize) -> QParams {
        if i == 0 {
            self.input_qp
        } else {
            // pools/flatten pass qparams through
            let mut j = i;
            while j > 0 {
                j -= 1;
                match self.def.layers[j].kind {
                    LayerKind::Conv { .. }
                    | LayerKind::Linear { .. }
                    | LayerKind::GlobalAvgPool => {
                        return self.act_qp[j];
                    }
                    _ => {}
                }
            }
            self.input_qp
        }
    }

    /// Forward pass for one sample. Works for plain inference too (drop the
    /// trace): the paper's zero-downtime property — training shares the
    /// inference representation byte-for-byte.
    ///
    /// Convenience wrapper over [`NativeModel::forward_in`] with a
    /// throwaway scratch arena; hot loops (the trainer, the batch engine)
    /// should hold a [`Scratch`] and call `forward_in` directly.
    pub fn forward(&self, x: &TensorF32, ops: &mut OpCounter) -> FwdTrace {
        self.forward_in(x, &mut Scratch::new(), ops)
    }

    /// Forward pass with an explicit scratch arena. Non-depthwise convs are
    /// routed through the im2col/GEMM engine (`kernels::gemm`), which is
    /// bit-exact with the scalar reference kernels; depthwise convs,
    /// linears and pools use the MCU-faithful kernels directly.
    pub fn forward_in(
        &self,
        x: &TensorF32,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> FwdTrace {
        let n = self.def.layers.len();
        let mut acts: Vec<Act> = Vec::with_capacity(n);
        let mut argmax: Vec<Option<Vec<u32>>> = vec![None; n];

        let input = match self.prec[0] {
            Precision::Uint8 => Act::Q(QTensor::quantize_with(x, self.input_qp)),
            Precision::Float32 => Act::F(x.clone()),
        };

        let mut cur = input.clone();
        for (i, l) in self.def.layers.iter().enumerate() {
            // coerce the running activation into this layer's precision
            cur = match (self.prec[i], cur) {
                (Precision::Uint8, Act::F(t)) => {
                    Act::Q(QTensor::quantize_with(&t, self.in_qp(i)))
                }
                (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
                (_, c) => c,
            };
            cur = match (&l.kind, &cur) {
                (LayerKind::Conv { geom, relu }, Act::Q(xq)) => {
                    let (w, bias) = match &self.params[i] {
                        LayerParams::Q { w, bias } => (w, bias),
                        other => panic!(
                            "layer {i} ({}): expected quantized (uint8) conv params, found {}",
                            l.name,
                            other.flavor()
                        ),
                    };
                    let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                    let y = if geom.depthwise {
                        qconv::qconv2d_fwd(xq, w, &bq, geom, self.act_qp[i], *relu, ops)
                    } else {
                        qconv::qconv2d_fwd_gemm(
                            xq,
                            w,
                            &bq,
                            geom,
                            self.act_qp[i],
                            *relu,
                            scratch,
                            ops,
                        )
                    };
                    Act::Q(y)
                }
                (LayerKind::Conv { geom, relu }, Act::F(xf)) => {
                    let (w, bias) = match &self.params[i] {
                        LayerParams::F { w, bias } => (w, bias),
                        other => panic!(
                            "layer {i} ({}): expected float32 conv params, found {}",
                            l.name,
                            other.flavor()
                        ),
                    };
                    let y = if geom.depthwise {
                        fconv::fconv2d_fwd(xf, w, bias, geom, *relu, ops)
                    } else {
                        fconv::fconv2d_fwd_gemm(xf, w, bias, geom, *relu, scratch, ops)
                    };
                    Act::F(y)
                }
                (LayerKind::Linear { relu, .. }, Act::Q(xq)) => {
                    let (w, bias) = match &self.params[i] {
                        LayerParams::Q { w, bias } => (w, bias),
                        other => panic!(
                            "layer {i} ({}): expected quantized (uint8) linear params, found {}",
                            l.name,
                            other.flavor()
                        ),
                    };
                    let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                    Act::Q(qlinear::qlinear_fwd(xq, w, &bq, self.act_qp[i], *relu, ops))
                }
                (LayerKind::Linear { relu, .. }, Act::F(xf)) => {
                    let (w, bias) = match &self.params[i] {
                        LayerParams::F { w, bias } => (w, bias),
                        other => panic!(
                            "layer {i} ({}): expected float32 linear params, found {}",
                            l.name,
                            other.flavor()
                        ),
                    };
                    Act::F(flinear::flinear_fwd(xf, w, bias, *relu, ops))
                }
                (LayerKind::MaxPool { k }, Act::Q(xq)) => {
                    let o = pool::qmaxpool_fwd(xq, *k, ops);
                    argmax[i] = Some(o.argmax);
                    Act::Q(o.y)
                }
                (LayerKind::MaxPool { k }, Act::F(xf)) => {
                    let o = pool::fmaxpool_fwd(xf, *k, ops);
                    argmax[i] = Some(o.argmax);
                    Act::F(o.y)
                }
                (LayerKind::GlobalAvgPool, Act::Q(xq)) => {
                    Act::Q(pool::qgap_fwd(xq, self.act_qp[i], ops))
                }
                (LayerKind::GlobalAvgPool, Act::F(xf)) => Act::F(pool::fgap_fwd(xf, ops)),
                (LayerKind::Flatten, a) => {
                    let flat: usize = a.shape().iter().product();
                    a.reshaped(&[flat])
                }
            };
            acts.push(cur.clone());
        }

        let logits = acts.last().unwrap().to_float().into_vec();
        FwdTrace { input, acts, argmax, logits }
    }

    /// Training-path forward: run the regular forward pass, then let the
    /// activation ranges of *trainable* quantized layers follow the drifting
    /// activation distribution. Training moves weight distributions (which
    /// Eqs. 5–7 track), which in turn moves the activations they produce;
    /// with ranges frozen at PTQ calibration the logits saturate and
    /// training stalls — the failure mode the paper attributes to "the
    /// quantization of tensors in the last layers" (§IV-A). The adaptation
    /// rule mirrors Eqs. 6–7: when >1 % of a trainable layer's output
    /// saturates the uint8 range, widen its range 25 % (upper end only for
    /// folded-ReLU layers, whose lower bound is pinned at the zero point).
    pub fn forward_adapt(&mut self, x: &TensorF32, ops: &mut OpCounter) -> FwdTrace {
        self.forward_adapt_in(x, &mut Scratch::new(), ops)
    }

    /// [`NativeModel::forward_adapt`] with an explicit scratch arena.
    pub fn forward_adapt_in(
        &mut self,
        x: &TensorF32,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> FwdTrace {
        let trace = self.forward_in(x, scratch, ops);
        let sat = self.measure_saturation(&trace, ops);
        self.apply_range_adaptation(&sat);
        trace
    }

    /// Per-layer saturation telemetry of one forward trace: for each
    /// *trainable, quantized* layer, the number of output values clipped at
    /// the uint8 range (upper end only for folded-ReLU layers, whose lower
    /// bound is pinned at the zero point) and the output element count.
    /// `None` for layers the adaptation rule does not apply to.
    fn measure_saturation(
        &self,
        trace: &FwdTrace,
        ops: &mut OpCounter,
    ) -> Vec<Option<(usize, usize)>> {
        self.def
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if !l.trainable || self.prec[i] != Precision::Uint8 {
                    return None;
                }
                let relu = matches!(
                    l.kind,
                    LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
                );
                match &trace.acts[i] {
                    Act::Q(t) => {
                        let n = t.len().max(1);
                        let sat_hi = t.values.data().iter().filter(|&&v| v == 255).count();
                        let sat_lo = if relu {
                            0
                        } else {
                            t.values.data().iter().filter(|&&v| v == 0).count()
                        };
                        ops.int_ops += n as u64;
                        Some((sat_hi + sat_lo, n))
                    }
                    Act::F(_) => None,
                }
            })
            .collect()
    }

    /// Apply the Eqs. 6–7-style range widening for saturation telemetry
    /// gathered by [`NativeModel::measure_saturation`]: when >1 % of a
    /// layer's output saturates, widen its range 25 %. Split from the
    /// measurement so the batch engine can collect telemetry concurrently
    /// and fold it in deterministically, in sample order.
    fn apply_range_adaptation(&mut self, sat: &[Option<(usize, usize)>]) {
        for (i, s) in sat.iter().enumerate() {
            let Some(&(sat, n)) = s.as_ref() else { continue };
            if sat * 100 > n {
                let relu = matches!(
                    self.def.layers[i].kind,
                    LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
                );
                let qp = self.act_qp[i];
                let lo = (0 - qp.zero_point) as f32 * qp.scale;
                let hi = (255 - qp.zero_point) as f32 * qp.scale;
                let (nlo, nhi) = if relu {
                    (lo, hi * 1.25)
                } else {
                    let span = hi - lo;
                    (lo - 0.25 * span, hi + 0.25 * span)
                };
                self.act_qp[i] = QParams::from_min_max(nlo, nhi);
            }
        }
    }

    /// One full training-sample pass: forward (with activation-range
    /// adaptation), loss, backward. Returns the loss, the predicted class
    /// and the per-layer gradients. One scratch arena serves both passes.
    pub fn train_sample(
        &mut self,
        x: &TensorF32,
        label: usize,
        masks: &mut dyn MaskProvider,
        ops: &mut OpCounter,
    ) -> (f32, usize, BwdResult) {
        let mut scratch = Scratch::new();
        let trace = self.forward_adapt_in(x, &mut scratch, ops);
        let (loss, probs, err_f) = softmax::softmax_ce(&trace.logits, label, ops);
        let pred = softmax::predict(&probs);
        let bwd = self.backward_in(&trace, err_f, masks, &mut scratch, ops);
        (loss, pred, bwd)
    }

    /// One sample of a batch, computed against the *frozen* model snapshot
    /// (`&self`): forward + saturation telemetry + backward against a local
    /// copy of the error observers. Shard-independent by construction.
    fn batch_sample_pass(&self, x: &TensorF32, label: usize, scratch: &mut Scratch) -> SamplePass {
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        let trace = self.forward_in(x, scratch, &mut fwd_ops);
        let sat = self.measure_saturation(&trace, &mut fwd_ops);
        let (loss, probs, err) = softmax::softmax_ce(&trace.logits, label, &mut bwd_ops);
        let pred = softmax::predict(&probs);
        let mut err_obs = self.err_obs.clone();
        let grads = self.backward_with(
            &trace,
            err,
            &mut DenseUpdates,
            &mut err_obs,
            scratch,
            &mut bwd_ops,
        );
        SamplePass { loss, pred, grads, err_obs, sat, fwd_ops, bwd_ops }
    }

    /// Batched training pass: run forward+backward for every sample of a
    /// minibatch, sharding samples across `workers` `std::thread` workers.
    ///
    /// Semantics (chosen so results are **bit-identical for every worker
    /// count**, including 1):
    ///
    ///  * every sample is evaluated against the same model snapshot — the
    ///    state at batch entry (activation ranges, error observers,
    ///    weights);
    ///  * each sample's backward runs against a private copy of the error
    ///    observers taken at batch entry;
    ///  * after all samples finish, the per-sample observer ranges and
    ///    activation-saturation telemetry are folded into the model
    ///    **in sample order** on the coordinating thread.
    ///
    /// Gradient application stays with the caller: [`BatchResult::grads`]
    /// holds per-sample gradients in sample order, so feeding them to an
    /// optimizer reproduces the sequential accumulation bit-for-bit. The
    /// dynamic sparse controller is inherently sequential (its Eq. 9 state
    /// advances per sample), so the batch engine always computes dense
    /// gradients; sparse runs stay on [`NativeModel::train_sample`].
    ///
    /// Each worker builds its scratch arena at spawn and reuses it across
    /// its samples; with typical minibatches (≥ 8 samples) the per-call
    /// arena cost is noise next to the conv work it serves.
    pub fn train_batch(&mut self, xs: &[&TensorF32], ys: &[usize], workers: usize) -> BatchResult {
        assert_eq!(xs.len(), ys.len(), "one label per sample");
        let n = xs.len();
        let workers = workers.max(1).min(n.max(1));
        let mut passes: Vec<Option<SamplePass>> = (0..n).map(|_| None).collect();

        if workers <= 1 {
            let mut scratch = Scratch::for_model(&self.def);
            for i in 0..n {
                passes[i] = Some(self.batch_sample_pass(xs[i], ys[i], &mut scratch));
            }
        } else {
            let model: &NativeModel = self;
            let chunk = n.div_ceil(workers);
            let results: Vec<Vec<(usize, SamplePass)>> = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for wi in 0..workers {
                    let lo = wi * chunk;
                    let hi = ((wi + 1) * chunk).min(n);
                    if lo >= hi {
                        break;
                    }
                    let wxs = &xs[lo..hi];
                    let wys = &ys[lo..hi];
                    handles.push(s.spawn(move || {
                        let mut scratch = Scratch::for_model(&model.def);
                        let mut out = Vec::with_capacity(wxs.len());
                        for (j, (&x, &y)) in wxs.iter().zip(wys.iter()).enumerate() {
                            out.push((lo + j, model.batch_sample_pass(x, y, &mut scratch)));
                        }
                        out
                    }));
                }
                handles.into_iter().map(|h| h.join().expect("batch worker panicked")).collect()
            });
            for (i, p) in results.into_iter().flatten() {
                passes[i] = Some(p);
            }
        }

        // Deterministic merge, in sample order.
        let mut losses = Vec::with_capacity(n);
        let mut preds = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        for p in passes.into_iter() {
            let p = p.expect("every batch sample must produce a pass");
            self.apply_range_adaptation(&p.sat);
            for (obs, local) in self.err_obs.iter_mut().zip(p.err_obs.iter()) {
                if let Some((lo, hi)) = local.range() {
                    obs.observe_range(lo, hi);
                }
            }
            fwd_ops.add(&p.fwd_ops);
            bwd_ops.add(&p.bwd_ops);
            losses.push(p.loss);
            preds.push(p.pred);
            grads.push(p.grads);
        }
        BatchResult { losses, preds, grads, fwd_ops, bwd_ops }
    }

    /// Backward pass from a float head error (`softmax − onehot`). Walks
    /// layers in reverse down to the earliest trainable layer; error
    /// tensors are quantized per layer precision; ReLU masking uses the
    /// saved forward outputs; pool routing uses the saved argmaxes.
    ///
    /// Convenience wrapper over [`NativeModel::backward_in`] with a
    /// throwaway scratch arena; hot loops (the trainer, the batch engine)
    /// should hold a [`Scratch`] and call `backward_in` directly.
    pub fn backward(
        &mut self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        ops: &mut OpCounter,
    ) -> BwdResult {
        self.backward_in(trace, head_err, masks, &mut Scratch::new(), ops)
    }

    /// [`NativeModel::backward`] with an explicit scratch arena backing the
    /// GEMM-routed backward kernels. Updates the model's own error
    /// observers; delegates to [`NativeModel::backward_with`], which the
    /// batch engine calls directly with per-worker observer copies.
    pub fn backward_in(
        &mut self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> BwdResult {
        let mut obs = std::mem::take(&mut self.err_obs);
        let r = self.backward_with(trace, head_err, masks, &mut obs, scratch, ops);
        self.err_obs = obs;
        r
    }

    /// [`NativeModel::backward_in`] against caller-provided error
    /// observers. The model itself is only read, so concurrent workers can
    /// each run backward passes over a shared `&NativeModel` with their own
    /// observer copies (and their own scratch arenas) and merge the
    /// observations deterministically afterwards.
    ///
    /// Backward compute is GEMM-routed like the forward pass: non-depthwise
    /// convs lower `dW` onto an error × im2col A·Bᵀ GEMM and `dX` onto a
    /// flipped-weights × backward-im2col GEMM; linear layers use the shared
    /// GEMM cores as degenerate cases. Sparse-update masks skip whole GEMM
    /// rows (see DESIGN.md §2). Depthwise convs stay on the scalar kernels.
    pub fn backward_with(
        &self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        err_obs: &mut [MinMaxObserver],
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> BwdResult {
        let n = self.def.layers.len();
        assert_eq!(err_obs.len(), n, "one error observer per layer");
        let stop = self.def.first_trainable().unwrap_or(n);
        let mut grads: Vec<Option<LayerGrads>> = (0..n).map(|_| None).collect();

        // Error w.r.t. the output of layer `i`, in layer i's precision.
        let mut err: Act = match self.prec[n - 1] {
            Precision::Float32 => Act::F(head_err),
            Precision::Uint8 => {
                let obs = &mut err_obs[n - 1];
                obs.observe(head_err.data());
                Act::Q(QTensor::quantize_with(&head_err, obs.qparams()))
            }
        };

        for i in (stop..n).rev() {
            let l = self.def.layers[i].clone();
            // Coerce error into this layer's precision (mixed boundary).
            err = match (self.prec[i], err) {
                (Precision::Uint8, Act::F(t)) => {
                    let obs = &mut err_obs[i];
                    obs.observe(t.data());
                    Act::Q(QTensor::quantize_with(&t, obs.qparams()))
                }
                (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
                (_, e) => e,
            };

            let layer_in: Act =
                if i == 0 { trace.input.clone() } else { trace.acts[i - 1].clone() };
            // Input act coerced to this layer's precision (as in forward).
            let layer_in = match (self.prec[i], layer_in) {
                (Precision::Uint8, Act::F(t)) => Act::Q(QTensor::quantize_with(&t, self.in_qp(i))),
                (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
                (_, a) => a,
            };

            match (&l.kind, &mut err) {
                (LayerKind::Conv { geom, relu }, e) => {
                    let keep = if l.trainable {
                        let norms = structure_norms(e);
                        masks.mask(i, &norms)
                    } else {
                        None
                    };
                    match e {
                        Act::Q(eq) => {
                            if *relu {
                                if let Act::Q(y) = &trace.acts[i] {
                                    qconv::relu_bwd_mask_q(eq, y, ops);
                                }
                            }
                            let (w, _) = match &self.params[i] {
                                LayerParams::Q { w, bias } => (w, bias),
                                other => panic!(
                                    "layer {i} ({}): backward expected quantized (uint8) conv \
                                     params, found {}",
                                    l.name,
                                    other.flavor()
                                ),
                            };
                            let xq = match &layer_in {
                                Act::Q(x) => x,
                                Act::F(_) => panic!(
                                    "layer {i} ({}): backward expected a quantized input \
                                     activation, found float32",
                                    l.name
                                ),
                            };
                            if l.trainable {
                                let (gw, gb) = if geom.depthwise {
                                    qconv::qconv2d_bwd_weight(eq, xq, geom, keep.as_deref(), ops)
                                } else {
                                    qconv::qconv2d_bwd_weight_gemm(
                                        eq,
                                        xq,
                                        geom,
                                        keep.as_deref(),
                                        scratch,
                                        ops,
                                    )
                                };
                                let total = geom.cout;
                                let kept = kept_count(keep.as_deref(), total);
                                grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                            }
                            if i > stop {
                                let (h, w_in) = (layer_in.shape()[1], layer_in.shape()[2]);
                                let prev_obs = &mut err_obs[i - 1];
                                let out_qp = propagate_qp(prev_obs, eq, ops);
                                err = if geom.depthwise {
                                    Act::Q(qconv::qconv2d_bwd_input(
                                        eq,
                                        w,
                                        geom,
                                        h,
                                        w_in,
                                        out_qp,
                                        keep.as_deref(),
                                        ops,
                                    ))
                                } else {
                                    Act::Q(qconv::qconv2d_bwd_input_gemm(
                                        eq,
                                        w,
                                        geom,
                                        h,
                                        w_in,
                                        out_qp,
                                        keep.as_deref(),
                                        scratch,
                                        ops,
                                    ))
                                };
                                observe_saturation(&mut err_obs[i - 1], &err);
                            }
                        }
                        Act::F(ef) => {
                            if *relu {
                                if let Act::F(y) = &trace.acts[i] {
                                    fconv::relu_bwd_mask_f(ef, y, ops);
                                }
                            }
                            let (w, _) = match &self.params[i] {
                                LayerParams::F { w, bias } => (w, bias),
                                other => panic!(
                                    "layer {i} ({}): backward expected float32 conv params, \
                                     found {}",
                                    l.name,
                                    other.flavor()
                                ),
                            };
                            let xf = match &layer_in {
                                Act::F(x) => x,
                                Act::Q(_) => panic!(
                                    "layer {i} ({}): backward expected a float32 input \
                                     activation, found quantized",
                                    l.name
                                ),
                            };
                            if l.trainable {
                                let (gw, gb) = if geom.depthwise {
                                    fconv::fconv2d_bwd_weight(ef, xf, geom, keep.as_deref(), ops)
                                } else {
                                    fconv::fconv2d_bwd_weight_gemm(
                                        ef,
                                        xf,
                                        geom,
                                        keep.as_deref(),
                                        scratch,
                                        ops,
                                    )
                                };
                                let total = geom.cout;
                                let kept = kept_count(keep.as_deref(), total);
                                grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                            }
                            if i > stop {
                                let (h, w_in) = (layer_in.shape()[1], layer_in.shape()[2]);
                                err = if geom.depthwise {
                                    Act::F(fconv::fconv2d_bwd_input(
                                        ef,
                                        w,
                                        geom,
                                        h,
                                        w_in,
                                        keep.as_deref(),
                                        ops,
                                    ))
                                } else {
                                    Act::F(fconv::fconv2d_bwd_input_gemm(
                                        ef,
                                        w,
                                        geom,
                                        h,
                                        w_in,
                                        keep.as_deref(),
                                        scratch,
                                        ops,
                                    ))
                                };
                            }
                        }
                    }
                }
                (LayerKind::Linear { .. }, e) => {
                    let relu = matches!(l.kind, LayerKind::Linear { relu: true, .. });
                    let keep = if l.trainable {
                        let norms = structure_norms(e);
                        masks.mask(i, &norms)
                    } else {
                        None
                    };
                    match e {
                        Act::Q(eq) => {
                            if relu {
                                if let Act::Q(y) = &trace.acts[i] {
                                    qconv::relu_bwd_mask_q(eq, y, ops);
                                }
                            }
                            let (w, _) = match &self.params[i] {
                                LayerParams::Q { w, bias } => (w, bias),
                                other => panic!(
                                    "layer {i} ({}): backward expected quantized (uint8) linear \
                                     params, found {}",
                                    l.name,
                                    other.flavor()
                                ),
                            };
                            let xq = match &layer_in {
                                Act::Q(x) => x,
                                Act::F(_) => panic!(
                                    "layer {i} ({}): backward expected a quantized input \
                                     activation, found float32",
                                    l.name
                                ),
                            };
                            if l.trainable {
                                let (gw, gb) = qlinear::qlinear_bwd_weight_gemm(
                                    eq,
                                    xq,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                );
                                let total = eq.len();
                                let kept = kept_count(keep.as_deref(), total);
                                grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                            }
                            if i > stop {
                                let prev_obs = &mut err_obs[i - 1];
                                let out_qp = propagate_qp(prev_obs, eq, ops);
                                err = Act::Q(qlinear::qlinear_bwd_input_gemm(
                                    eq,
                                    w,
                                    out_qp,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                ));
                                observe_saturation(&mut err_obs[i - 1], &err);
                            }
                        }
                        Act::F(ef) => {
                            if relu {
                                if let Act::F(y) = &trace.acts[i] {
                                    fconv::relu_bwd_mask_f(ef, y, ops);
                                }
                            }
                            let (w, _) = match &self.params[i] {
                                LayerParams::F { w, bias } => (w, bias),
                                other => panic!(
                                    "layer {i} ({}): backward expected float32 linear params, \
                                     found {}",
                                    l.name,
                                    other.flavor()
                                ),
                            };
                            let xf = match &layer_in {
                                Act::F(x) => x,
                                Act::Q(_) => panic!(
                                    "layer {i} ({}): backward expected a float32 input \
                                     activation, found quantized",
                                    l.name
                                ),
                            };
                            if l.trainable {
                                let (gw, gb) =
                                    flinear::flinear_bwd_weight_gemm(ef, xf, keep.as_deref(), ops);
                                let total = ef.len();
                                let kept = kept_count(keep.as_deref(), total);
                                grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                            }
                            if i > stop {
                                err = Act::F(flinear::flinear_bwd_input_gemm(
                                    ef,
                                    w,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                ));
                            }
                        }
                    }
                }
                (LayerKind::MaxPool { .. }, e) => {
                    if i > stop {
                        let am = trace.argmax[i].as_ref().expect("pool argmax");
                        err = match e {
                            Act::Q(eq) => {
                                Act::Q(pool::qmaxpool_bwd(eq, am, &layer_in.shape().to_vec(), ops))
                            }
                            Act::F(ef) => {
                                Act::F(pool::fmaxpool_bwd(ef, am, &layer_in.shape().to_vec(), ops))
                            }
                        };
                    }
                }
                (LayerKind::GlobalAvgPool, e) => {
                    if i > stop {
                        err = match e {
                            Act::Q(eq) => {
                                let prev_obs = &mut err_obs[i - 1];
                                let out_qp = propagate_qp(prev_obs, eq, ops);
                                Act::Q(pool::qgap_bwd(eq, &layer_in.shape().to_vec(), out_qp, ops))
                            }
                            Act::F(ef) => {
                                Act::F(pool::fgap_bwd(ef, &layer_in.shape().to_vec(), ops))
                            }
                        };
                    }
                }
                (LayerKind::Flatten, e) => {
                    if i > stop {
                        err = e.reshaped(&layer_in.shape().to_vec());
                    }
                }
            }
        }

        BwdResult { grads }
    }

    /// Plain inference: predicted class for one sample.
    pub fn predict(&self, x: &TensorF32, ops: &mut OpCounter) -> usize {
        let t = self.forward(x, ops);
        softmax::predict(&t.logits)
    }

    /// Test-set accuracy.
    pub fn evaluate(&self, xs: &[TensorF32], ys: &[usize]) -> f32 {
        let mut ops = OpCounter::new();
        let correct = xs.iter().zip(ys).filter(|(x, &y)| self.predict(x, &mut ops) == y).count();
        correct as f32 / xs.len().max(1) as f32
    }
}

/// L1 norm of the error per structure (outer dimension: out-channels for
/// conv, rows for linear) — the §III-B ranking heuristic, computed on the
/// dequantized magnitudes.
pub fn structure_norms(e: &Act) -> Vec<f32> {
    match e {
        Act::Q(t) => {
            let z = t.qp.zero_point;
            let s = t.qp.scale;
            (0..t.values.outer_dim())
                .map(|c| {
                    t.values.outer(c).iter().map(|&q| ((q as i32 - z).abs() as f32) * s).sum()
                })
                .collect()
        }
        Act::F(t) => (0..t.outer_dim()).map(|c| crate::util::stats::l1(t.outer(c))).collect(),
    }
}

/// Error-observer update when the float-space error is not directly
/// available (fully quantized path): use the incoming error's dequantized
/// range as the proposal for the next layer's range; the saturation check
/// afterwards widens it if the requantized result clips.
fn propagate_qp(obs: &mut MinMaxObserver, incoming: &QTensor, _ops: &mut OpCounter) -> QParams {
    if !obs.has_observed() {
        // bootstrap from the incoming error's range
        let lo = (0 - incoming.qp.zero_point) as f32 * incoming.qp.scale;
        let hi = (255 - incoming.qp.zero_point) as f32 * incoming.qp.scale;
        obs.observe_range(lo, hi);
    }
    obs.qparams()
}

/// Post-hoc range widening: if a noticeable fraction of the requantized
/// error saturates the uint8 range, widen the observer so subsequent
/// samples get more headroom (online analogue of Eqs. 6–7 for errors).
fn observe_saturation(obs: &mut MinMaxObserver, e: &Act) {
    if let Act::Q(t) = e {
        let n = t.len().max(1);
        let sat = t.values.data().iter().filter(|&&v| v == 0 || v == 255).count();
        let (lo, hi) = match obs.range() {
            Some(r) => r,
            None => return,
        };
        if sat * 200 > n {
            // >0.5% saturated: widen by 25%
            obs.observe_range(lo * 1.25, hi * 1.25);
        } else {
            // follow the actual occupied range so scales can also shrink
            let deq = t.dequantize();
            let (dlo, dhi) = crate::util::stats::min_max(deq.data());
            obs.observe_range(dlo, dhi);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn toy_data(
        rng: &mut Pcg32,
        n: usize,
        shape: &[usize],
        classes: usize,
    ) -> (Vec<TensorF32>, Vec<usize>) {
        // Two-class-separable synthetic data: class k biases channel mean.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let y = i % classes;
            let mut x = TensorF32::zeros(shape);
            rng.fill_normal(x.data_mut(), 0.5);
            for v in x.data_mut().iter_mut() {
                *v += y as f32 * 0.8;
            }
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    fn deployed(cfg: DnnConfig, seed: u64) -> (NativeModel, Vec<TensorF32>, Vec<usize>) {
        let mut rng = Pcg32::seeded(seed);
        let def = models::mnist_cnn(&[1, 12, 12], 3);
        let fp = FloatParams::init(&def, &mut rng);
        let (xs, ys) = toy_data(&mut rng, 12, &[1, 12, 12], 3);
        let calib = calibrate(&def, &fp, &xs[..4]);
        (NativeModel::build(def, cfg, &fp, &calib), xs, ys)
    }

    #[test]
    fn forward_shapes_all_configs() {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (m, xs, _) = deployed(cfg, 61);
            let mut ops = OpCounter::new();
            let t = m.forward(&xs[0], &mut ops);
            assert_eq!(t.logits.len(), 3, "{cfg:?}");
            assert_eq!(t.acts.len(), m.def.layers.len());
            assert!(ops.total_macs() > 0);
        }
    }

    #[test]
    fn quantized_forward_tracks_float_forward() {
        let (mq, xs, _) = deployed(DnnConfig::Uint8, 62);
        let (mf, _, _) = deployed(DnnConfig::Float32, 62);
        let mut ops = OpCounter::new();
        // identical float masters (same seed) -> logits should correlate
        let lq = mq.forward(&xs[0], &mut ops).logits;
        let lf = mf.forward(&xs[0], &mut ops).logits;
        // rank agreement on the toy problem is enough (quantization noise)
        let aq = crate::util::stats::argmax(&lq);
        let af = crate::util::stats::argmax(&lf);
        assert_eq!(aq, af, "lq={lq:?} lf={lf:?}");
    }

    #[test]
    fn uint8_uses_integer_macs_float_uses_float_macs() {
        let (mq, xs, _) = deployed(DnnConfig::Uint8, 63);
        let mut ops = OpCounter::new();
        mq.forward(&xs[0], &mut ops);
        assert!(ops.int_macs > 0);
        assert_eq!(ops.float_macs, 0);

        let (mf, _, _) = deployed(DnnConfig::Float32, 63);
        let mut ops2 = OpCounter::new();
        mf.forward(&xs[0], &mut ops2);
        assert!(ops2.float_macs > 0);
        assert_eq!(ops2.int_macs, 0);
    }

    #[test]
    fn mixed_config_crosses_boundary_once() {
        let (m, xs, _) = deployed(DnnConfig::Mixed, 64);
        let mut ops = OpCounter::new();
        let t = m.forward(&xs[0], &mut ops);
        // feature extractor quantized, head float
        assert!(matches!(t.acts[0], Act::Q(_)));
        assert!(matches!(t.acts.last().unwrap(), Act::F(_)));
        assert!(ops.int_macs > 0 && ops.float_macs > 0);
    }

    #[test]
    fn backward_produces_grads_for_trainable_layers_only() {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (mut m, xs, ys) = deployed(cfg, 65);
            let mut ops = OpCounter::new();
            let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
            for (i, l) in m.def.layers.iter().enumerate() {
                assert_eq!(bwd.grads[i].is_some(), l.trainable, "layer {i} {cfg:?}");
            }
        }
    }

    #[test]
    fn grad_shapes_match_weights() {
        let (mut m, xs, ys) = deployed(DnnConfig::Uint8, 66);
        let mut ops = OpCounter::new();
        let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
        for (i, g) in bwd.grads.iter().enumerate() {
            if let Some(g) = g {
                match &m.params[i] {
                    LayerParams::Q { w, bias } => {
                        assert_eq!(g.gw.shape(), w.shape());
                        assert_eq!(g.gb.len(), bias.len());
                    }
                    LayerParams::F { w, bias } => {
                        assert_eq!(g.gw.shape(), w.shape());
                        assert_eq!(g.gb.len(), bias.len());
                    }
                    LayerParams::None => panic!("grads on weightless layer"),
                }
            }
        }
    }

    #[test]
    fn transfer_mode_stops_backprop_early() {
        let mut rng = Pcg32::seeded(67);
        let mut def = models::mnist_cnn(&[1, 12, 12], 3);
        def.set_trainable_tail(2); // only the two linear layers
        let fp = FloatParams::init(&def, &mut rng);
        let (xs, ys) = toy_data(&mut rng, 6, &[1, 12, 12], 3);
        let calib = calibrate(&def, &fp, &xs[..2]);
        let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);

        let mut ops_full = OpCounter::new();
        let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops_full);
        assert!(bwd.grads[0].is_none());
        assert!(bwd.grads[4].is_some() && bwd.grads[5].is_some());

        // transfer-learning bwd must be cheaper than fwd (Fig. 4b property)
        let mut ops_fwd = OpCounter::new();
        m.forward(&xs[0], &mut ops_fwd);
        let bwd_macs = ops_full.total_macs().saturating_sub(ops_fwd.total_macs());
        assert!(bwd_macs < ops_fwd.total_macs(), "bwd={} fwd={}", bwd_macs, ops_fwd.total_macs());
    }

    #[test]
    fn structure_norms_match_dequantized_l1() {
        let t = TensorF32::from_vec(&[2, 2], vec![1.0, -1.0, 0.5, 0.25]);
        let nf = structure_norms(&Act::F(t.clone()));
        assert!((nf[0] - 2.0).abs() < 1e-6);
        assert!((nf[1] - 0.75).abs() < 1e-6);
        let q = QTensor::quantize(&t);
        let nq = structure_norms(&Act::Q(q));
        assert!((nq[0] - 2.0).abs() < 0.1);
        assert!((nq[1] - 0.75).abs() < 0.1);
    }

    /// The batch engine must be worker-count invariant: identical losses,
    /// predictions, gradients, op totals and post-batch model state
    /// (adapted ranges, observers) for 1 and many workers.
    #[test]
    fn train_batch_is_worker_count_invariant() {
        let (mut m1, xs, ys) = deployed(DnnConfig::Uint8, 70);
        let (mut m2, _, _) = deployed(DnnConfig::Uint8, 70);
        let refs: Vec<&TensorF32> = xs.iter().collect();
        let r1 = m1.train_batch(&refs, &ys, 1);
        let r2 = m2.train_batch(&refs, &ys, 4);
        assert_eq!(r1.losses, r2.losses);
        assert_eq!(r1.preds, r2.preds);
        assert_eq!(r1.fwd_ops, r2.fwd_ops);
        assert_eq!(r1.bwd_ops, r2.bwd_ops);
        for (a, b) in r1.grads.iter().zip(r2.grads.iter()) {
            for (ga, gb) in a.grads.iter().zip(b.grads.iter()) {
                match (ga, gb) {
                    (Some(ga), Some(gb)) => {
                        assert_eq!(ga.gw.data(), gb.gw.data());
                        assert_eq!(ga.gb.data(), gb.gb.data());
                        assert_eq!(ga.kept, gb.kept);
                    }
                    (None, None) => {}
                    _ => panic!("gradient presence differs between worker counts"),
                }
            }
        }
        for (a, b) in m1.act_qp.iter().zip(m2.act_qp.iter()) {
            assert_eq!(a, b, "adapted activation ranges must match");
        }
        for (a, b) in m1.err_obs.iter().zip(m2.err_obs.iter()) {
            assert_eq!(a.range(), b.range(), "merged observer state must match");
        }
    }

    /// Batched gradients must match the per-sample path when the model
    /// state is frozen (same snapshot semantics): sample 0 sees identical
    /// conditions in both engines.
    #[test]
    fn train_batch_first_sample_matches_sequential() {
        let (mut mb, xs, ys) = deployed(DnnConfig::Uint8, 71);
        let (mut ms, _, _) = deployed(DnnConfig::Uint8, 71);
        let refs: Vec<&TensorF32> = xs.iter().take(1).collect();
        let rb = mb.train_batch(&refs, &ys[..1], 2);
        let mut ops = OpCounter::new();
        let (loss, pred, bwd) = ms.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
        assert_eq!(rb.losses[0], loss);
        assert_eq!(rb.preds[0], pred);
        for (a, b) in rb.grads[0].grads.iter().zip(bwd.grads.iter()) {
            if let (Some(a), Some(b)) = (a, b) {
                assert_eq!(a.gw.data(), b.gw.data());
            }
        }
    }

    /// A few FQT steps on the toy problem must reduce the loss — the
    /// integration smoke test of the whole fwd/bwd stack (full training is
    /// exercised by `train::` and the benches).
    #[test]
    fn quantized_training_reduces_loss_smoke() {
        use crate::train::Optimizer;
        let (mut m, xs, ys) = deployed(DnnConfig::Uint8, 68);
        let mut opt = crate::train::fqt::FqtSgd::new(&m, 0.01, 4);
        let mut first = 0.0;
        let mut last = 0.0;
        let mut ops = OpCounter::new();
        for epoch in 0..12 {
            let mut tot = 0.0;
            for (x, &y) in xs.iter().zip(ys.iter()) {
                let (loss, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                opt.accumulate(&mut m, &bwd, &mut ops);
                tot += loss;
            }
            if epoch == 0 {
                first = tot;
            }
            last = tot;
        }
        assert!(last < first * 0.9, "loss did not drop: first={first} last={last}");
    }
}
