//! Native model executor: deployed model state plus the forward and
//! backward entry points, lowered onto the compiled layer-op plan.
//!
//! The deployed state is split along the fleet axis (DESIGN.md §9):
//!
//!  * [`ModelArtifacts`] — everything produced once at deployment and
//!    immutable afterwards: the model definition and configuration, the
//!    per-layer precisions, PTQ calibration output (input quantization
//!    parameters plus the *base* activation ranges and quantized weights),
//!    and the [`ExecPlan`] compiled for the configuration (`graph::plan`),
//!    which carries the trait-based layer ops, the liveness-planned
//!    activation arena and the exact scratch requirements of a training
//!    step. Artifacts are shared across tenants behind an `Arc` — the
//!    fleet coordinator deploys one and spawns thousands of sessions off
//!    it.
//!  * [`SessionState`] — the per-tenant mutable training state: the live
//!    parameters (Arc-CoW clones of the base weights, so an untouched
//!    layer costs nothing), the adapted activation ranges, the online
//!    error observers (`quant::observer`), the per-layer parameter
//!    versions and the plan-owned packed-weight cache keyed by them.
//!
//! A [`NativeModel`] is one session bound to its artifacts — exactly what
//! a single MCU holds in RAM/Flash. The forward pass doubles as inference
//! (the paper's in-place property: the same representation serves both,
//! §III-A); the backward pass implements Eqs. 1–4 with optional
//! per-structure masks from the dynamic sparse update controller (§III-B).
//! Both are pure dispatch over the plan's op list; the straight-line
//! pre-plan implementation is retained in [`crate::graph::reference`] as
//! the golden parity reference.

pub use crate::graph::act::{calibrate, structure_norms, Act, Calibration, FloatParams, LayerParams};
pub use crate::graph::batch::BatchResult;

use std::sync::Arc;

use crate::graph::act::init_layer;
use crate::graph::packs::{PackCache, PackStats};
use crate::graph::plan::{BitSpec, ExecPlan};
use crate::graph::{DnnConfig, LayerKind, ModelDef, Precision};
use crate::kernels::{dwconv, gemm, softmax, OpCounter};
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::quant::subbyte::{self, PackedQTensor};
use crate::quant::{QParams, QTensor};
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

/// Saved forward-pass state needed by backprop (the data dependencies of
/// Fig. 1: layer inputs, post-activation outputs, pool argmaxes).
pub struct FwdTrace {
    pub input: Act,
    pub acts: Vec<Act>,
    pub argmax: Vec<Option<Vec<u32>>>,
    /// Per-layer `(saturated, total)` output-range saturation counts the
    /// fused kernel epilogues record while requantizing the register tile
    /// (`None` for float layers, unfused plans, and the reference
    /// executor). The saturation-telemetry pass behind
    /// [`NativeModel::forward_adapt`] consumes these instead of
    /// re-sweeping the activation when present.
    pub sat: Vec<Option<(usize, usize)>>,
    pub logits: Vec<f32>,
}

/// Per-layer gradient output of one backward pass.
pub struct LayerGrads {
    pub gw: TensorF32,
    pub gb: TensorF32,
    /// (kept structures, total structures) under the sparse mask.
    pub kept: (usize, usize),
}

/// Result of one backward pass.
pub struct BwdResult {
    /// Aligned with `def.layers`; `Some` only for trainable layers.
    pub grads: Vec<Option<LayerGrads>>,
}

/// Mask provider interface implemented by the dynamic sparse update
/// controller (`train::sparse`). `None` = update everything.
pub trait MaskProvider {
    fn mask(&mut self, layer: usize, structure_norms: &[f32]) -> Option<Vec<bool>>;
}

/// The always-dense provider (λ_min = λ_max = 1).
pub struct DenseUpdates;

impl MaskProvider for DenseUpdates {
    fn mask(&mut self, _layer: usize, _norms: &[f32]) -> Option<Vec<bool>> {
        None
    }
}

/// The immutable output of deployment: definition, configuration,
/// compiled execution plan and PTQ base state. One `ModelArtifacts` is
/// shared (behind an [`Arc`]) by every tenant session spawned from it —
/// tenants never write any of this, so per-tenant memory starts at zero
/// and grows only with what each tenant's training actually diverges
/// (see [`SessionState::delta_bytes`]).
pub struct ModelArtifacts {
    pub def: ModelDef,
    pub cfg: DnnConfig,
    pub prec: Vec<Precision>,
    /// PTQ input quantization parameters (calibration output; fixed).
    pub input_qp: QParams,
    /// Quantized (or float, per precision) deployed base weights — the
    /// flash image. Sessions CoW-clone these; an untrained layer aliases
    /// this storage byte-for-byte.
    pub base_params: Vec<LayerParams>,
    /// PTQ activation ranges sessions start from (they adapt per tenant).
    pub base_act_qp: Vec<QParams>,
    plan: ExecPlan,
}

impl ModelArtifacts {
    /// Deploy: quantize float master weights per the configuration, using
    /// PTQ calibration ranges for activations, and compile the execution
    /// plan (`O(layers)`, once).
    pub fn deploy(def: ModelDef, cfg: DnnConfig, fp: &FloatParams, calib: &Calibration) -> Self {
        Self::deploy_with_fusion(def, cfg, fp, calib, crate::graph::plan::fuse_default())
    }

    /// [`ModelArtifacts::deploy`] with an explicit plan-fusion mode (see
    /// [`ExecPlan::compile_with`]); `deploy` follows the `TT_NO_FUSE`
    /// environment default.
    pub fn deploy_with_fusion(
        def: ModelDef,
        cfg: DnnConfig,
        fp: &FloatParams,
        calib: &Calibration,
        fused: bool,
    ) -> Self {
        Self::deploy_with_bits(def, cfg, fp, calib, fused, &BitSpec::from_env())
    }

    /// [`ModelArtifacts::deploy_with_fusion`] with an explicit weight
    /// storage-width request (see [`BitSpec`] /
    /// [`ExecPlan::compile_with_bits`]); the other constructors follow the
    /// `TT_WBITS` / `TT_WEIGHT_BUDGET` environment defaults.
    ///
    /// The plan is compiled *first*: its bit-selection pass decides which
    /// layers deploy plain u8 ([`LayerParams::Q`] — the default, and the
    /// retained bit-exactness oracle) and which deploy packed sub-byte
    /// ([`LayerParams::Qp`], quantized straight from the float masters at
    /// the assigned width).
    pub fn deploy_with_bits(
        def: ModelDef,
        cfg: DnnConfig,
        fp: &FloatParams,
        calib: &Calibration,
        fused: bool,
        bits: &BitSpec,
    ) -> Self {
        let prec = def.precisions(cfg);
        let plan = ExecPlan::compile_with_bits(&def, cfg, fused, bits);
        let base_params = def
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| match (&fp.layers[i], prec[i]) {
                (Some((w, b)), Precision::Uint8) if l.has_weights() => {
                    match plan.bit_plan().packed(i) {
                        Some(wb) => LayerParams::Qp {
                            w: PackedQTensor::quantize_bits(w, wb),
                            bias: b.clone(),
                        },
                        None => LayerParams::Q { w: QTensor::quantize(w), bias: b.clone() },
                    }
                }
                (Some((w, b)), _) if l.has_weights() => {
                    LayerParams::F { w: w.clone(), bias: b.clone() }
                }
                _ => LayerParams::None,
            })
            .collect();
        ModelArtifacts {
            prec,
            input_qp: calib.input_qp,
            base_params,
            base_act_qp: calib.act_qp.clone(),
            plan,
            def,
            cfg,
        }
    }

    /// The execution plan compiled at deployment.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Scratch arena pre-sized from the plan's exact requirements: a full
    /// training step (any configuration) performs zero arena growth.
    pub fn make_scratch(&self) -> Scratch {
        self.plan.make_scratch()
    }

    /// Bytes of deployment state every tenant shares instead of owning:
    /// the base weights plus the plan's activation arena requirement (the
    /// dominant shared-infrastructure cost; per-worker scratch arenas are
    /// pool property, also not per-tenant).
    pub fn shared_bytes(&self) -> usize {
        let weights: usize = self.base_params.iter().map(|p| p.byte_size()).sum();
        weights + self.plan.planned_peak_bytes
    }
}

/// Per-tenant mutable training state: what one adapting device owns
/// beyond the shared [`ModelArtifacts`]. Spawned cheap — parameters are
/// Arc-CoW clones of the base weights (alias until the optimizer's first
/// write to a layer), the pack cache starts cold and fills lazily on the
/// first backward pass (`warm_packs`; a cold entry falls back to scratch
/// packing, bit-identical either way).
pub struct SessionState {
    pub params: Vec<LayerParams>,
    pub act_qp: Vec<QParams>,
    pub err_obs: Vec<MinMaxObserver>,
    /// Plan-owned dense backward weight packs (`graph::packs`), read by
    /// the plan ops through a shared reference; re-packed by
    /// [`SessionState::warm_packs`] only for layers whose
    /// [`SessionState::touch_layer`] version moved.
    packs: PackCache,
    /// Per-layer parameter versions (start at 1). Every parameter write
    /// must go through [`SessionState::touch_layer`] so the pack cache can
    /// tell fresh packs from stale ones.
    param_versions: Vec<u64>,
}

impl SessionState {
    /// A fresh session off the shared artifacts: CoW parameter clones,
    /// base activation ranges, pristine observers, cold pack cache.
    pub fn fresh(shared: &ModelArtifacts) -> SessionState {
        let n = shared.def.layers.len();
        let mut packs = PackCache::new(n);
        // The plan's autotuned per-layer kernel preferences ride along in
        // the pack cache: both are plan-derived per-layer dispatch state
        // the ops consult on the hot path through the same `ctx.packs`.
        packs.install_choices(shared.plan().kernel_choices());
        SessionState {
            params: shared.base_params.clone(),
            act_qp: shared.base_act_qp.clone(),
            err_obs: shared.def.layers.iter().map(|_| MinMaxObserver::online()).collect(),
            packs,
            param_versions: vec![1; n],
        }
    }

    /// The session's packed-weight cache (read-only view; the plan ops
    /// consult it on the backward hot path).
    pub fn packs(&self) -> &PackCache {
        &self.packs
    }

    /// Per-layer parameter versions (the pack cache's freshness key).
    pub fn param_versions(&self) -> &[u64] {
        &self.param_versions
    }

    /// Record that layer `i`'s parameters changed. The optimizers call
    /// this on every applied update (the dirty bit that invalidates the
    /// layer's cached backward pack); any other code that writes
    /// `self.params[i]` must do the same.
    pub fn touch_layer(&mut self, i: usize) {
        self.param_versions[i] += 1;
    }

    /// Re-pack the backward weight packs for every layer whose parameter
    /// version moved since the last warm (a cheap per-layer version
    /// compare when nothing changed). Covers exactly the layers whose
    /// backward-input kernel the plan can reach (`layer > stop`): dense
    /// convs get the flipped-transposed GEMM pack, depthwise convs the
    /// 180°-flipped per-channel pack of the depthwise engine
    /// (`kernels::dwconv`). Called at deployment, by `backward_in` before
    /// each sequential backward pass, and by the batch engine once per
    /// minibatch before sharding — so concurrent workers only ever read a
    /// fresh cache.
    pub fn warm_packs(&mut self, def: &ModelDef) {
        let n = def.layers.len();
        let stop = def.first_trainable().unwrap_or(n);
        for i in 0..n {
            let geom = match def.layers[i].kind {
                LayerKind::Conv { geom, .. } => geom,
                _ => continue,
            };
            if i <= stop {
                continue;
            }
            let v = self.param_versions[i];
            if geom.depthwise {
                match &self.params[i] {
                    LayerParams::Q { w, .. } => {
                        self.packs.put_dw_u8(i, v, |dst| {
                            dst.resize(geom.cout * geom.kh * geom.kw, 0);
                            dwconv::pack_dw_flip_u8(w.values.data(), &geom, dst);
                        });
                    }
                    // Packed layers keep their cache entry packed too:
                    // unpack the lanes, flip, re-pack. The flipped lane
                    // *sequence* is what gets packed, so the consumer's
                    // plain unpack restores the flipped layout directly.
                    LayerParams::Qp { w, .. } => {
                        self.packs.put_dw_u8_packed(i, v, w.bits, |dst| {
                            let mut lanes = vec![0u8; w.len()];
                            w.unpack_into(&mut lanes);
                            let mut flip = vec![0u8; geom.cout * geom.kh * geom.kw];
                            dwconv::pack_dw_flip_u8(&lanes, &geom, &mut flip);
                            *dst = subbyte::pack_lanes(&flip, w.bits);
                        });
                    }
                    LayerParams::F { w, .. } => {
                        self.packs.put_dw_f32(i, v, |dst| {
                            dst.resize(geom.cout * geom.kh * geom.kw, 0.0);
                            dwconv::pack_dw_flip_f32(w.data(), &geom, dst);
                        });
                    }
                    LayerParams::None => {}
                }
                continue;
            }
            match &self.params[i] {
                LayerParams::Q { w, .. } => {
                    self.packs.put_u8(i, v, |dst| {
                        dst.resize(geom.cin * geom.cout * geom.kh * geom.kw, 0);
                        gemm::pack_wt_flip_u8(w.values.data(), &geom, None, dst);
                    });
                }
                LayerParams::Qp { w, .. } => {
                    self.packs.put_u8_packed(i, v, w.bits, |dst| {
                        let mut lanes = vec![0u8; w.len()];
                        w.unpack_into(&mut lanes);
                        let mut flip = vec![0u8; geom.cin * geom.cout * geom.kh * geom.kw];
                        gemm::pack_wt_flip_u8(&lanes, &geom, None, &mut flip);
                        *dst = subbyte::pack_lanes(&flip, w.bits);
                    });
                }
                LayerParams::F { w, .. } => {
                    self.packs.put_f32(i, v, |dst| {
                        dst.resize(geom.cin * geom.cout * geom.kh * geom.kw, 0.0);
                        gemm::pack_wt_flip_f32(w.data(), &geom, None, dst);
                    });
                }
                LayerParams::None => {}
            }
        }
    }

    /// Bytes this session owns beyond the shared artifacts: weight storage
    /// that has CoW-diverged from the base (an untouched layer's tensor
    /// still aliases the shared buffer and counts zero), per-tenant bias
    /// vectors, adapted activation ranges, error observers, parameter
    /// versions and the session's pack cache. This is the "per-tenant
    /// memory is deltas only" number the fleet benchmark reports.
    pub fn delta_bytes(&self, shared: &ModelArtifacts) -> usize {
        let mut bytes = 0usize;
        for (mine, base) in self.params.iter().zip(shared.base_params.iter()) {
            bytes += match (mine, base) {
                (LayerParams::Q { w, bias }, LayerParams::Q { w: bw, .. }) => {
                    let wb = if w.values.shares_data(&bw.values) { 0 } else { w.values.len() };
                    wb + std::mem::size_of::<QParams>() + bias.len() * 4
                }
                // Packed layers diverge at their *packed* byte count — the
                // whole point of sub-byte storage is that a CoW-diverged
                // 4-bit layer costs half its u8 twin.
                (LayerParams::Qp { w, bias }, LayerParams::Qp { w: bw, .. }) => {
                    let wb = if w.data.shares_data(&bw.data) { 0 } else { w.packed_bytes() };
                    wb + std::mem::size_of::<QParams>() + bias.len() * 4
                }
                (LayerParams::F { w, bias }, LayerParams::F { w: bw, .. }) => {
                    let wb = if w.shares_data(bw) { 0 } else { w.len() * 4 };
                    wb + bias.len() * 4
                }
                _ => mine.byte_size(),
            };
        }
        bytes += self.act_qp.len() * std::mem::size_of::<QParams>();
        bytes += self.err_obs.len() * std::mem::size_of::<MinMaxObserver>();
        bytes += self.param_versions.len() * std::mem::size_of::<u64>();
        bytes + self.packs.reserved_bytes()
    }
}

/// A deployed model: one session bound to its (shareable) deployment
/// artifacts — the exact state a single MCU holds in RAM/Flash, plus the
/// execution plan compiled for its configuration.
pub struct NativeModel {
    /// Immutable deployment artifacts, shared across every session
    /// spawned from the same deployment ([`NativeModel::from_artifacts`]).
    pub shared: Arc<ModelArtifacts>,
    /// This session's mutable training state.
    pub state: SessionState,
}

impl NativeModel {
    /// Deploy a standalone model: artifacts plus one warm session. See
    /// [`ModelArtifacts::deploy`]; fleet callers deploy artifacts once and
    /// spawn sessions with [`NativeModel::from_artifacts`].
    pub fn build(def: ModelDef, cfg: DnnConfig, fp: &FloatParams, calib: &Calibration) -> Self {
        Self::build_with_fusion(def, cfg, fp, calib, crate::graph::plan::fuse_default())
    }

    /// [`NativeModel::build`] with an explicit plan-fusion mode (see
    /// [`ExecPlan::compile_with`]); `build` follows the `TT_NO_FUSE`
    /// environment default. The parity suite deploys one model per mode
    /// from the same float masters and asserts bit-identical behavior.
    pub fn build_with_fusion(
        def: ModelDef,
        cfg: DnnConfig,
        fp: &FloatParams,
        calib: &Calibration,
        fused: bool,
    ) -> Self {
        let shared = Arc::new(ModelArtifacts::deploy_with_fusion(def, cfg, fp, calib, fused));
        let mut model = Self::from_artifacts(shared);
        model.warm_packs();
        model
    }

    /// [`NativeModel::build_with_fusion`] with an explicit weight
    /// storage-width request (see [`ModelArtifacts::deploy_with_bits`]).
    /// The sub-byte parity suite deploys one model per width from the same
    /// float masters and compares against the u8 oracle.
    pub fn build_with_bits(
        def: ModelDef,
        cfg: DnnConfig,
        fp: &FloatParams,
        calib: &Calibration,
        fused: bool,
        bits: &BitSpec,
    ) -> Self {
        let shared = Arc::new(ModelArtifacts::deploy_with_bits(def, cfg, fp, calib, fused, bits));
        let mut model = Self::from_artifacts(shared);
        model.warm_packs();
        model
    }

    /// Spawn a session off shared deployment artifacts. Cheap by design:
    /// parameters are Arc-CoW clones of the base weights and the pack
    /// cache starts cold (filled lazily by the first backward pass), so a
    /// fresh tenant owns kilobytes, not a model copy — the fleet
    /// coordinator's per-tenant memory story.
    pub fn from_artifacts(shared: Arc<ModelArtifacts>) -> Self {
        let state = SessionState::fresh(&shared);
        NativeModel { shared, state }
    }

    /// The shared deployment artifacts (clone the `Arc` to spawn sibling
    /// sessions off the same deployment).
    pub fn artifacts(&self) -> &Arc<ModelArtifacts> {
        &self.shared
    }

    /// The execution plan compiled at deployment.
    pub fn plan(&self) -> &ExecPlan {
        self.shared.plan()
    }

    /// Scratch arena pre-sized from the plan's exact requirements: a full
    /// training step (any configuration) performs zero arena growth.
    pub fn make_scratch(&self) -> Scratch {
        self.shared.make_scratch()
    }

    /// The session's packed-weight cache (read-only view; the plan ops
    /// consult it on the backward hot path).
    pub fn packs(&self) -> &PackCache {
        self.state.packs()
    }

    /// Per-layer parameter versions (the pack cache's freshness key).
    pub fn param_versions(&self) -> &[u64] {
        self.state.param_versions()
    }

    /// Pack-cache telemetry (hits/misses/builds).
    pub fn pack_stats(&self) -> PackStats {
        self.state.packs.stats()
    }

    /// Record that layer `i`'s parameters changed (see
    /// [`SessionState::touch_layer`]).
    pub fn touch_layer(&mut self, i: usize) {
        self.state.touch_layer(i);
    }

    /// Re-pack stale backward weight packs (see
    /// [`SessionState::warm_packs`]).
    pub fn warm_packs(&mut self) {
        self.state.warm_packs(&self.shared.def);
    }

    /// Re-randomize the trainable layers (§IV-A: "we set the last five
    /// layers of each DNN to random values, thereby resetting its
    /// classification capabilities").
    pub fn reset_trainable(&mut self, rng: &mut Pcg32) {
        for i in 0..self.shared.def.layers.len() {
            if !self.shared.def.layers[i].trainable {
                continue;
            }
            if let Some((w, b)) = init_layer(&self.shared.def.layers[i], rng) {
                self.state.params[i] = match self.shared.prec[i] {
                    Precision::Uint8 => match self.shared.plan().bit_plan().packed(i) {
                        Some(bits) => {
                            LayerParams::Qp { w: PackedQTensor::quantize_bits(&w, bits), bias: b }
                        }
                        None => LayerParams::Q { w: QTensor::quantize(&w), bias: b },
                    },
                    Precision::Float32 => LayerParams::F { w, bias: b },
                };
                self.touch_layer(i);
            }
        }
        self.warm_packs();
    }

    /// Extract float masters (only valid for `Float32` models; used to pull
    /// pretrained weights out for deployment under other configs).
    pub fn to_float_params(&self) -> FloatParams {
        let layers = self
            .state
            .params
            .iter()
            .map(|p| match p {
                LayerParams::F { w, bias } => Some((w.clone(), bias.clone())),
                LayerParams::Q { w, bias } => Some((w.dequantize(), bias.clone())),
                LayerParams::Qp { w, bias } => Some((w.dequantize(), bias.clone())),
                LayerParams::None => None,
            })
            .collect();
        FloatParams { layers }
    }

    /// Forward pass for one sample. Works for plain inference too (drop the
    /// trace): the paper's zero-downtime property — training shares the
    /// inference representation byte-for-byte.
    ///
    /// Convenience wrapper over [`NativeModel::forward_in`] with a
    /// throwaway scratch arena; hot loops (the trainer, the batch engine)
    /// should hold a [`Scratch`] and call `forward_in` directly.
    pub fn forward(&self, x: &TensorF32, ops: &mut OpCounter) -> FwdTrace {
        self.forward_in(x, &mut Scratch::new(), ops)
    }

    /// Forward pass with an explicit scratch arena, executing the compiled
    /// plan: non-depthwise convs route through the im2col/GEMM engine
    /// (`kernels::gemm`) and depthwise convs through the register-blocked
    /// depthwise engine (`kernels::dwconv`) — both bit-exact with the
    /// scalar reference kernels; linears and pools use the MCU-faithful
    /// kernels directly. `Flatten` is a zero-copy view.
    pub fn forward_in(
        &self,
        x: &TensorF32,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> FwdTrace {
        self.shared.plan.run_forward(self, x, scratch, ops)
    }

    /// Training-path forward: run the regular forward pass, then let the
    /// activation ranges of *trainable* quantized layers follow the drifting
    /// activation distribution. Training moves weight distributions (which
    /// Eqs. 5–7 track), which in turn moves the activations they produce;
    /// with ranges frozen at PTQ calibration the logits saturate and
    /// training stalls — the failure mode the paper attributes to "the
    /// quantization of tensors in the last layers" (§IV-A). The adaptation
    /// rule mirrors Eqs. 6–7: when >1 % of a trainable layer's output
    /// saturates the uint8 range, widen its range 25 % (upper end only for
    /// folded-ReLU layers, whose lower bound is pinned at the zero point).
    pub fn forward_adapt(&mut self, x: &TensorF32, ops: &mut OpCounter) -> FwdTrace {
        self.forward_adapt_in(x, &mut Scratch::new(), ops)
    }

    /// [`NativeModel::forward_adapt`] with an explicit scratch arena.
    pub fn forward_adapt_in(
        &mut self,
        x: &TensorF32,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> FwdTrace {
        let trace = self.forward_in(x, scratch, ops);
        let sat = self.measure_saturation(&trace, ops);
        self.apply_range_adaptation(&sat);
        trace
    }

    /// Per-layer saturation telemetry of one forward trace: for each
    /// *trainable, quantized* layer, the number of output values clipped at
    /// the uint8 range (upper end only for folded-ReLU layers, whose lower
    /// bound is pinned at the zero point) and the output element count.
    /// `None` for layers the adaptation rule does not apply to.
    pub(crate) fn measure_saturation(
        &self,
        trace: &FwdTrace,
        ops: &mut OpCounter,
    ) -> Vec<Option<(usize, usize)>> {
        self.shared
            .def
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if !l.trainable || self.shared.prec[i] != Precision::Uint8 {
                    return None;
                }
                // The fused epilogues already counted saturation while
                // requantizing the register tile — consume the recorded
                // count instead of re-sweeping the activation. The op
                // accounting matches the sweep it replaces, so fused and
                // unfused telemetry report identical `OpCounter` totals.
                if let Some(s) = trace.sat[i] {
                    ops.int_ops += s.1 as u64;
                    return Some(s);
                }
                let relu = matches!(
                    l.kind,
                    LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
                );
                match &trace.acts[i] {
                    Act::Q(t) => {
                        let n = t.len().max(1);
                        let sat_hi = t.values.data().iter().filter(|&&v| v == 255).count();
                        let sat_lo = if relu {
                            0
                        } else {
                            t.values.data().iter().filter(|&&v| v == 0).count()
                        };
                        ops.int_ops += n as u64;
                        Some((sat_hi + sat_lo, n))
                    }
                    Act::F(_) => None,
                }
            })
            .collect()
    }

    /// Apply the Eqs. 6–7-style range widening for saturation telemetry
    /// gathered by [`NativeModel::measure_saturation`]: when >1 % of a
    /// layer's output saturates, widen its range 25 %. Split from the
    /// measurement so the batch engine can collect telemetry concurrently
    /// and fold it in deterministically, in sample order.
    pub(crate) fn apply_range_adaptation(&mut self, sat: &[Option<(usize, usize)>]) {
        for (i, s) in sat.iter().enumerate() {
            let Some(&(sat, n)) = s.as_ref() else { continue };
            if sat * 100 > n {
                let relu = matches!(
                    self.shared.def.layers[i].kind,
                    LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
                );
                let qp = self.state.act_qp[i];
                let lo = (0 - qp.zero_point) as f32 * qp.scale;
                let hi = (255 - qp.zero_point) as f32 * qp.scale;
                let (nlo, nhi) = if relu {
                    (lo, hi * 1.25)
                } else {
                    let span = hi - lo;
                    (lo - 0.25 * span, hi + 0.25 * span)
                };
                self.state.act_qp[i] = QParams::from_min_max(nlo, nhi);
            }
        }
    }

    /// One full training-sample pass: forward (with activation-range
    /// adaptation), loss, backward. Returns the loss, the predicted class
    /// and the per-layer gradients. One scratch arena serves both passes.
    pub fn train_sample(
        &mut self,
        x: &TensorF32,
        label: usize,
        masks: &mut dyn MaskProvider,
        ops: &mut OpCounter,
    ) -> (f32, usize, BwdResult) {
        let mut scratch = Scratch::new();
        let trace = self.forward_adapt_in(x, &mut scratch, ops);
        let (loss, probs, err_f) = softmax::softmax_ce(&trace.logits, label, ops);
        let pred = softmax::predict(&probs);
        let bwd = self.backward_in(&trace, err_f, masks, &mut scratch, ops);
        (loss, pred, bwd)
    }

    /// Backward pass from a float head error (`softmax − onehot`). Walks
    /// the plan in reverse down to the earliest trainable layer; error
    /// tensors are quantized per layer precision; ReLU masking uses the
    /// saved forward outputs; pool routing uses the saved argmaxes.
    ///
    /// Convenience wrapper over [`NativeModel::backward_in`] with a
    /// throwaway scratch arena; hot loops (the trainer, the batch engine)
    /// should hold a [`Scratch`] and call `backward_in` directly.
    pub fn backward(
        &mut self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        ops: &mut OpCounter,
    ) -> BwdResult {
        self.backward_in(trace, head_err, masks, &mut Scratch::new(), ops)
    }

    /// [`NativeModel::backward`] with an explicit scratch arena backing the
    /// GEMM-routed backward kernels. Updates the model's own error
    /// observers; delegates to [`NativeModel::backward_with`], which the
    /// batch engine calls directly with per-worker observer copies.
    pub fn backward_in(
        &mut self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> BwdResult {
        // Refresh any backward pack the optimizer invalidated since the
        // last pass (per-layer version compare; a no-op when clean).
        self.warm_packs();
        let mut obs = std::mem::take(&mut self.state.err_obs);
        let r = self.backward_with(trace, head_err, masks, &mut obs, scratch, ops);
        self.state.err_obs = obs;
        r
    }

    /// [`NativeModel::backward_in`] against caller-provided error
    /// observers. The model itself is only read, so concurrent workers can
    /// each run backward passes over a shared `&NativeModel` with their own
    /// observer copies (and their own scratch arenas) and merge the
    /// observations deterministically afterwards.
    ///
    /// Backward compute is engine-routed like the forward pass:
    /// non-depthwise convs lower `dW` onto an error × im2col A·Bᵀ GEMM and
    /// `dX` onto a flipped-weights × backward-im2col GEMM; depthwise convs
    /// run the register-blocked depthwise kernels (`kernels::dwconv`);
    /// linear layers use the shared GEMM cores as degenerate cases.
    /// Sparse-update masks skip whole GEMM rows — for depthwise, whole
    /// channel planes (see DESIGN.md §2 and §5).
    pub fn backward_with(
        &self,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        err_obs: &mut [MinMaxObserver],
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> BwdResult {
        self.shared.plan.run_backward(self, trace, head_err, masks, err_obs, scratch, ops)
    }

    /// Plain inference: predicted class for one sample.
    pub fn predict(&self, x: &TensorF32, ops: &mut OpCounter) -> usize {
        let t = self.forward(x, ops);
        softmax::predict(&t.logits)
    }

    /// Test-set accuracy.
    pub fn evaluate(&self, xs: &[TensorF32], ys: &[usize]) -> f32 {
        let mut ops = OpCounter::new();
        let correct = xs.iter().zip(ys).filter(|(x, &y)| self.predict(x, &mut ops) == y).count();
        correct as f32 / xs.len().max(1) as f32
    }
}
