//! Plan-owned packed-weight cache for the backward-input GEMM.
//!
//! PR 2 lowered `dX = wt_flip × colE`, but re-ran the flipped-transposed
//! weight packing ([`crate::kernels::gemm::pack_wt_flip_u8`] /
//! `pack_wt_flip_f32`) from scratch on *every sample* — a pure function of
//! the layer weights, which only change when the optimizer steps. This
//! module caches the **dense** pack (no sparse mask) per layer, owned by
//! the deployed model next to its compiled plan:
//!
//!  * **ownership** — one [`PackCache`] per `NativeModel`, sized at build
//!    to one slot per layer; slots are populated for every conv layer
//!    whose backward-input kernel the plan can reach (`layer > stop`):
//!    dense convs hold the flipped-transposed GEMM pack, depthwise convs
//!    the 180°-flipped per-channel pack consumed by the depthwise engine
//!    (`kernels::dwconv`). Depthwise packs are per-channel, so — unlike
//!    the dense packs — they also serve *masked* calls: a `DynamicSparse`
//!    mask skips whole planes of the same cached pack.
//!  * **invalidation** — every layer carries a parameter *version*
//!    (`NativeModel::touch_layer` bumps it; the optimizers call it on each
//!    applied update, `reset_trainable` on re-init). A cache entry is
//!    valid only while its recorded version matches; `warm_packs`
//!    re-packs exactly the stale entries (a no-op when nothing changed).
//!  * **sparse masks** — a `DynamicSparse` mask selects a *subset* of
//!    GEMM rows, so a masked pack differs per sample; masked calls bypass
//!    the cache entirely and pack into the scratch arena exactly as
//!    before (bit-identical fallback). Dense calls that find a stale
//!    entry (a missed `warm_packs`) take the same fallback, so staleness
//!    can cost time but never correctness.
//!  * **concurrency** — batch workers execute the plan over a shared
//!    `&NativeModel`; they read the cache through a shared reference and
//!    never write it (`train_batch` warms once, before sharding). The
//!    hit/miss telemetry uses relaxed atomics so shared-reference readers
//!    can count.
//!
//! The `ScratchSpec` of the compiled plan no longer pre-sizes the
//! flipped-weight buffers (`wt_u8`/`wt_f32`): the dense packs live here,
//! and the masked fallback grows its scratch buffer on first use only.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::kernels::simd::TilePref;
use crate::quant::subbyte::WBits;

/// The plan compiler's autotuned micro-kernel choice for one layer: a
/// [`TilePref`] per kernel direction (see `kernels::simd::tune`). The
/// preference is a pure function of the layer *geometry* — deliberately
/// **not** a concrete ISA — so a compiled plan stays valid across hosts
/// and across `TT_KERNEL` overrides; the concrete micro-kernel is resolved
/// at dispatch time from the preference, the runtime mode, and the
/// detected ISA (`kernels::simd::resolve`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelChoice {
    /// Forward kernel (GEMM or depthwise AXPY map).
    pub fwd: TilePref,
    /// Backward-input kernel (transposed GEMM or flipped depthwise map).
    pub bwd_input: TilePref,
    /// Backward-weight kernel (A·Bᵀ dot reductions).
    pub bwd_weight: TilePref,
}

/// A cached dense backward pack, tagged by the precision it was built
/// for. A layer is only ever one precision per deployment, but the tag
/// makes serving a stale other-precision pack impossible even if a
/// future schedule switches a layer's precision between warms: a
/// version bump plus a re-pack of one precision can never revalidate
/// leftover bytes of the other.
enum PackBuf {
    /// Never built.
    Empty,
    /// Flipped-transposed weights `[Cin, Cout·Kh·Kw]` (uint8 dense convs).
    U8(Vec<u8>),
    /// f32 twin (float32 dense convs).
    F32(Vec<f32>),
    /// 180°-flipped per-channel depthwise kernels `[C, Kh·Kw]`
    /// (`kernels::dwconv::pack_dw_flip_u8`, uint8 depthwise convs).
    /// Distinct from [`PackBuf::U8`] so a dense pack can never be served
    /// to the depthwise engine or vice versa, even across re-warms.
    DwU8(Vec<u8>),
    /// f32 twin of [`PackBuf::DwU8`] (float32 depthwise convs).
    DwF32(Vec<f32>),
    /// [`PackBuf::U8`] stored packed at a sub-byte width (`quant::subbyte`,
    /// layers deployed as `LayerParams::Qp`): the flipped-transposed lane
    /// sequence packed *after* flipping, so a plain lane unpack restores
    /// the flipped layout. The width tag travels with the bytes — a pack
    /// built at one width can never be unpacked at another.
    U8Packed(WBits, Vec<u8>),
    /// Packed twin of [`PackBuf::DwU8`] (sub-byte depthwise convs).
    DwU8Packed(WBits, Vec<u8>),
}

/// One layer's cached dense backward pack plus the parameter version it
/// was built from.
struct PackEntry {
    /// Parameter version at pack time; 0 = never built (versions start
    /// at 1).
    version: u64,
    buf: PackBuf,
}

impl Default for PackEntry {
    fn default() -> PackEntry {
        PackEntry { version: 0, buf: PackBuf::Empty }
    }
}

/// Cache telemetry: `hits`/`misses` count dense backward-input lookups,
/// `builds` counts actual re-packs performed by warming.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PackStats {
    pub hits: u64,
    pub misses: u64,
    pub builds: u64,
}

/// Per-layer packed-weight cache (see the module docs).
pub struct PackCache {
    entries: Vec<PackEntry>,
    /// Per-layer autotuned kernel choices, installed from the compiled
    /// plan (`ExecPlan::kernel_choices`) when a session is built; `None`
    /// for layers the tuner never visits (activations, losses, …).
    choices: Vec<Option<KernelChoice>>,
    hits: AtomicU64,
    misses: AtomicU64,
    builds: AtomicU64,
}

impl PackCache {
    /// Empty cache with one slot per graph layer.
    pub fn new(n_layers: usize) -> PackCache {
        PackCache {
            entries: (0..n_layers).map(|_| PackEntry::default()).collect(),
            choices: vec![None; n_layers],
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            builds: AtomicU64::new(0),
        }
    }

    /// Install the compiled plan's per-layer kernel choices (length must
    /// match the layer count this cache was sized for).
    pub fn install_choices(&mut self, choices: &[Option<KernelChoice>]) {
        assert_eq!(choices.len(), self.choices.len(), "kernel choice slot count");
        self.choices.copy_from_slice(choices);
    }

    /// The autotuned kernel choice for layer `l`, if the plan recorded
    /// one. Ops fall back to [`crate::kernels::simd::KernelSel::Auto`]
    /// when absent.
    pub fn choice(&self, l: usize) -> Option<KernelChoice> {
        self.choices.get(l).copied().flatten()
    }

    /// The dense u8 pack for layer `l`, if the cached one was built at
    /// exactly `version`. Counts a hit or miss; a miss means the caller
    /// falls back to packing into scratch (correct, just slower).
    pub fn wt_u8(&self, l: usize, version: u64) -> Option<&[u8]> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::U8(b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// f32 twin of [`PackCache::wt_u8`].
    pub fn wt_f32(&self, l: usize, version: u64) -> Option<&[f32]> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::F32(b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Install/refresh the dense u8 pack for layer `l` at `version`.
    /// No-op when the entry is already fresh; otherwise `build` fills a
    /// cleared buffer (reusing the allocation when the slot already held
    /// a u8 pack).
    pub fn put_u8(&mut self, l: usize, version: u64, build: impl FnOnce(&mut Vec<u8>)) {
        let e = &mut self.entries[l];
        if e.version == version && matches!(&e.buf, PackBuf::U8(b) if !b.is_empty()) {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::U8(mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::U8(buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// f32 twin of [`PackCache::put_u8`].
    pub fn put_f32(&mut self, l: usize, version: u64, build: impl FnOnce(&mut Vec<f32>)) {
        let e = &mut self.entries[l];
        if e.version == version && matches!(&e.buf, PackBuf::F32(b) if !b.is_empty()) {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::F32(mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::F32(buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// The flipped depthwise pack for layer `l`, if the cached one was
    /// built at exactly `version`. Unlike the dense packs, the depthwise
    /// pack is consulted for masked calls too: channels are independent,
    /// so a `DynamicSparse` mask skips whole planes of the *same* dense
    /// pack rather than needing a per-sample re-pack.
    pub fn dw_u8(&self, l: usize, version: u64) -> Option<&[u8]> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::DwU8(b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// f32 twin of [`PackCache::dw_u8`].
    pub fn dw_f32(&self, l: usize, version: u64) -> Option<&[f32]> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::DwF32(b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Install/refresh the flipped depthwise u8 pack for layer `l` at
    /// `version` (see [`PackCache::put_u8`] for the rebuild contract).
    pub fn put_dw_u8(&mut self, l: usize, version: u64, build: impl FnOnce(&mut Vec<u8>)) {
        let e = &mut self.entries[l];
        if e.version == version && matches!(&e.buf, PackBuf::DwU8(b) if !b.is_empty()) {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::DwU8(mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::DwU8(buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// f32 twin of [`PackCache::put_dw_u8`].
    pub fn put_dw_f32(&mut self, l: usize, version: u64, build: impl FnOnce(&mut Vec<f32>)) {
        let e = &mut self.entries[l];
        if e.version == version && matches!(&e.buf, PackBuf::DwF32(b) if !b.is_empty()) {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::DwF32(mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::DwF32(buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// The sub-byte-packed flipped-transposed pack for layer `l`, with the
    /// width it was packed at, if the cached one was built at exactly
    /// `version` (sub-byte twin of [`PackCache::wt_u8`]).
    pub fn wt_u8_packed(&self, l: usize, version: u64) -> Option<(&[u8], WBits)> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::U8Packed(bits, b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((b, *bits))
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Sub-byte twin of [`PackCache::dw_u8`]: the packed flipped depthwise
    /// pack with its width. Note the depthwise *consumer* always unpacks
    /// the whole pack (per-channel kernel planes are not byte-aligned at
    /// sub-byte widths), so masked calls still share this dense entry.
    pub fn dw_u8_packed(&self, l: usize, version: u64) -> Option<(&[u8], WBits)> {
        let e = &self.entries[l];
        match &e.buf {
            PackBuf::DwU8Packed(bits, b) if e.version == version && !b.is_empty() => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((b, *bits))
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Install/refresh the sub-byte-packed dense pack for layer `l` at
    /// `version` (see [`PackCache::put_u8`] for the rebuild contract).
    pub fn put_u8_packed(
        &mut self,
        l: usize,
        version: u64,
        bits: WBits,
        build: impl FnOnce(&mut Vec<u8>),
    ) {
        let e = &mut self.entries[l];
        if e.version == version
            && matches!(&e.buf, PackBuf::U8Packed(bt, b) if *bt == bits && !b.is_empty())
        {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::U8Packed(_, mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::U8Packed(bits, buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// Sub-byte twin of [`PackCache::put_dw_u8`].
    pub fn put_dw_u8_packed(
        &mut self,
        l: usize,
        version: u64,
        bits: WBits,
        build: impl FnOnce(&mut Vec<u8>),
    ) {
        let e = &mut self.entries[l];
        if e.version == version
            && matches!(&e.buf, PackBuf::DwU8Packed(bt, b) if *bt == bits && !b.is_empty())
        {
            return;
        }
        let mut buf = match std::mem::replace(&mut e.buf, PackBuf::Empty) {
            PackBuf::DwU8Packed(_, mut b) => {
                b.clear();
                b
            }
            _ => Vec::new(),
        };
        build(&mut buf);
        e.buf = PackBuf::DwU8Packed(bits, buf);
        e.version = version;
        self.builds.fetch_add(1, Ordering::Relaxed);
    }

    /// Current telemetry snapshot.
    pub fn stats(&self) -> PackStats {
        PackStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            builds: self.builds.load(Ordering::Relaxed),
        }
    }

    /// Bytes held by the cached packs (memory accounting).
    pub fn reserved_bytes(&self) -> usize {
        self.entries
            .iter()
            .map(|e| match &e.buf {
                PackBuf::Empty => 0,
                PackBuf::U8(b) | PackBuf::DwU8(b) => b.len(),
                // packed entries report their *packed* byte count — the
                // whole point of the sub-byte store
                PackBuf::U8Packed(_, b) | PackBuf::DwU8Packed(_, b) => b.len(),
                PackBuf::F32(b) | PackBuf::DwF32(b) => b.len() * 4,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_entry_hits_and_stale_entry_misses() {
        let mut c = PackCache::new(3);
        assert!(c.wt_u8(1, 1).is_none(), "empty cache must miss");
        c.put_u8(1, 1, |dst| dst.extend_from_slice(&[7, 8, 9]));
        assert_eq!(c.wt_u8(1, 1), Some(&[7u8, 8, 9][..]));
        // version bump invalidates; re-put rebuilds
        assert!(c.wt_u8(1, 2).is_none());
        c.put_u8(1, 2, |dst| dst.extend_from_slice(&[1]));
        assert_eq!(c.wt_u8(1, 2), Some(&[1u8][..]));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.builds), (2, 2, 2));
    }

    #[test]
    fn put_is_noop_when_fresh() {
        let mut c = PackCache::new(1);
        c.put_u8(0, 5, |dst| dst.push(42));
        c.put_u8(0, 5, |_| panic!("fresh entry must not rebuild"));
        assert_eq!(c.wt_u8(0, 5), Some(&[42u8][..]));
        assert_eq!(c.stats().builds, 1);
    }

    #[test]
    fn u8_and_f32_slots_are_independent_per_precision() {
        let mut c = PackCache::new(2);
        c.put_f32(0, 1, |dst| dst.extend_from_slice(&[1.5, 2.5]));
        assert!(c.wt_u8(0, 1).is_none(), "u8 lookup must not see an f32 pack");
        assert_eq!(c.wt_f32(0, 1), Some(&[1.5f32, 2.5][..]));
        assert_eq!(c.reserved_bytes(), 8);
    }

    #[test]
    fn depthwise_and_dense_slots_never_cross_serve() {
        let mut c = PackCache::new(2);
        c.put_dw_u8(0, 1, |dst| dst.extend_from_slice(&[4, 5]));
        // a dense lookup must not see the depthwise pack (and vice versa)
        assert!(c.wt_u8(0, 1).is_none(), "dense lookup served a depthwise pack");
        assert_eq!(c.dw_u8(0, 1), Some(&[4u8, 5][..]));
        c.put_u8(1, 1, |dst| dst.push(9));
        assert!(c.dw_u8(1, 1).is_none(), "depthwise lookup served a dense pack");
        // version bumps invalidate depthwise entries exactly like dense ones
        assert!(c.dw_u8(0, 2).is_none());
        c.put_dw_u8(0, 2, |dst| dst.push(7));
        assert_eq!(c.dw_u8(0, 2), Some(&[7u8][..]));
        assert_eq!(c.reserved_bytes(), 2);
    }

    #[test]
    fn depthwise_f32_slot_roundtrips_and_is_noop_when_fresh() {
        let mut c = PackCache::new(1);
        c.put_dw_f32(0, 3, |dst| dst.extend_from_slice(&[1.0, 2.0]));
        c.put_dw_f32(0, 3, |_| panic!("fresh depthwise entry must not rebuild"));
        assert_eq!(c.dw_f32(0, 3), Some(&[1.0f32, 2.0][..]));
        assert!(c.wt_f32(0, 3).is_none());
        assert_eq!(c.reserved_bytes(), 8);
    }

    #[test]
    fn packed_slots_are_width_tagged_and_report_packed_bytes() {
        let mut c = PackCache::new(2);
        c.put_u8_packed(0, 1, WBits::W4, |dst| dst.extend_from_slice(&[0xA3, 0x07]));
        // a u8 lookup must never see a packed pack (it would misread lanes)
        assert!(c.wt_u8(0, 1).is_none(), "u8 lookup served a packed pack");
        assert_eq!(c.wt_u8_packed(0, 1), Some((&[0xA3u8, 0x07][..], WBits::W4)));
        // a fresh same-width re-put is a no-op; a width change rebuilds
        c.put_u8_packed(0, 1, WBits::W4, |_| panic!("fresh packed entry must not rebuild"));
        c.put_u8_packed(0, 1, WBits::W2, |dst| dst.push(0b11_10_01_00));
        assert_eq!(c.wt_u8_packed(0, 1), Some((&[0b11_10_01_00u8][..], WBits::W2)));
        // depthwise packed slots are independent of dense packed slots
        c.put_dw_u8_packed(1, 1, WBits::W4, |dst| dst.push(0x21));
        assert!(c.wt_u8_packed(1, 1).is_none(), "dense lookup served a depthwise pack");
        assert_eq!(c.dw_u8_packed(1, 1), Some((&[0x21u8][..], WBits::W4)));
        // reserved bytes count the packed lengths
        assert_eq!(c.reserved_bytes(), 1 + 1);
    }

    #[test]
    fn precision_tag_prevents_cross_precision_staleness() {
        let mut c = PackCache::new(1);
        c.put_f32(0, 1, |dst| dst.extend_from_slice(&[1.0, 2.0]));
        // Switching the slot to u8 at a newer version must not make the
        // old f32 bytes look fresh again at that version.
        c.put_u8(0, 2, |dst| dst.extend_from_slice(&[9]));
        assert!(c.wt_f32(0, 2).is_none(), "stale f32 pack revalidated by a u8 re-pack");
        assert_eq!(c.wt_u8(0, 2), Some(&[9u8][..]));
    }
}
