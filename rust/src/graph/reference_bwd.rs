//! Backward half of the straight-line reference executor — see
//! [`crate::graph::reference`] for the contract. Split into its own file
//! only to keep every graph source file within the ~400-line budget; the
//! code is the pre-plan implementation, verbatim.

use crate::graph::act::{observe_saturation, propagate_qp, structure_norms, Act, LayerParams};
use crate::graph::exec::{BwdResult, FwdTrace, LayerGrads, MaskProvider, NativeModel};
use crate::graph::reference::in_qp;
use crate::graph::{LayerKind, Precision};
use crate::kernels::{fconv, flinear, kept_count, pool, qconv, qlinear, OpCounter};
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::quant::QTensor;
use crate::tensor::TensorF32;

/// The pre-plan backward pass, byte-for-byte, against caller-provided
/// error observers.
pub fn backward_reference(
    m: &NativeModel,
    trace: &FwdTrace,
    head_err: TensorF32,
    masks: &mut dyn MaskProvider,
    err_obs: &mut [MinMaxObserver],
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> BwdResult {
    let n = m.shared.def.layers.len();
    assert_eq!(err_obs.len(), n, "one error observer per layer");
    let stop = m.shared.def.first_trainable().unwrap_or(n);
    let mut grads: Vec<Option<LayerGrads>> = (0..n).map(|_| None).collect();

    // Error w.r.t. the output of layer `i`, in layer i's precision.
    let mut err: Act = match m.shared.prec[n - 1] {
        Precision::Float32 => Act::F(head_err),
        Precision::Uint8 => {
            let obs = &mut err_obs[n - 1];
            obs.observe(head_err.data());
            Act::Q(QTensor::quantize_with(&head_err, obs.qparams()))
        }
    };

    for i in (stop..n).rev() {
        let l = m.shared.def.layers[i].clone();
        // Coerce error into this layer's precision (mixed boundary).
        err = match (m.shared.prec[i], err) {
            (Precision::Uint8, Act::F(t)) => {
                let obs = &mut err_obs[i];
                obs.observe(t.data());
                Act::Q(QTensor::quantize_with(&t, obs.qparams()))
            }
            (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
            (_, e) => e,
        };

        let layer_in: Act = if i == 0 { trace.input.clone() } else { trace.acts[i - 1].clone() };
        // Input act coerced to this layer's precision (as in forward).
        let layer_in = match (m.shared.prec[i], layer_in) {
            (Precision::Uint8, Act::F(t)) => Act::Q(QTensor::quantize_with(&t, in_qp(m, i))),
            (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
            (_, a) => a,
        };

        match (&l.kind, &mut err) {
            (LayerKind::Conv { geom, relu }, e) => {
                let keep = if l.trainable {
                    let norms = structure_norms(e);
                    masks.mask(i, &norms)
                } else {
                    None
                };
                match e {
                    Act::Q(eq) => {
                        if *relu {
                            if let Act::Q(y) = &trace.acts[i] {
                                qconv::relu_bwd_mask_q(eq, y, ops);
                            }
                        }
                        // Packed sub-byte weights: fully unpack and run the
                        // identical u8 body (the reference path is the slow
                        // golden oracle — see `forward_reference`).
                        let unpacked;
                        let (w, _) = match &m.state.params[i] {
                            LayerParams::Q { w, bias } => (w, bias),
                            LayerParams::Qp { w, bias } => {
                                unpacked = w.to_qtensor();
                                (&unpacked, bias)
                            }
                            other => panic!(
                                "layer {i} ({}): backward expected quantized (uint8) conv \
                                 params, found {}",
                                l.name,
                                other.flavor()
                            ),
                        };
                        let xq = match &layer_in {
                            Act::Q(x) => x,
                            Act::F(_) => panic!(
                                "layer {i} ({}): backward expected a quantized input \
                                 activation, found float32",
                                l.name
                            ),
                        };
                        if l.trainable {
                            let (gw, gb) = if geom.depthwise {
                                qconv::qconv2d_bwd_weight(eq, xq, geom, keep.as_deref(), ops)
                            } else {
                                qconv::qconv2d_bwd_weight_gemm(
                                    eq,
                                    xq,
                                    geom,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                )
                            };
                            let total = geom.cout;
                            let kept = kept_count(keep.as_deref(), total);
                            grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                        }
                        if i > stop {
                            let (h, w_in) = (layer_in.shape()[1], layer_in.shape()[2]);
                            let prev_obs = &mut err_obs[i - 1];
                            let out_qp = propagate_qp(prev_obs, eq, ops);
                            err = if geom.depthwise {
                                Act::Q(qconv::qconv2d_bwd_input(
                                    eq,
                                    w,
                                    geom,
                                    h,
                                    w_in,
                                    out_qp,
                                    keep.as_deref(),
                                    ops,
                                ))
                            } else {
                                Act::Q(qconv::qconv2d_bwd_input_gemm(
                                    eq,
                                    w,
                                    geom,
                                    h,
                                    w_in,
                                    out_qp,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                ))
                            };
                            observe_saturation(&mut err_obs[i - 1], &err);
                        }
                    }
                    Act::F(ef) => {
                        if *relu {
                            if let Act::F(y) = &trace.acts[i] {
                                fconv::relu_bwd_mask_f(ef, y, ops);
                            }
                        }
                        let (w, _) = match &m.state.params[i] {
                            LayerParams::F { w, bias } => (w, bias),
                            other => panic!(
                                "layer {i} ({}): backward expected float32 conv params, \
                                 found {}",
                                l.name,
                                other.flavor()
                            ),
                        };
                        let xf = match &layer_in {
                            Act::F(x) => x,
                            Act::Q(_) => panic!(
                                "layer {i} ({}): backward expected a float32 input \
                                 activation, found quantized",
                                l.name
                            ),
                        };
                        if l.trainable {
                            let (gw, gb) = if geom.depthwise {
                                fconv::fconv2d_bwd_weight(ef, xf, geom, keep.as_deref(), ops)
                            } else {
                                fconv::fconv2d_bwd_weight_gemm(
                                    ef,
                                    xf,
                                    geom,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                )
                            };
                            let total = geom.cout;
                            let kept = kept_count(keep.as_deref(), total);
                            grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                        }
                        if i > stop {
                            let (h, w_in) = (layer_in.shape()[1], layer_in.shape()[2]);
                            err = if geom.depthwise {
                                Act::F(fconv::fconv2d_bwd_input(
                                    ef,
                                    w,
                                    geom,
                                    h,
                                    w_in,
                                    keep.as_deref(),
                                    ops,
                                ))
                            } else {
                                Act::F(fconv::fconv2d_bwd_input_gemm(
                                    ef,
                                    w,
                                    geom,
                                    h,
                                    w_in,
                                    keep.as_deref(),
                                    scratch,
                                    ops,
                                ))
                            };
                        }
                    }
                }
            }
            (LayerKind::Linear { .. }, e) => {
                let relu = matches!(l.kind, LayerKind::Linear { relu: true, .. });
                let keep = if l.trainable {
                    let norms = structure_norms(e);
                    masks.mask(i, &norms)
                } else {
                    None
                };
                match e {
                    Act::Q(eq) => {
                        if relu {
                            if let Act::Q(y) = &trace.acts[i] {
                                qconv::relu_bwd_mask_q(eq, y, ops);
                            }
                        }
                        let unpacked;
                        let (w, _) = match &m.state.params[i] {
                            LayerParams::Q { w, bias } => (w, bias),
                            LayerParams::Qp { w, bias } => {
                                unpacked = w.to_qtensor();
                                (&unpacked, bias)
                            }
                            other => panic!(
                                "layer {i} ({}): backward expected quantized (uint8) linear \
                                 params, found {}",
                                l.name,
                                other.flavor()
                            ),
                        };
                        let xq = match &layer_in {
                            Act::Q(x) => x,
                            Act::F(_) => panic!(
                                "layer {i} ({}): backward expected a quantized input \
                                 activation, found float32",
                                l.name
                            ),
                        };
                        if l.trainable {
                            let (gw, gb) = qlinear::qlinear_bwd_weight_gemm(
                                eq,
                                xq,
                                keep.as_deref(),
                                scratch,
                                ops,
                            );
                            let total = eq.len();
                            let kept = kept_count(keep.as_deref(), total);
                            grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                        }
                        if i > stop {
                            let prev_obs = &mut err_obs[i - 1];
                            let out_qp = propagate_qp(prev_obs, eq, ops);
                            err = Act::Q(qlinear::qlinear_bwd_input_gemm(
                                eq,
                                w,
                                out_qp,
                                keep.as_deref(),
                                scratch,
                                ops,
                            ));
                            observe_saturation(&mut err_obs[i - 1], &err);
                        }
                    }
                    Act::F(ef) => {
                        if relu {
                            if let Act::F(y) = &trace.acts[i] {
                                fconv::relu_bwd_mask_f(ef, y, ops);
                            }
                        }
                        let (w, _) = match &m.state.params[i] {
                            LayerParams::F { w, bias } => (w, bias),
                            other => panic!(
                                "layer {i} ({}): backward expected float32 linear params, \
                                 found {}",
                                l.name,
                                other.flavor()
                            ),
                        };
                        let xf = match &layer_in {
                            Act::F(x) => x,
                            Act::Q(_) => panic!(
                                "layer {i} ({}): backward expected a float32 input \
                                 activation, found quantized",
                                l.name
                            ),
                        };
                        if l.trainable {
                            let (gw, gb) =
                                flinear::flinear_bwd_weight_gemm(ef, xf, keep.as_deref(), ops);
                            let total = ef.len();
                            let kept = kept_count(keep.as_deref(), total);
                            grads[i] = Some(LayerGrads { gw, gb, kept: (kept, total) });
                        }
                        if i > stop {
                            err = Act::F(flinear::flinear_bwd_input_gemm(
                                ef,
                                w,
                                keep.as_deref(),
                                scratch,
                                ops,
                            ));
                        }
                    }
                }
            }
            (LayerKind::MaxPool { .. }, e) => {
                if i > stop {
                    let am = trace.argmax[i].as_ref().expect("pool argmax");
                    err = match e {
                        Act::Q(eq) => {
                            Act::Q(pool::qmaxpool_bwd(eq, am, &layer_in.shape().to_vec(), ops))
                        }
                        Act::F(ef) => {
                            Act::F(pool::fmaxpool_bwd(ef, am, &layer_in.shape().to_vec(), ops))
                        }
                    };
                }
            }
            (LayerKind::GlobalAvgPool, e) => {
                if i > stop {
                    err = match e {
                        Act::Q(eq) => {
                            let prev_obs = &mut err_obs[i - 1];
                            let out_qp = propagate_qp(prev_obs, eq, ops);
                            Act::Q(pool::qgap_bwd(eq, &layer_in.shape().to_vec(), out_qp, ops))
                        }
                        Act::F(ef) => Act::F(pool::fgap_bwd(ef, &layer_in.shape().to_vec(), ops)),
                    };
                }
            }
            (LayerKind::Flatten, e) => {
                if i > stop {
                    err = e.reshaped(&layer_in.shape().to_vec());
                }
            }
        }
    }

    BwdResult { grads }
}
