//! Straight-line reference executor — the pre-plan implementation of the
//! forward and backward passes, retained verbatim as the golden parity
//! oracle for the compiled layer-op plan (`graph::plan`).
//!
//! Production code must not call these: [`NativeModel::forward_in`] and
//! [`NativeModel::backward_with`] dispatch over the compiled plan. The
//! property tests in `tests/plan_parity.rs` run both paths over all three
//! models × all three configurations on random inputs and assert
//! bit-identical logits, activations, gradients, observer updates and
//! [`OpCounter`] totals — the contract that keeps refactors of the planned
//! executor honest.

use crate::graph::act::{Act, LayerParams};
use crate::graph::exec::{FwdTrace, NativeModel};
use crate::graph::{LayerKind, Precision};
use crate::kernels::{fconv, flinear, pool, qconv, qlinear, OpCounter};
use crate::memplan::Scratch;
use crate::quant::{quantize_bias, QTensor};
use crate::tensor::TensorF32;

pub use crate::graph::reference_bwd::backward_reference;

/// Quantization parameters of the input to layer `i` (pools/flatten pass
/// qparams through).
pub(crate) fn in_qp(m: &NativeModel, i: usize) -> crate::quant::QParams {
    if i == 0 {
        m.shared.input_qp
    } else {
        let mut j = i;
        while j > 0 {
            j -= 1;
            match m.shared.def.layers[j].kind {
                LayerKind::Conv { .. } | LayerKind::Linear { .. } | LayerKind::GlobalAvgPool => {
                    return m.state.act_qp[j];
                }
                _ => {}
            }
        }
        m.shared.input_qp
    }
}

/// The pre-plan forward pass, byte-for-byte.
pub fn forward_reference(
    m: &NativeModel,
    x: &TensorF32,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> FwdTrace {
    let n = m.shared.def.layers.len();
    let mut acts: Vec<Act> = Vec::with_capacity(n);
    let mut argmax: Vec<Option<Vec<u32>>> = vec![None; n];

    let input = match m.shared.prec[0] {
        Precision::Uint8 => Act::Q(QTensor::quantize_with(x, m.shared.input_qp)),
        Precision::Float32 => Act::F(x.clone()),
    };

    let mut cur = input.clone();
    for (i, l) in m.shared.def.layers.iter().enumerate() {
        // coerce the running activation into this layer's precision
        cur = match (m.shared.prec[i], cur) {
            (Precision::Uint8, Act::F(t)) => Act::Q(QTensor::quantize_with(&t, in_qp(m, i))),
            (Precision::Float32, Act::Q(t)) => Act::F(t.dequantize()),
            (_, c) => c,
        };
        cur = match (&l.kind, &cur) {
            (LayerKind::Conv { geom, relu }, Act::Q(xq)) => {
                // Packed sub-byte weights are fully unpacked here: the
                // reference executor is the slow golden path, and running
                // the identical u8 body keeps parity with the planned
                // executor trivial at every width.
                let unpacked;
                let (w, bias) = match &m.state.params[i] {
                    LayerParams::Q { w, bias } => (w, bias),
                    LayerParams::Qp { w, bias } => {
                        unpacked = w.to_qtensor();
                        (&unpacked, bias)
                    }
                    other => panic!(
                        "layer {i} ({}): expected quantized (uint8) conv params, found {}",
                        l.name,
                        other.flavor()
                    ),
                };
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                let y = if geom.depthwise {
                    qconv::qconv2d_fwd(xq, w, &bq, geom, m.state.act_qp[i], *relu, ops)
                } else {
                    qconv::qconv2d_fwd_gemm(
                        xq,
                        w,
                        &bq,
                        geom,
                        m.state.act_qp[i],
                        *relu,
                        scratch,
                        ops,
                    )
                };
                Act::Q(y)
            }
            (LayerKind::Conv { geom, relu }, Act::F(xf)) => {
                let (w, bias) = match &m.state.params[i] {
                    LayerParams::F { w, bias } => (w, bias),
                    other => panic!(
                        "layer {i} ({}): expected float32 conv params, found {}",
                        l.name,
                        other.flavor()
                    ),
                };
                let y = if geom.depthwise {
                    fconv::fconv2d_fwd(xf, w, bias, geom, *relu, ops)
                } else {
                    fconv::fconv2d_fwd_gemm(xf, w, bias, geom, *relu, scratch, ops)
                };
                Act::F(y)
            }
            (LayerKind::Linear { relu, .. }, Act::Q(xq)) => {
                let unpacked;
                let (w, bias) = match &m.state.params[i] {
                    LayerParams::Q { w, bias } => (w, bias),
                    LayerParams::Qp { w, bias } => {
                        unpacked = w.to_qtensor();
                        (&unpacked, bias)
                    }
                    other => panic!(
                        "layer {i} ({}): expected quantized (uint8) linear params, found {}",
                        l.name,
                        other.flavor()
                    ),
                };
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                Act::Q(qlinear::qlinear_fwd(xq, w, &bq, m.state.act_qp[i], *relu, ops))
            }
            (LayerKind::Linear { relu, .. }, Act::F(xf)) => {
                let (w, bias) = match &m.state.params[i] {
                    LayerParams::F { w, bias } => (w, bias),
                    other => panic!(
                        "layer {i} ({}): expected float32 linear params, found {}",
                        l.name,
                        other.flavor()
                    ),
                };
                Act::F(flinear::flinear_fwd(xf, w, bias, *relu, ops))
            }
            (LayerKind::MaxPool { k }, Act::Q(xq)) => {
                let o = pool::qmaxpool_fwd(xq, *k, ops);
                argmax[i] = Some(o.argmax);
                Act::Q(o.y)
            }
            (LayerKind::MaxPool { k }, Act::F(xf)) => {
                let o = pool::fmaxpool_fwd(xf, *k, ops);
                argmax[i] = Some(o.argmax);
                Act::F(o.y)
            }
            (LayerKind::GlobalAvgPool, Act::Q(xq)) => {
                Act::Q(pool::qgap_fwd(xq, m.state.act_qp[i], ops))
            }
            (LayerKind::GlobalAvgPool, Act::F(xf)) => Act::F(pool::fgap_fwd(xf, ops)),
            (LayerKind::Flatten, a) => {
                let flat: usize = a.shape().iter().product();
                a.reshaped(&[flat])
            }
        };
        acts.push(cur.clone());
    }

    let logits = acts.last().unwrap().to_float().into_vec();
    // The reference executor never records fused saturation counts —
    // `measure_saturation` falls back to its activation sweep.
    let sat = vec![None; acts.len()];
    FwdTrace { input, acts, argmax, sat, logits }
}
