//! Compile-once execution planning: lower a `ModelDef × DnnConfig` pair
//! into a trait-based layer-op schedule plus an activation-liveness arena
//! plan.
//!
//! The pre-plan executor re-derived everything per sample: precision
//! coercions were matched dynamically, parameter flavors probed, shapes
//! re-inferred, and activations allocated ad hoc. [`ExecPlan::compile`]
//! does all of that exactly once, at deployment:
//!
//!  * **op lowering** — each graph layer becomes one boxed
//!    [`LayerOp`](crate::graph::ops::LayerOp) (`QConvOp` / `FConvOp` /
//!    `QLinearOp` / `FLinearOp` / `MaxPoolOp` / `GlobalAvgPoolOp` /
//!    `FlattenOp`) carrying pre-resolved geometry, input shapes and
//!    quantization-parameter slots; the precision coercions that hid
//!    inside the old forward/backward loops become explicit
//!    `QuantizeOp` / `DequantizeOp` boundary steps;
//!  * **liveness** — the forward+backward schedule of the real plan
//!    (including the zero-copy `Flatten` aliasing and the transient
//!    boundary staging buffers) is lowered onto
//!    [`crate::memplan::allocate_arena`], giving `planned_peak_bytes` and
//!    per-buffer arena offsets from the plan itself rather than the
//!    analytic estimate;
//!  * **scratch sizing** — every GEMM scratch request the ops can make is
//!    accumulated into a [`ScratchSpec`], so
//!    [`Scratch::for_spec`](crate::memplan::Scratch::for_spec) pre-sizes
//!    one arena that serves the whole training step with zero growth, for
//!    every configuration (uint8, mixed *and* float32).
//!
//! Plan construction is `O(layers)` — independent of sample count and of
//! spatial extents (only shape arithmetic, no tensor allocation). The
//! planned passes are bit-identical to the straight-line reference
//! executor ([`crate::graph::reference`]): same kernels, same call order,
//! same `OpCounter` accounting (enforced by `tests/plan_parity.rs`).

use crate::graph::act::Act;
use crate::graph::exec::{BwdResult, FwdTrace, MaskProvider, NativeModel};
use crate::graph::ops::{
    DequantizeOp, ExecCtx, FConvOp, FLinearOp, FlattenOp, GlobalAvgPoolOp, LayerOp, MaxPoolOp,
    QConvOp, QLinearOp, QpSlot, QuantizeOp,
};
use crate::graph::packs::KernelChoice;
use crate::graph::{DnnConfig, LayerKind, ModelDef, Precision};
use crate::kernels::simd::tune;
use crate::kernels::{ConvGeom, OpCounter};
use crate::memplan::{allocate_arena, ArenaItem, ArenaPlan, Scratch, ScratchSpec};
use crate::quant::observer::MinMaxObserver;
use crate::quant::subbyte::WBits;
use crate::quant::QTensor;
use crate::tensor::TensorF32;

/// A compiled execution schedule for one deployed model configuration.
pub struct ExecPlan {
    ops: Vec<Box<dyn LayerOp>>,
    /// Backend-neutral description of each schedule step, recorded in the
    /// same compile loop that boxes `ops`: `steps[k]` describes `ops[k]`
    /// one-for-one. Alternate executors (the wgpu/WGSL lowering in
    /// `backend::gpu`) read this instead of downcasting trait objects.
    steps: Vec<StepDesc>,
    /// Liveness-planned activation arena for a full training step.
    arena: ArenaPlan,
    /// Peak feature-arena bytes of the planned training step.
    pub planned_peak_bytes: usize,
    /// Union of every GEMM scratch request the ops can make.
    spec: ScratchSpec,
    /// Per-layer autotuned micro-kernel preferences (`None` for layers
    /// with no tuned kernel: pools, flatten, boundaries). Computed once at
    /// compile from the layer geometry (`kernels::simd::tune`) and
    /// installed into each session's [`crate::graph::packs::PackCache`].
    choices: Vec<Option<KernelChoice>>,
    /// Per-layer weight storage widths chosen by the bit-selection pass
    /// (see [`BitPlan`]). Deployment reads this to decide which layers get
    /// packed sub-byte parameters ([`crate::graph::act::LayerParams::Qp`]).
    bit_plan: BitPlan,
    /// The configuration this plan was compiled for.
    pub cfg: DnnConfig,
    /// Whether this plan runs the fused-epilogue kernels and folds legal
    /// precision boundaries into their producers (see
    /// [`ExecPlan::compile_with`]).
    fused: bool,
}

/// Pure-data description of one plan step — the geometry and
/// quantization-parameter slots behind the matching [`LayerOp`] in
/// [`ExecPlan::ops`], without the executor behavior attached.
///
/// Recorded by the compile loop at every op push, so any alternate backend
/// can lower the *identical* schedule (same boundary-op placement, same
/// fold decisions) from plain data. The wgpu/WGSL backend (`backend::gpu`)
/// is the first consumer; it lowers the unfused schedule, where
/// `fold_dequant` is always `false` and every precision crossing appears
/// as an explicit [`StepDesc::Quantize`] / [`StepDesc::Dequantize`] step.
#[derive(Clone, Debug)]
pub enum StepDesc {
    /// Float → uint8 boundary into layer `layer`'s staging slot, using the
    /// quantization parameters resolved from `qp` at run time.
    Quantize { layer: usize, qp: QpSlot },
    /// Uint8 → float boundary into layer `layer`'s staging slot.
    Dequantize { layer: usize },
    /// Quantized convolution (dense or depthwise, per `geom.depthwise`).
    /// `fold_dequant` marks the fused-plan variant that also emits the
    /// dequantized float copy from its epilogue.
    QConv {
        layer: usize,
        geom: ConvGeom,
        relu: bool,
        in_qp: QpSlot,
        in_h: usize,
        in_w: usize,
        fold_dequant: bool,
    },
    /// Float convolution.
    FConv { layer: usize, geom: ConvGeom, relu: bool, in_h: usize, in_w: usize },
    /// Quantized fully-connected layer (see `QConv` for `fold_dequant`).
    QLinear {
        layer: usize,
        n_in: usize,
        n_out: usize,
        relu: bool,
        in_qp: QpSlot,
        fold_dequant: bool,
    },
    /// Float fully-connected layer.
    FLinear { layer: usize, n_in: usize, n_out: usize, relu: bool },
    /// Non-overlapping max pool with window `k` (precision-preserving).
    MaxPool { layer: usize, k: usize, in_shape: Vec<usize> },
    /// Global average pool (requantizing in uint8, plain mean in float).
    GlobalAvgPool { layer: usize, in_shape: Vec<usize> },
    /// Zero-copy reshape: aliases the producer's buffer, no compute.
    Flatten { layer: usize, out_len: usize },
}

/// Whether plans compile in fused-epilogue mode by default: `true` unless
/// the `TT_NO_FUSE` environment variable is set to `1`/`true`, which forces
/// the unfused op sequence — the bit-for-bit parity oracle the fused path
/// is tested against (`tests/plan_parity.rs`, and a dedicated CI leg runs
/// the whole tier-1 suite under `TT_NO_FUSE=1`).
pub fn fuse_default() -> bool {
    !matches!(std::env::var("TT_NO_FUSE").ok().as_deref(), Some("1") | Some("true"))
}

/// Plan-fusion legality: can the `DequantizeOp` boundary *after* layer `l`
/// be folded into layer `l`'s own kernel epilogue?
///
/// Legal iff layer `l` is a **quantized dense (non-depthwise) conv or
/// linear** and layer `l+1` runs in float: those producers route through
/// the GEMM micro-kernel, whose fused epilogue
/// ([`crate::kernels::gemm::gemm_u8_i32_fused`]) can emit the dequantized
/// float copy from the register tile while requantizing. Everything else
/// keeps its explicit boundary op:
///
///  * **depthwise convs** — the depthwise engine fuses requantization but
///    has no dequant-emitting write-out (its tile loop is per-channel, not
///    GEMM-shaped), so the boundary stays explicit;
///  * **pools / flatten** — never produce a precision crossing themselves
///    (they pass precision through);
///  * **`QuantizeOp` boundaries (float → uint8)** — never folded: the
///    float producer's epilogue has no quantization parameters of its own
///    to target, and no shipping configuration produces this crossing
///    (`Mixed` crosses uint8 → float exactly once).
pub fn folds_dequant(def: &ModelDef, prec: &[Precision], l: usize) -> bool {
    l + 1 < def.layers.len()
        && prec[l] == Precision::Uint8
        && prec[l + 1] == Precision::Float32
        && match def.layers[l].kind {
            LayerKind::Conv { geom, .. } => !geom.depthwise,
            LayerKind::Linear { .. } => true,
            _ => false,
        }
}

/// Storage-width request for the plan compiler's weight bit-selection
/// pass. The default (`force: None, budget: None`) keeps every layer on
/// the plain u8 representation — byte-for-byte today's plans.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BitSpec {
    /// Force every quantized weighted layer to this packed width
    /// (`TT_WBITS`). `W8` selects the *packed* code path at 8 bits — the
    /// bit-exactness oracle configuration, since packed-8 lanes round-trip
    /// to the exact u8 weight bytes.
    pub force: Option<WBits>,
    /// Quantized-weight byte budget (`TT_WEIGHT_BUDGET`) the demotion
    /// pass must fit. Ignored when `force` is set.
    pub budget: Option<usize>,
}

impl BitSpec {
    /// The environment-configured spec. Parsing happens at the single
    /// `TT_*` parse site ([`crate::config::RunConfig::from_env`]).
    pub fn from_env() -> BitSpec {
        let rc = crate::config::RunConfig::from_env();
        BitSpec { force: rc.wbits, budget: rc.weight_budget }
    }
}

/// Per-layer weight storage widths chosen at compile: `None` keeps the
/// plain u8 representation ([`crate::graph::act::LayerParams::Q`] — the
/// retained bit-exactness oracle), `Some(b)` deploys the layer's weights
/// packed at `b` bits per lane ([`crate::graph::act::LayerParams::Qp`]).
///
/// Width assignment (see [`BitPlan::assign`]): a forced width applies to
/// every quantized weighted layer; otherwise a byte budget is met by
/// repeatedly demoting the layer whose weight tensor currently occupies
/// the most bytes one step down the `u8 → 4-bit → 2-bit` ladder (ties:
/// earliest layer), stopping when the quantized weight total fits — or
/// when everything is already 2-bit and the budget is simply unreachable.
/// Only quantized (uint8-precision) conv/linear weights participate;
/// float master weights of a `Mixed`/`Float32` head are not packable and
/// stay outside the budget.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitPlan {
    pub wbits: Vec<Option<WBits>>,
}

impl BitPlan {
    /// The packed width of layer `l`, or `None` for the u8 path.
    pub fn packed(&self, l: usize) -> Option<WBits> {
        self.wbits.get(l).copied().flatten()
    }

    /// Weight-tensor lane counts of the packable layers: quantized conv /
    /// linear weights (0 for float, unweighted or out-of-range layers).
    fn quant_lanes(def: &ModelDef, prec: &[Precision]) -> Vec<usize> {
        def.layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if prec[i] != Precision::Uint8 {
                    return 0;
                }
                match &l.kind {
                    LayerKind::Conv { geom, .. } => geom.weights(),
                    LayerKind::Linear { n_in, n_out, .. } => n_in * n_out,
                    _ => 0,
                }
            })
            .collect()
    }

    /// Run the bit-selection pass for `def` at the given precisions.
    pub fn assign(def: &ModelDef, prec: &[Precision], spec: &BitSpec) -> BitPlan {
        let lanes = Self::quant_lanes(def, prec);
        let mut wbits: Vec<Option<WBits>> = vec![None; def.layers.len()];
        if let Some(b) = spec.force {
            for (w, &nl) in wbits.iter_mut().zip(&lanes) {
                if nl > 0 {
                    *w = Some(b);
                }
            }
            return BitPlan { wbits };
        }
        let Some(budget) = spec.budget else {
            return BitPlan { wbits };
        };
        loop {
            let bytes = |i: usize| wbits[i].map_or(lanes[i], |b| b.packed_len(lanes[i]));
            let total: usize = (0..lanes.len()).map(bytes).sum();
            if total <= budget {
                break;
            }
            // Demote the largest remaining tensor one step (ties: earliest
            // layer). u8 demotes straight to 4-bit — packing at 8 bits
            // saves nothing, so W8 never appears in a budget-driven plan.
            let cand = (0..lanes.len())
                .filter(|&i| lanes[i] > 0 && wbits[i] != Some(WBits::W2))
                .max_by(|&a, &b| bytes(a).cmp(&bytes(b)).then(b.cmp(&a)));
            match cand {
                Some(i) => {
                    wbits[i] = Some(match wbits[i] {
                        None | Some(WBits::W8) => WBits::W4,
                        Some(WBits::W4) | Some(WBits::W2) => WBits::W2,
                    });
                }
                None => break, // everything already 2-bit: budget unreachable
            }
        }
        BitPlan { wbits }
    }

    /// Total bytes the quantized weight tensors occupy under this plan
    /// (weight payloads only; biases are width-independent). This is the
    /// quantity [`BitPlan::assign`] fits into a `TT_WEIGHT_BUDGET`.
    pub fn weight_bytes(&self, def: &ModelDef, prec: &[Precision]) -> usize {
        let lanes = Self::quant_lanes(def, prec);
        lanes
            .iter()
            .enumerate()
            .map(|(i, &nl)| self.packed(i).map_or(nl, |b| b.packed_len(nl)))
            .sum()
    }
}

impl ExecPlan {
    /// Compile the plan for `def` under `cfg` in the default fusion mode
    /// ([`fuse_default`]: fused unless `TT_NO_FUSE=1`). `O(layers)`: pure
    /// shape and precision arithmetic, no per-sample work.
    pub fn compile(def: &ModelDef, cfg: DnnConfig) -> ExecPlan {
        Self::compile_with(def, cfg, fuse_default())
    }

    /// Compile the plan with an explicit fusion mode.
    ///
    /// `fused = false` emits the PR 3 op sequence unchanged: one compute op
    /// per layer, explicit `QuantizeOp`/`DequantizeOp` boundary steps, and
    /// kernels that run requantization as a separate pass over an i32
    /// accumulator strip. This is the retained bit-for-bit parity oracle.
    ///
    /// `fused = true` applies two plan-level transformations, both
    /// bit-identical to the oracle by construction (asserted over every
    /// model × precision × mask configuration in `tests/plan_parity.rs`):
    ///
    ///  * **epilogue fusion** — quantized conv/linear ops route through the
    ///    `_fused` kernel twins, which requantize (bias add, ReLU clamp)
    ///    the MR×NR accumulator tile in registers and count range
    ///    saturation on the way out, so the i32 accumulator strips of the
    ///    forward and backward-input GEMMs never materialize. The
    ///    [`ScratchSpec`] shrinks accordingly, and the unfused plan's
    ///    liveness timeline models the dropped strips explicitly (see
    ///    [`arena_items_with`]) so `planned_peak_bytes` reflects the
    ///    saving;
    ///  * **boundary folding** — `DequantizeOp` steps whose producer
    ///    passes [`folds_dequant`] are deleted from the schedule; the
    ///    producer's fused kernel emits the dequantized float staging
    ///    tensor directly from the register tile, and the producer's
    ///    backward absorbs the boundary's error-quantization step
    ///    (observing into the same per-layer error observer, in the same
    ///    order).
    ///
    /// Weight storage widths come from the environment
    /// ([`BitSpec::from_env`]: `TT_WBITS` / `TT_WEIGHT_BUDGET`); use
    /// [`ExecPlan::compile_with_bits`] for explicit control.
    pub fn compile_with(def: &ModelDef, cfg: DnnConfig, fused: bool) -> ExecPlan {
        Self::compile_with_bits(def, cfg, fused, &BitSpec::from_env())
    }

    /// Compile the plan with an explicit fusion mode and an explicit
    /// weight storage-width request (see [`BitSpec`] / [`BitPlan`]).
    ///
    /// Layers the bit-selection pass marks packed get their unpack lane
    /// scratch pre-sized here: the GEMM paths unpack into the dedicated
    /// `wq_u8` span, the depthwise engine into its existing `wt_u8`
    /// flipped-weight span (which therefore must exist even for frozen
    /// packed layers — the *forward* unpacks too). A default `BitSpec`
    /// leaves the spec byte-for-byte identical to the pre-packing plans.
    pub fn compile_with_bits(
        def: &ModelDef,
        cfg: DnnConfig,
        fused: bool,
        bits: &BitSpec,
    ) -> ExecPlan {
        let prec = def.precisions(cfg);
        let bit_plan = BitPlan::assign(def, &prec, bits);
        let shapes = def.shapes();
        // Backward scratch is sized only for the layers the backward pass
        // can actually visit: weight-gradient buffers for trainable
        // layers, input-gradient buffers above the earliest trainable
        // layer. Frozen early layers contribute their forward buffers
        // only (transfer-learning tails keep arenas small).
        let stop = def.first_trainable().unwrap_or(def.layers.len());
        let mut ops: Vec<Box<dyn LayerOp>> = Vec::with_capacity(def.layers.len() + 2);
        let mut steps: Vec<StepDesc> = Vec::with_capacity(def.layers.len() + 2);
        let mut spec = ScratchSpec::default();
        let mut choices: Vec<Option<KernelChoice>> = vec![None; def.layers.len()];
        for (i, l) in def.layers.iter().enumerate() {
            let in_shape = if i == 0 { def.input_shape.clone() } else { shapes[i - 1].clone() };
            let prev = if i == 0 { prec[0] } else { prec[i - 1] };
            if prec[i] != prev {
                match prec[i] {
                    Precision::Uint8 => {
                        ops.push(Box::new(QuantizeOp { layer: i, qp: in_qp_slot(def, i) }));
                        steps.push(StepDesc::Quantize { layer: i, qp: in_qp_slot(def, i) });
                    }
                    // A foldable dequantize boundary is deleted from the
                    // fused schedule: its producer emits the float staging
                    // tensor itself (forward) and absorbs the error
                    // quantization (backward).
                    Precision::Float32 => {
                        if !(fused && i > 0 && folds_dequant(def, &prec, i - 1)) {
                            ops.push(Box::new(DequantizeOp { layer: i }));
                            steps.push(StepDesc::Dequantize { layer: i });
                        }
                    }
                }
            }
            match &l.kind {
                LayerKind::Conv { geom, relu } => {
                    if geom.depthwise {
                        // Depthwise engine (`kernels::dwconv`): forward and
                        // backward tiles live in fixed-size local arrays, so
                        // the only scratch the kernels can request is the
                        // flipped-weight fallback of a stale-pack bypass —
                        // `Cout·Kh·Kw`, pre-sized so even that path never
                        // grows the arena.
                        let dw = geom.cout * geom.kh * geom.kw;
                        if i > stop {
                            match prec[i] {
                                Precision::Uint8 => spec.wt_u8 = spec.wt_u8.max(dw),
                                Precision::Float32 => spec.wt_f32 = spec.wt_f32.max(dw),
                            }
                        }
                        // Packed depthwise weights unpack into the same
                        // `wt_u8` span on the *forward* path too
                        // (`qdwconv2d_fwd_fused_pa_sel`), so it must exist
                        // even for frozen packed layers.
                        if bit_plan.packed(i).is_some() {
                            spec.wt_u8 = spec.wt_u8.max(dw);
                        }
                    }
                    if !geom.depthwise {
                        let n_hw = shapes[i][1] * shapes[i][2];
                        let kdim = geom.cin * geom.kh * geom.kw;
                        let hw_in = in_shape[1] * in_shape[2];
                        let krow = geom.cout * geom.kh * geom.kw;
                        let fwd_col = if geom.is_pointwise() { 0 } else { kdim * n_hw };
                        match prec[i] {
                            Precision::Uint8 => {
                                spec.col_u8 = spec.col_u8.max(fwd_col);
                                // Fused plans requantize the accumulator
                                // tile in registers: the forward and
                                // backward-input i32 strips exist only on
                                // the unfused oracle path. The trainable
                                // weight-gradient accumulator stays in both
                                // modes (dW is emitted in float either way).
                                if !fused {
                                    spec.acc_i32 = spec.acc_i32.max(geom.cout * n_hw);
                                }
                                if l.trainable {
                                    spec.acc_i32 = spec.acc_i32.max(geom.cout * kdim);
                                }
                                // The flipped-weight pack (`wt_u8`) is NOT
                                // sized here: the dense pack lives in the
                                // plan-owned cache (`graph::packs`); only
                                // the per-sample masked fallback packs into
                                // scratch, growing once on first use.
                                if i > stop {
                                    spec.col_u8 = spec.col_u8.max(krow * hw_in);
                                    if !fused {
                                        spec.acc_i32 = spec.acc_i32.max(geom.cin * hw_in);
                                    }
                                    spec.zeros_i32 = spec.zeros_i32.max(geom.cin);
                                }
                                // Packed weights unpack into the dedicated
                                // `wq_u8` lane span: the forward A-panel
                                // (`cout·kdim`), and above the trainable
                                // stop also the cached flipped pack the
                                // backward-input GEMM consumes
                                // (`cin·krow` — the same weight volume).
                                if bit_plan.packed(i).is_some() {
                                    spec.wq_u8 = spec.wq_u8.max(geom.cout * kdim);
                                    if i > stop {
                                        spec.wq_u8 = spec.wq_u8.max(geom.cin * krow);
                                    }
                                }
                            }
                            Precision::Float32 => {
                                spec.col_f32 = spec.col_f32.max(fwd_col);
                                // `wt_f32` deliberately unsized — see the
                                // uint8 branch (dense packs are plan-owned).
                                if i > stop {
                                    spec.col_f32 = spec.col_f32.max(krow * hw_in);
                                    spec.zeros_f32 = spec.zeros_f32.max(geom.cin);
                                }
                            }
                        }
                    }
                    // Autotune the layer's micro-kernel preferences from its
                    // geometry (machine-independent — see `simd::tune`).
                    choices[i] = Some(if geom.depthwise {
                        KernelChoice {
                            fwd: tune::prefer_axpy(shapes[i][2]),
                            bwd_input: tune::prefer_axpy(in_shape[2]),
                            bwd_weight: tune::prefer_dot(shapes[i][2]),
                        }
                    } else {
                        KernelChoice {
                            fwd: tune::prefer_gemm(
                                geom.cout,
                                geom.cin * geom.kh * geom.kw,
                                shapes[i][1] * shapes[i][2],
                            ),
                            bwd_input: tune::prefer_gemm(
                                geom.cin,
                                geom.cout * geom.kh * geom.kw,
                                in_shape[1] * in_shape[2],
                            ),
                            bwd_weight: tune::prefer_dot(shapes[i][1] * shapes[i][2]),
                        }
                    });
                    match prec[i] {
                        Precision::Uint8 => {
                            let fold_dequant = fused && folds_dequant(def, &prec, i);
                            ops.push(Box::new(QConvOp {
                                layer: i,
                                name: l.name.clone(),
                                geom: *geom,
                                relu: *relu,
                                in_qp: in_qp_slot(def, i),
                                in_h: in_shape[1],
                                in_w: in_shape[2],
                                fused,
                                fold_dequant,
                            }));
                            steps.push(StepDesc::QConv {
                                layer: i,
                                geom: *geom,
                                relu: *relu,
                                in_qp: in_qp_slot(def, i),
                                in_h: in_shape[1],
                                in_w: in_shape[2],
                                fold_dequant,
                            });
                        }
                        Precision::Float32 => {
                            ops.push(Box::new(FConvOp {
                                layer: i,
                                name: l.name.clone(),
                                geom: *geom,
                                relu: *relu,
                                in_h: in_shape[1],
                                in_w: in_shape[2],
                            }));
                            steps.push(StepDesc::FConv {
                                layer: i,
                                geom: *geom,
                                relu: *relu,
                                in_h: in_shape[1],
                                in_w: in_shape[2],
                            });
                        }
                    }
                }
                LayerKind::Linear { n_in, n_out, relu } => {
                    match prec[i] {
                        Precision::Uint8 => {
                            if l.trainable {
                                spec.acc_i32 = spec.acc_i32.max(n_out * n_in);
                            }
                            if i > stop {
                                spec.col_u8 = spec.col_u8.max(*n_out);
                                // Fused: the bwd-input GEMM requantizes in
                                // registers, no i32 strip (see the conv arm).
                                if !fused {
                                    spec.acc_i32 = spec.acc_i32.max(*n_in);
                                }
                                spec.zeros_i32 = spec.zeros_i32.max(1);
                            }
                            if bit_plan.packed(i).is_some() {
                                spec.wq_u8 = spec.wq_u8.max(n_out * n_in);
                                // The packed forward pulls its i32
                                // accumulator from scratch (the u8 twin
                                // allocates locally), so the unfused spec
                                // must cover it.
                                if !fused {
                                    spec.acc_i32 = spec.acc_i32.max(*n_out);
                                }
                            }
                        }
                        Precision::Float32 => {
                            if i > stop {
                                spec.col_f32 = spec.col_f32.max(*n_out);
                                spec.zeros_f32 = spec.zeros_f32.max(1);
                            }
                        }
                    }
                    // Linear layers: forward is an `n_out × n_in × 1`
                    // matvec, backward-input a `1 × n_out × n_in` GEMM row,
                    // backward-weight a rank-1 outer product (kd = 1 dots —
                    // always scalar).
                    choices[i] = Some(KernelChoice {
                        fwd: tune::prefer_gemm(*n_out, *n_in, 1),
                        bwd_input: tune::prefer_gemm(1, *n_out, *n_in),
                        bwd_weight: tune::prefer_dot(1),
                    });
                    match prec[i] {
                        Precision::Uint8 => {
                            let fold_dequant = fused && folds_dequant(def, &prec, i);
                            ops.push(Box::new(QLinearOp {
                                layer: i,
                                name: l.name.clone(),
                                relu: *relu,
                                in_qp: in_qp_slot(def, i),
                                fused,
                                fold_dequant,
                            }));
                            steps.push(StepDesc::QLinear {
                                layer: i,
                                n_in: *n_in,
                                n_out: *n_out,
                                relu: *relu,
                                in_qp: in_qp_slot(def, i),
                                fold_dequant,
                            });
                        }
                        Precision::Float32 => {
                            ops.push(Box::new(FLinearOp {
                                layer: i,
                                name: l.name.clone(),
                                relu: *relu,
                            }));
                            steps.push(StepDesc::FLinear {
                                layer: i,
                                n_in: *n_in,
                                n_out: *n_out,
                                relu: *relu,
                            });
                        }
                    }
                }
                LayerKind::MaxPool { k } => {
                    steps.push(StepDesc::MaxPool { layer: i, k: *k, in_shape: in_shape.clone() });
                    ops.push(Box::new(MaxPoolOp { layer: i, k: *k, in_shape }))
                }
                LayerKind::GlobalAvgPool => {
                    steps.push(StepDesc::GlobalAvgPool { layer: i, in_shape: in_shape.clone() });
                    ops.push(Box::new(GlobalAvgPoolOp { layer: i, in_shape }))
                }
                LayerKind::Flatten => {
                    let out_len: usize = in_shape.iter().product();
                    steps.push(StepDesc::Flatten { layer: i, out_len });
                    ops.push(Box::new(FlattenOp { layer: i, out_len, in_shape }))
                }
            }
        }
        let arena = planned_arena_with(def, cfg, true, fused);
        ExecPlan {
            planned_peak_bytes: arena.total_bytes,
            arena,
            ops,
            steps,
            spec,
            choices,
            bit_plan,
            cfg,
            fused,
        }
    }

    /// Backend-neutral step descriptions: `steps()[k]` is the pure-data
    /// twin of `ops()[k]`, same length, same order (see [`StepDesc`]).
    pub fn steps(&self) -> &[StepDesc] {
        &self.steps
    }

    /// The per-layer weight storage widths this plan deploys with (see
    /// [`BitPlan`]).
    pub fn bit_plan(&self) -> &BitPlan {
        &self.bit_plan
    }

    /// The per-layer autotuned micro-kernel preferences (`None` for layers
    /// with no tuned kernel). Installed into each session's pack cache at
    /// build ([`crate::graph::packs::PackCache::install_choices`]); ops
    /// read them back per dispatch via `PackCache::choice`.
    pub fn kernel_choices(&self) -> &[Option<KernelChoice>] {
        &self.choices
    }

    /// Whether this plan was compiled in fused-epilogue mode (see
    /// [`ExecPlan::compile_with`]).
    pub fn fused(&self) -> bool {
        self.fused
    }

    /// The compiled schedule, in forward execution order.
    pub fn ops(&self) -> &[Box<dyn LayerOp>] {
        &self.ops
    }

    /// Number of plan steps (compute ops + precision boundary ops).
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// The union of all GEMM scratch requests the plan's ops can make.
    pub fn scratch_spec(&self) -> &ScratchSpec {
        &self.spec
    }

    /// Pre-sized scratch arena serving every op of this plan with zero
    /// growth across a full training step.
    pub fn make_scratch(&self) -> Scratch {
        Scratch::for_spec(&self.spec)
    }

    /// The planned arena placement: `(buffer name, offset, bytes)` per
    /// liveness-planned buffer, sorted by offset then birth. This is the
    /// table the harness emits so memory claims are reproducible.
    pub fn arena_table(&self) -> Vec<(String, usize, usize)> {
        let mut rows: Vec<(String, usize, usize)> =
            self.arena.items.iter().map(|(it, off)| (it.name.clone(), *off, it.bytes)).collect();
        rows.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));
        rows
    }

    /// Run the planned forward pass. Bit-identical (values and op counts)
    /// to [`crate::graph::reference::forward_reference`].
    pub fn run_forward(
        &self,
        model: &NativeModel,
        x: &TensorF32,
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> FwdTrace {
        let n = model.shared.def.layers.len();
        let input = match model.shared.prec[0] {
            Precision::Uint8 => Act::Q(QTensor::quantize_with(x, model.shared.input_qp)),
            Precision::Float32 => Act::F(x.clone()),
        };
        let mut ctx = ExecCtx {
            params: &model.state.params,
            prec: &model.shared.prec,
            act_qp: &model.state.act_qp,
            input_qp: model.shared.input_qp,
            layers: &model.shared.def.layers,
            stop: 0,
            scratch,
            packs: model.packs(),
            param_versions: model.param_versions(),
            ops,
            input: Some(input),
            acts: Vec::with_capacity(n),
            argmax: vec![None; n],
            sat: vec![None; n],
            staged: None,
            trace: None,
            err: None,
            err_obs: None,
            masks: None,
            grads: Vec::new(),
        };
        for op in &self.ops {
            op.forward(&mut ctx);
        }
        let logits = ctx.acts.last().expect("model must have at least one layer").to_float();
        FwdTrace {
            input: ctx.input.take().expect("forward input survives the pass"),
            acts: ctx.acts,
            argmax: ctx.argmax,
            sat: ctx.sat,
            logits: logits.into_vec(),
        }
    }

    /// Run the planned backward pass against caller-provided error
    /// observers. Bit-identical (gradients, observer updates, op counts)
    /// to [`crate::graph::reference::backward_reference`].
    pub fn run_backward(
        &self,
        model: &NativeModel,
        trace: &FwdTrace,
        head_err: TensorF32,
        masks: &mut dyn MaskProvider,
        err_obs: &mut [MinMaxObserver],
        scratch: &mut Scratch,
        ops: &mut OpCounter,
    ) -> BwdResult {
        let n = model.shared.def.layers.len();
        assert_eq!(err_obs.len(), n, "one error observer per layer");
        let stop = model.shared.def.first_trainable().unwrap_or(n);
        let err = match model.shared.prec[n - 1] {
            Precision::Float32 => Act::F(head_err),
            Precision::Uint8 => {
                let obs = &mut err_obs[n - 1];
                obs.observe(head_err.data());
                Act::Q(QTensor::quantize_with(&head_err, obs.qparams()))
            }
        };
        let mut ctx = ExecCtx {
            params: &model.state.params,
            prec: &model.shared.prec,
            act_qp: &model.state.act_qp,
            input_qp: model.shared.input_qp,
            layers: &model.shared.def.layers,
            stop,
            scratch,
            packs: model.packs(),
            param_versions: model.param_versions(),
            ops,
            input: None,
            acts: Vec::new(),
            argmax: Vec::new(),
            sat: Vec::new(),
            staged: None,
            trace: Some(trace),
            err: Some(err),
            err_obs: Some(err_obs),
            masks: Some(masks),
            grads: (0..n).map(|_| None).collect(),
        };
        for op in self.ops.iter().rev() {
            if op.runs_backward(stop) {
                op.backward(&mut ctx);
            }
        }
        BwdResult { grads: ctx.grads }
    }
}

/// Resolve where layer `i`'s input quantization parameters live: the
/// nearest preceding producer (conv / linear / global average pool) — pools
/// and flatten pass quantization parameters through — falling back to the
/// network input.
fn in_qp_slot(def: &ModelDef, i: usize) -> QpSlot {
    for j in (0..i).rev() {
        match def.layers[j].kind {
            LayerKind::Conv { .. } | LayerKind::Linear { .. } | LayerKind::GlobalAvgPool => {
                return QpSlot::Layer(j);
            }
            _ => {}
        }
    }
    QpSlot::Input
}

fn act_bytes(shape: &[usize], prec: Precision) -> usize {
    let n: usize = shape.iter().product();
    match prec {
        Precision::Uint8 => n,
        Precision::Float32 => n * 4,
    }
}

/// Liveness items of the *planned* schedule in the default fusion mode
/// ([`fuse_default`]). See [`arena_items_with`].
pub fn arena_items(def: &ModelDef, cfg: DnnConfig, training: bool) -> Vec<ArenaItem> {
    arena_items_with(def, cfg, training, fuse_default())
}

/// Liveness items of the *planned* schedule: the analytic fwd/bwd timeline
/// refined with what the compiled ops actually allocate — `Flatten` outputs
/// alias their input buffer (zero-copy view, so they add no arena item,
/// only extend the aliased buffer's lifetime), and precision boundaries add
/// transient staging buffers. Timeline: forward step of layer `i` is time
/// `i`; its backward step is time `2n−1−i`.
///
/// The fusion mode changes the timeline in two ways, mirroring
/// [`ExecPlan::compile_with`]:
///
///  * **accumulator strips** — the unfused GEMM path materializes an i32
///    accumulator strip per quantized dense conv/linear: `facc{i}`
///    (`out_elems × 4` bytes, transient at forward step `i`) and, when the
///    backward-input GEMM runs, `bacc{i}` (`in_elems × 4` bytes, transient
///    at backward step `2n−1−i`). Fused plans requantize the register tile
///    directly, so these items vanish from the timeline. (The trainable
///    weight-gradient accumulator — `cout × kdim` i32 — is scratch-pooled
///    in both modes and deliberately not modeled here.)
///  * **folded boundary staging** — a `DequantizeOp` whose producer passes
///    [`folds_dequant`] has its float staging tensor emitted by the
///    producer's fused epilogue one step earlier, so `stage{i}`'s birth
///    moves from `i` to `i − 1`. At that step the stage buffer is exactly
///    the size of the producer's dropped `facc{i−1}` strip (`out_elems ×
///    4`), so fused liveness never exceeds unfused liveness at any step.
pub fn arena_items_with(
    def: &ModelDef,
    cfg: DnnConfig,
    training: bool,
    fused: bool,
) -> Vec<ArenaItem> {
    let n = def.layers.len();
    let prec = def.precisions(cfg);
    let shapes = def.shapes();
    let stop = if training { def.first_trainable().unwrap_or(n) } else { n };
    let bwd_t = |i: usize| 2 * n - 1 - i;

    let mut items: Vec<ArenaItem> = Vec::new();
    // The input buffer is item 0; if layer 0 is trainable its input must
    // survive until layer 0's backward step.
    let input_trainable = training && def.layers.first().is_some_and(|l| l.trainable);
    let input_death = if input_trainable { bwd_t(0) } else { 0 };
    items.push(ArenaItem {
        name: "input".into(),
        bytes: act_bytes(&def.input_shape, prec[0]),
        birth: 0,
        death: input_death,
    });
    // items index of the buffer backing each layer's output activation
    let mut slot: Vec<usize> = Vec::with_capacity(n);

    for i in 0..n {
        // Death of layer i's output: consumed by layer i+1 in forward;
        // training extends it to backward uses (weight-gradient input,
        // ReLU masking, the loss at the head).
        let mut death = if i + 1 < n { i + 1 } else { i };
        if training {
            if i + 1 < n && def.layers[i + 1].trainable {
                death = death.max(bwd_t(i + 1));
            }
            let needs_own_output = matches!(
                def.layers[i].kind,
                LayerKind::Conv { relu: true, .. } | LayerKind::Linear { relu: true, .. }
            );
            if i >= stop && needs_own_output {
                death = death.max(bwd_t(i));
            }
            if i == n - 1 {
                death = death.max(bwd_t(n - 1));
            }
        }
        if matches!(def.layers[i].kind, LayerKind::Flatten) {
            // zero-copy view: no new buffer, extend the aliased one
            let s = if i == 0 { 0 } else { slot[i - 1] };
            items[s].death = items[s].death.max(death);
            slot.push(s);
        } else {
            items.push(ArenaItem {
                name: format!("act{i}"),
                bytes: act_bytes(&shapes[i], prec[i]),
                birth: i,
                death,
            });
            slot.push(items.len() - 1);
        }

        let prev_prec = if i == 0 { prec[0] } else { prec[i - 1] };
        let crosses = prec[i] != prev_prec;
        if crosses {
            // Forward boundary staging buffer, transient within step i. A
            // folded dequantize boundary's float staging tensor is emitted
            // by the producer's fused epilogue one step earlier, so its
            // birth moves to the producer's step.
            let in_shape = if i == 0 { &def.input_shape } else { &shapes[i - 1] };
            let folded = fused
                && i > 0
                && prec[i] == Precision::Float32
                && folds_dequant(def, &prec, i - 1);
            items.push(ArenaItem {
                name: format!("stage{i}"),
                bytes: act_bytes(in_shape, prec[i]),
                birth: if folded { i - 1 } else { i },
                death: i,
            });
        }
        // i32 accumulator strips of the unfused GEMM path: the forward
        // requantize pass reads a `out_elems × 4`-byte strip at step i,
        // and the backward-input pass (when it runs) an `in_elems × 4`-
        // byte strip at bwd(i). Fused kernels requantize the register
        // tile directly — no strip ever materializes.
        let quant_gemm = prec[i] == Precision::Uint8
            && match def.layers[i].kind {
                LayerKind::Conv { geom, .. } => !geom.depthwise,
                LayerKind::Linear { .. } => true,
                _ => false,
            };
        if !fused && quant_gemm {
            let out_elems: usize = shapes[i].iter().product();
            items.push(ArenaItem {
                name: format!("facc{i}"),
                bytes: out_elems * 4,
                birth: i,
                death: i,
            });
            if training && i > stop {
                let in_elems: usize =
                    (if i == 0 { &def.input_shape } else { &shapes[i - 1] }).iter().product();
                items.push(ArenaItem {
                    name: format!("bacc{i}"),
                    bytes: in_elems * 4,
                    birth: bwd_t(i),
                    death: bwd_t(i),
                });
            }
        }
        if training {
            if matches!(def.layers[i].kind, LayerKind::MaxPool { .. }) && i >= stop {
                let n_out: usize = shapes[i].iter().product();
                items.push(ArenaItem {
                    name: format!("argmax{i}"),
                    bytes: n_out * 4,
                    birth: i,
                    death: bwd_t(i),
                });
            }
            // Error buffers: err{i} is produced by layer i+1's backward
            // (or the loss head) and consumed at bwd(i). A flatten's
            // backward is a zero-copy reshape, so the error it emits
            // aliases the one it consumed — the chain is represented by
            // its top item, with the death extended through the
            // consecutive flatten layers below it.
            let produced_by_flatten =
                i + 1 < n && matches!(def.layers[i + 1].kind, LayerKind::Flatten);
            if i >= stop && !produced_by_flatten {
                let mut death = bwd_t(i);
                let mut j = i;
                while j > stop && matches!(def.layers[j].kind, LayerKind::Flatten) {
                    j -= 1;
                    death = death.max(bwd_t(j));
                }
                items.push(ArenaItem {
                    name: format!("err{i}"),
                    bytes: act_bytes(&shapes[i], prec[i]),
                    birth: bwd_t(i).saturating_sub(1),
                    death,
                });
            }
            // backward staging: the layer input re-coerced across the
            // boundary for the weight-gradient GEMM, transient at bwd(i)
            if i >= stop && crosses && def.layers[i].has_weights() {
                let in_shape = if i == 0 { &def.input_shape } else { &shapes[i - 1] };
                items.push(ArenaItem {
                    name: format!("bstage{i}"),
                    bytes: act_bytes(in_shape, prec[i]),
                    birth: bwd_t(i),
                    death: bwd_t(i),
                });
            }
        }
    }
    items
}

/// Arena placement of the planned schedule in the default fusion mode
/// (see [`arena_items`]).
pub fn planned_arena(def: &ModelDef, cfg: DnnConfig, training: bool) -> ArenaPlan {
    allocate_arena(arena_items(def, cfg, training))
}

/// Arena placement of the planned schedule with an explicit fusion mode
/// (see [`arena_items_with`]).
pub fn planned_arena_with(
    def: &ModelDef,
    cfg: DnnConfig,
    training: bool,
    fused: bool,
) -> ArenaPlan {
    allocate_arena(arena_items_with(def, cfg, training, fused))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn plan_has_one_op_per_layer_plus_boundaries() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let n = def.layers.len();
        for fused in [false, true] {
            assert_eq!(ExecPlan::compile_with(&def, DnnConfig::Uint8, fused).num_ops(), n);
            assert_eq!(ExecPlan::compile_with(&def, DnnConfig::Float32, fused).num_ops(), n);
        }
        // mixed crosses the precision boundary exactly once (after the
        // last conv), adding exactly one dequantize boundary op — which
        // the fusion pass folds into its (dense, quantized) producer
        assert_eq!(ExecPlan::compile_with(&def, DnnConfig::Mixed, false).num_ops(), n + 1);
        assert_eq!(ExecPlan::compile_with(&def, DnnConfig::Mixed, true).num_ops(), n);
    }

    #[test]
    fn fused_plan_drops_gemm_accumulator_scratch() {
        // The fused plan never materializes the fwd / bwd-input i32 GEMM
        // strips; only the (smaller) trainable weight-gradient accumulator
        // remains in scratch.
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let unfused = ExecPlan::compile_with(&def, DnnConfig::Uint8, false);
        let fused = ExecPlan::compile_with(&def, DnnConfig::Uint8, true);
        assert!(fused.scratch_spec().acc_i32 < unfused.scratch_spec().acc_i32);
        assert!(fused.fused() && !unfused.fused());
        // everything else is shared between the two modes
        assert_eq!(fused.scratch_spec().col_u8, unfused.scratch_spec().col_u8);
        assert_eq!(fused.scratch_spec().zeros_i32, unfused.scratch_spec().zeros_i32);
    }

    #[test]
    fn fused_arena_drops_accumulator_strips() {
        for def in [
            models::mnist_cnn(&[1, 12, 12], 4),
            models::mbednet(&[3, 16, 16], 5),
            models::mcunet5fps(&[3, 32, 32], 4),
        ] {
            for cfg in [DnnConfig::Uint8, DnnConfig::Mixed] {
                let uf = arena_items_with(&def, cfg, true, false);
                let f = arena_items_with(&def, cfg, true, true);
                assert!(uf.iter().any(|it| it.name.starts_with("facc")), "{} {cfg:?}", def.name);
                assert!(f.iter().all(|it| !it.name.starts_with("facc")), "{} {cfg:?}", def.name);
                assert!(f.iter().all(|it| !it.name.starts_with("bacc")), "{} {cfg:?}", def.name);
            }
            // float32 plans have no quantized GEMMs: identical timelines
            let uf = arena_items_with(&def, DnnConfig::Float32, true, false);
            let f = arena_items_with(&def, DnnConfig::Float32, true, true);
            assert_eq!(uf.len(), f.len(), "{}", def.name);
        }
    }

    #[test]
    fn plan_scratch_spec_covers_uint8_model() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let plan = ExecPlan::compile(&def, DnnConfig::Uint8);
        let spec = plan.scratch_spec();
        assert!(spec.col_u8 > 0 && spec.acc_i32 > 0 && spec.zeros_i32 > 0);
        // dense flipped-weight packs are plan-owned (`graph::packs`), not
        // scratch-sized — the spec shrank accordingly
        assert_eq!(spec.wt_u8, 0);
        // the uint8 plan never touches the float twins
        assert_eq!(spec.col_f32, 0);
        assert_eq!(spec.wt_f32, 0);
        // a float32 plan sizes the float twins instead
        let fspec = ExecPlan::compile(&def, DnnConfig::Float32).scratch_spec().clone();
        assert!(fspec.col_f32 > 0 && fspec.zeros_f32 > 0);
        assert_eq!(fspec.wt_f32, 0);
        assert_eq!(fspec.col_u8, 0);
    }

    #[test]
    fn depthwise_fallback_pack_is_presized() {
        // Depthwise-separable models pre-size the (tiny) flipped-weight
        // fallback of the depthwise engine's stale-pack bypass, in the
        // precision the deployment actually uses.
        let def = models::mbednet(&[3, 16, 16], 5);
        let spec = ExecPlan::compile(&def, DnnConfig::Uint8).scratch_spec().clone();
        assert!(spec.wt_u8 > 0, "uint8 depthwise fallback must be pre-sized");
        assert_eq!(spec.wt_f32, 0);
        let fspec = ExecPlan::compile(&def, DnnConfig::Float32).scratch_spec().clone();
        assert!(fspec.wt_f32 > 0, "float depthwise fallback must be pre-sized");
        assert_eq!(fspec.wt_u8, 0);
    }

    #[test]
    fn planned_arena_is_bounded_and_nonempty() {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let def = models::mbednet(&[3, 16, 16], 5);
            let plan = ExecPlan::compile(&def, cfg);
            let total_bytes: usize = arena_items(&def, cfg, true).iter().map(|i| i.bytes).sum();
            assert!(plan.planned_peak_bytes > 0, "{cfg:?}");
            assert!(plan.planned_peak_bytes <= total_bytes, "{cfg:?}");
            assert!(!plan.arena_table().is_empty(), "{cfg:?}");
        }
    }

    #[test]
    fn flatten_adds_no_arena_item() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let items = arena_items(&def, DnnConfig::Uint8, true);
        assert!(items.iter().all(|it| it.name != "act3"), "flatten output must alias");
        // ... and so does its backward reshape: the error below the
        // flatten (err2) shares the flatten error's buffer (err3)
        assert!(items.iter().all(|it| it.name != "err2"), "flatten bwd error must alias");
        assert!(items.iter().any(|it| it.name == "err3"));
        // training arena carries error buffers for the trainable layers
        assert!(items.iter().any(|it| it.name.starts_with("err")));
    }

    #[test]
    fn inference_arena_smaller_than_training_arena() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let inf = planned_arena(&def, DnnConfig::Uint8, false);
        let tr = planned_arena(&def, DnnConfig::Uint8, true);
        assert!(tr.total_bytes > inf.total_bytes, "{} vs {}", tr.total_bytes, inf.total_bytes);
    }

    #[test]
    fn default_bit_plan_leaves_spec_unchanged() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let base = ExecPlan::compile_with_bits(&def, DnnConfig::Uint8, true, &BitSpec::default());
        assert!(base.bit_plan().wbits.iter().all(|w| w.is_none()));
        assert_eq!(base.scratch_spec().wq_u8, 0);
        // Forcing packed-8 touches only the unpack lane span — everything
        // else of the spec, and the activation arena, stay identical.
        let p8 = ExecPlan::compile_with_bits(
            &def,
            DnnConfig::Uint8,
            true,
            &BitSpec { force: Some(WBits::W8), budget: None },
        );
        assert!(p8.scratch_spec().wq_u8 > 0);
        let mut spec8 = p8.scratch_spec().clone();
        spec8.wq_u8 = 0;
        assert_eq!(&spec8, base.scratch_spec());
        assert_eq!(p8.planned_peak_bytes, base.planned_peak_bytes);
    }

    #[test]
    fn forced_width_marks_every_quantized_weighted_layer() {
        let def = models::mbednet(&[3, 16, 16], 5);
        let plan = ExecPlan::compile_with_bits(
            &def,
            DnnConfig::Uint8,
            true,
            &BitSpec { force: Some(WBits::W4), budget: None },
        );
        for (i, l) in def.layers.iter().enumerate() {
            let expect = if l.has_weights() { Some(WBits::W4) } else { None };
            assert_eq!(plan.bit_plan().packed(i), expect, "layer {i}");
        }
        // GEMM layers unpack into `wq_u8`; depthwise layers into `wt_u8`,
        // pre-sized even when the layer is frozen (forward unpacks too).
        assert!(plan.scratch_spec().wq_u8 > 0);
        assert!(plan.scratch_spec().wt_u8 > 0);
        // Float deployments have no packable weights.
        let f = ExecPlan::compile_with_bits(
            &def,
            DnnConfig::Float32,
            true,
            &BitSpec { force: Some(WBits::W4), budget: None },
        );
        assert!(f.bit_plan().wbits.iter().all(|w| w.is_none()));
    }

    #[test]
    fn budget_pass_demotes_largest_first_until_fit() {
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let prec = def.precisions(DnnConfig::Uint8);
        let full = BitPlan::assign(&def, &prec, &BitSpec::default()).weight_bytes(&def, &prec);
        assert!(full > 0);
        let budget = full * 6 / 10;
        let bp = BitPlan::assign(&def, &prec, &BitSpec { force: None, budget: Some(budget) });
        assert!(bp.weight_bytes(&def, &prec) <= budget, "budget must be met");
        assert!(bp.wbits.iter().any(|w| w.is_some()), "something must demote");
        // Only quantized weighted layers ever pack, and demotion is
        // largest-first: every still-u8 tensor is no larger than every
        // demoted one.
        let lanes = BitPlan::quant_lanes(&def, &prec);
        let largest_kept =
            (0..lanes.len()).filter(|&i| bp.packed(i).is_none()).map(|i| lanes[i]).max().unwrap();
        for i in 0..lanes.len() {
            if bp.packed(i).is_some() {
                assert!(lanes[i] > 0, "only weighted quantized layers pack");
                assert!(lanes[i] >= largest_kept, "demotion must be largest-first");
            }
        }
        // An unreachable budget demotes everything to 2-bit and stops.
        let bp2 = BitPlan::assign(&def, &prec, &BitSpec { force: None, budget: Some(1) });
        for (i, &nl) in lanes.iter().enumerate() {
            let expect = if nl > 0 { Some(WBits::W2) } else { None };
            assert_eq!(bp2.packed(i), expect, "layer {i}");
        }
        // ~4× smaller than the u8 total (+1 byte rounding per tensor)
        assert!(bp2.weight_bytes(&def, &prec) <= full / 4 + lanes.len());
    }

    #[test]
    fn compile_is_o_layers_in_op_count() {
        // structural O(layers) guard: the op count is bounded by
        // layers + boundary crossings (≤ 1 per layer), for every model
        for def in [
            models::mnist_cnn(&[1, 12, 12], 4),
            models::mbednet(&[3, 16, 16], 5),
            models::mcunet5fps(&[3, 32, 32], 4),
        ] {
            for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
                let plan = ExecPlan::compile(&def, cfg);
                let n = def.layers.len();
                assert!(plan.num_ops() >= n && plan.num_ops() <= 2 * n, "{} {cfg:?}", def.name);
            }
        }
    }

    #[test]
    fn steps_mirror_ops_one_for_one() {
        // `steps()[k]` must describe `ops()[k]`: same length in every
        // model × config × fusion combination, and per-kind counts match
        // the layer list (each compute layer lowers to exactly one step).
        for def in [
            models::mnist_cnn(&[1, 12, 12], 4),
            models::mbednet(&[3, 16, 16], 5),
            models::mcunet5fps(&[3, 32, 32], 4),
        ] {
            for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
                for fused in [false, true] {
                    let plan = ExecPlan::compile_with(&def, cfg, fused);
                    assert_eq!(plan.steps().len(), plan.num_ops(), "{} {cfg:?}", def.name);
                    let convs = plan
                        .steps()
                        .iter()
                        .filter(|s| matches!(s, StepDesc::QConv { .. } | StepDesc::FConv { .. }))
                        .count();
                    let want = def
                        .layers
                        .iter()
                        .filter(|l| matches!(l.kind, LayerKind::Conv { .. }))
                        .count();
                    assert_eq!(convs, want, "{} {cfg:?}", def.name);
                    // Unfused schedules never fold; every crossing appears
                    // as an explicit boundary step.
                    if !fused {
                        for s in plan.steps() {
                            match s {
                                StepDesc::QConv { fold_dequant, .. }
                                | StepDesc::QLinear { fold_dequant, .. } => {
                                    assert!(!fold_dequant)
                                }
                                _ => {}
                            }
                        }
                    }
                }
            }
        }
        // The fused Mixed schedule folds legal dequantize boundaries into
        // their producers: it never has more boundary steps than unfused.
        let def = models::mnist_cnn(&[1, 12, 12], 4);
        let n_deq = |p: &ExecPlan| {
            p.steps().iter().filter(|s| matches!(s, StepDesc::Dequantize { .. })).count()
        };
        let unfused = ExecPlan::compile_with(&def, DnnConfig::Mixed, false);
        let fused = ExecPlan::compile_with(&def, DnnConfig::Mixed, true);
        assert!(n_deq(&unfused) >= 1, "Mixed must cross uint8 → float");
        assert!(n_deq(&fused) <= n_deq(&unfused));
    }
}
