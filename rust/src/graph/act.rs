//! Activation values, deployed per-layer parameters, float master weights
//! and PTQ calibration — the data types the executor ([`crate::graph::exec`])
//! and the compiled layer-op plan ([`crate::graph::plan`]) both operate on.

use crate::graph::{LayerDef, LayerKind, ModelDef};
use crate::kernels::{fconv, flinear, pool, OpCounter};
use crate::quant::observer::MinMaxObserver;
use crate::quant::subbyte::PackedQTensor;
use crate::quant::{QParams, QTensor};
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

/// An activation value flowing through the graph — quantized or float
/// depending on the layer precision (mixed configurations cross the
/// boundary exactly once, after the last conv).
#[derive(Clone, Debug)]
pub enum Act {
    Q(QTensor),
    F(TensorF32),
}

impl Act {
    pub fn shape(&self) -> &[usize] {
        match self {
            Act::Q(t) => t.shape(),
            Act::F(t) => t.shape(),
        }
    }

    pub fn to_float(&self) -> TensorF32 {
        match self {
            Act::Q(t) => t.dequantize(),
            Act::F(t) => t.clone(),
        }
    }

    /// Reinterpret with a new shape of identical volume. Zero-copy: the
    /// payload buffer is shared with `self` (see [`crate::tensor::Tensor::reshape`]),
    /// which is what makes `Flatten` a view rather than a copy in the
    /// planned executor.
    pub fn reshaped(&self, shape: &[usize]) -> Act {
        match self {
            Act::Q(t) => Act::Q(QTensor { values: t.values.reshape(shape), qp: t.qp }),
            Act::F(t) => Act::F(t.reshape(shape)),
        }
    }

    /// Bytes this activation occupies in the on-device arena.
    pub fn byte_size(&self) -> usize {
        match self {
            Act::Q(t) => t.len(),
            Act::F(t) => t.len() * 4,
        }
    }
}

/// Deployed per-layer parameters. The float bias master is kept for both
/// flavors: quantized kernels consume it re-quantized to i32 at the current
/// input/weight scales (cheap, `Cout` values), and the bias SGD step runs
/// in float either way.
#[derive(Clone, Debug)]
pub enum LayerParams {
    Q { w: QTensor, bias: Vec<f32> },
    /// Packed sub-byte quantized weights (`quant::subbyte`): the layer the
    /// compiled plan's `BitPlan` assigned a 4- or 2-bit storage width (or
    /// forced to packed-8). Kernels unpack the lanes in-panel; the weight
    /// tensor never exists unpacked at rest.
    Qp { w: PackedQTensor, bias: Vec<f32> },
    F { w: TensorF32, bias: Vec<f32> },
    None,
}

impl LayerParams {
    pub fn byte_size(&self) -> usize {
        match self {
            LayerParams::Q { w, bias } => w.len() + bias.len() * 4,
            LayerParams::Qp { w, bias } => w.packed_bytes() + bias.len() * 4,
            LayerParams::F { w, bias } => (w.len() + bias.len()) * 4,
            LayerParams::None => 0,
        }
    }

    /// Human-readable parameter flavor, for mismatch diagnostics.
    pub fn flavor(&self) -> &'static str {
        match self {
            LayerParams::Q { .. } => "quantized (uint8)",
            LayerParams::Qp { .. } => "quantized (packed sub-byte)",
            LayerParams::F { .. } => "float32",
            LayerParams::None => "none",
        }
    }
}

/// Float master weights used before deployment (pretraining on the source
/// domain and PTQ calibration both run on these).
#[derive(Clone, Debug)]
pub struct FloatParams {
    /// `(weights, bias)` for weighted layers; `None` for pools etc.
    pub layers: Vec<Option<(TensorF32, Vec<f32>)>>,
}

impl FloatParams {
    /// He-initialized random parameters.
    pub fn init(def: &ModelDef, rng: &mut Pcg32) -> FloatParams {
        let layers = def.layers.iter().map(|l| init_layer(l, rng)).collect();
        FloatParams { layers }
    }
}

pub(crate) fn init_layer(l: &LayerDef, rng: &mut Pcg32) -> Option<(TensorF32, Vec<f32>)> {
    match &l.kind {
        LayerKind::Conv { geom, .. } => {
            let cf = if geom.depthwise { 1 } else { geom.cin };
            let fan_in = (cf * geom.kh * geom.kw) as f32;
            let std = (2.0 / fan_in).sqrt();
            let mut w = TensorF32::zeros(&[geom.cout, cf, geom.kh, geom.kw]);
            rng.fill_normal(w.data_mut(), std);
            Some((w, vec![0.0; geom.cout]))
        }
        LayerKind::Linear { n_in, n_out, .. } => {
            let std = (2.0 / *n_in as f32).sqrt();
            let mut w = TensorF32::zeros(&[*n_out, *n_in]);
            rng.fill_normal(w.data_mut(), std);
            Some((w, vec![0.0; *n_out]))
        }
        _ => None,
    }
}

/// PTQ calibration result: input range plus per-layer activation ranges.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub input_qp: QParams,
    pub act_qp: Vec<QParams>,
}

/// Run `samples` through the float model and record every layer's output
/// range (post-training quantization calibration).
pub fn calibrate(def: &ModelDef, fp: &FloatParams, samples: &[TensorF32]) -> Calibration {
    let mut in_obs = MinMaxObserver::calibration();
    let mut obs: Vec<MinMaxObserver> =
        def.layers.iter().map(|_| MinMaxObserver::calibration()).collect();
    let mut ops = OpCounter::new();
    for x in samples {
        in_obs.observe(x.data());
        let mut cur = x.clone();
        for (i, l) in def.layers.iter().enumerate() {
            cur = float_layer_fwd(l, &cur, fp.layers[i].as_ref(), &mut ops).0;
            obs[i].observe(cur.data());
        }
    }
    Calibration { input_qp: in_obs.qparams(), act_qp: obs.iter().map(|o| o.qparams()).collect() }
}

fn float_layer_fwd(
    l: &LayerDef,
    x: &TensorF32,
    p: Option<&(TensorF32, Vec<f32>)>,
    ops: &mut OpCounter,
) -> (TensorF32, Option<Vec<u32>>) {
    match &l.kind {
        LayerKind::Conv { geom, relu } => {
            let (w, b) = p.expect("conv params");
            (fconv::fconv2d_fwd(x, w, b, geom, *relu, ops), None)
        }
        LayerKind::Linear { relu, .. } => {
            let (w, b) = p.expect("linear params");
            (flinear::flinear_fwd(x, w, b, *relu, ops), None)
        }
        LayerKind::MaxPool { k } => {
            let o = pool::fmaxpool_fwd(x, *k, ops);
            (o.y, Some(o.argmax))
        }
        LayerKind::GlobalAvgPool => (pool::fgap_fwd(x, ops), None),
        LayerKind::Flatten => (x.reshape(&[x.len()]), None),
    }
}

/// L1 norm of the error per structure (outer dimension: out-channels for
/// conv, rows for linear) — the §III-B ranking heuristic, computed on the
/// dequantized magnitudes.
pub fn structure_norms(e: &Act) -> Vec<f32> {
    match e {
        Act::Q(t) => {
            let z = t.qp.zero_point;
            let s = t.qp.scale;
            (0..t.values.outer_dim())
                .map(|c| {
                    t.values.outer(c).iter().map(|&q| ((q as i32 - z).abs() as f32) * s).sum()
                })
                .collect()
        }
        Act::F(t) => (0..t.outer_dim()).map(|c| crate::util::stats::l1(t.outer(c))).collect(),
    }
}

/// Error-observer update when the float-space error is not directly
/// available (fully quantized path): use the incoming error's dequantized
/// range as the proposal for the next layer's range; the saturation check
/// afterwards widens it if the requantized result clips.
pub(crate) fn propagate_qp(
    obs: &mut MinMaxObserver,
    incoming: &QTensor,
    _ops: &mut OpCounter,
) -> QParams {
    if !obs.has_observed() {
        // bootstrap from the incoming error's range
        let lo = (0 - incoming.qp.zero_point) as f32 * incoming.qp.scale;
        let hi = (255 - incoming.qp.zero_point) as f32 * incoming.qp.scale;
        obs.observe_range(lo, hi);
    }
    obs.qparams()
}

/// Post-hoc range widening: if a noticeable fraction of the requantized
/// error saturates the uint8 range, widen the observer so subsequent
/// samples get more headroom (online analogue of Eqs. 6–7 for errors).
pub(crate) fn observe_saturation(obs: &mut MinMaxObserver, e: &Act) {
    if let Act::Q(t) = e {
        let n = t.len().max(1);
        let sat = t.values.data().iter().filter(|&&v| v == 0 || v == 255).count();
        let (lo, hi) = match obs.range() {
            Some(r) => r,
            None => return,
        };
        if sat * 200 > n {
            // >0.5% saturated: widen by 25%
            obs.observe_range(lo * 1.25, hi * 1.25);
        } else {
            // follow the actual occupied range so scales can also shrink
            let deq = t.dequantize();
            let (dlo, dhi) = crate::util::stats::min_max(deq.data());
            obs.observe_range(dlo, dhi);
        }
    }
}
