//! Shape / pooling layer ops: max pool, global average pool, and the
//! zero-copy flatten view.

use crate::graph::act::{propagate_qp, Act};
use crate::graph::ops::{fwd_input, ExecCtx, LayerOp};
use crate::kernels::pool;

/// Square max pool (window == stride == `k`), with pre-resolved input
/// shape for the backward routing.
pub struct MaxPoolOp {
    pub layer: usize,
    pub k: usize,
    pub in_shape: Vec<usize>,
}

impl LayerOp for MaxPoolOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("maxpool@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let (y, am) = match input {
            Act::Q(xq) => {
                let o = pool::qmaxpool_fwd(xq, self.k, ctx.ops);
                (Act::Q(o.y), o.argmax)
            }
            Act::F(xf) => {
                let o = pool::fmaxpool_fwd(xf, self.k, ctx.ops);
                (Act::F(o.y), o.argmax)
            }
        };
        ctx.argmax[l] = Some(am);
        ctx.acts.push(y);
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        if l <= ctx.stop {
            return;
        }
        let trace = ctx.trace.expect("backward needs a forward trace");
        let am = trace.argmax[l].as_ref().expect("pool argmax");
        let err = ctx.err.take().expect("backward error not set");
        let next = match err {
            Act::Q(eq) => Act::Q(pool::qmaxpool_bwd(&eq, am, &self.in_shape, ctx.ops)),
            Act::F(ef) => Act::F(pool::fmaxpool_bwd(&ef, am, &self.in_shape, ctx.ops)),
        };
        ctx.err = Some(next);
    }
}

/// Global average pool `[C,H,W] -> [C]`.
pub struct GlobalAvgPoolOp {
    pub layer: usize,
    pub in_shape: Vec<usize>,
}

impl LayerOp for GlobalAvgPoolOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("gap@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let y = match input {
            Act::Q(xq) => Act::Q(pool::qgap_fwd(xq, ctx.act_qp[l], ctx.ops)),
            Act::F(xf) => Act::F(pool::fgap_fwd(xf, ctx.ops)),
        };
        ctx.acts.push(y);
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        if l <= ctx.stop {
            return;
        }
        let err = ctx.err.take().expect("backward error not set");
        let next = match err {
            Act::Q(eq) => {
                let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
                let out_qp = propagate_qp(&mut obs[l - 1], &eq, ctx.ops);
                Act::Q(pool::qgap_bwd(&eq, &self.in_shape, out_qp, ctx.ops))
            }
            Act::F(ef) => Act::F(pool::fgap_bwd(&ef, &self.in_shape, ctx.ops)),
        };
        ctx.err = Some(next);
    }
}

/// `[C,H,W] -> [C·H·W]`, as a zero-copy view: the output activation aliases
/// the input buffer (copy-on-write), so flattening costs no allocation and
/// no copy in either pass.
pub struct FlattenOp {
    pub layer: usize,
    pub out_len: usize,
    pub in_shape: Vec<usize>,
}

impl LayerOp for FlattenOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("flatten@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let y = input.reshaped(&[self.out_len]);
        ctx.acts.push(y);
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        if l <= ctx.stop {
            return;
        }
        let err = ctx.err.take().expect("backward error not set");
        ctx.err = Some(err.reshaped(&self.in_shape));
    }
}
