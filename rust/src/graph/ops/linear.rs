//! Fully connected layer ops (quantized and float). Both route their
//! backward GEMMs through the shared cores as degenerate cases, exactly as
//! the pre-plan executor did.
//!
//! Unlike the conv ops, linear layers take no entry in the plan-owned
//! pack cache (`graph::packs`): their backward-input GEMM consumes the
//! `[Out, In]` weight matrix directly in its storage layout (`e_in =
//! eᵀ·W`), so there is no per-sample packing to cache — the forward
//! "pack" is a zero-cost view for linears and convs alike.

use crate::graph::act::{observe_saturation, propagate_qp, Act, LayerParams};
use crate::graph::exec::LayerGrads;
use crate::graph::ops::{fwd_input, sparse_keep, ExecCtx, LayerOp, QpSlot};
use crate::kernels::simd::{self, KernelSel};
use crate::kernels::{fconv, flinear, kept_count, qconv, qlinear};
use crate::quant::{quantize_bias, QTensor};
use crate::tensor::TensorF32;

/// Quantized (uint8) fully connected layer.
pub struct QLinearOp {
    pub layer: usize,
    pub name: String,
    pub relu: bool,
    pub in_qp: QpSlot,
    /// Route through the fused-epilogue kernel twins (see
    /// [`QConvOp`](crate::graph::ops::QConvOp)).
    pub fused: bool,
    /// The dequantize boundary that followed this layer was folded into its
    /// epilogue (see [`QConvOp`](crate::graph::ops::QConvOp)).
    pub fold_dequant: bool,
}

impl LayerOp for QLinearOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("qlinear@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let xq = match input {
            Act::Q(t) => t,
            Act::F(_) => panic!(
                "layer {l} ({}): expected a quantized input activation, found float32",
                self.name
            ),
        };
        let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.fwd));
        let y = match &ctx.params[l] {
            LayerParams::Q { w, bias } => {
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                if self.fused {
                    // A folded dequantize boundary is emitted here, straight
                    // from the register tile (see QConvOp::forward).
                    let n_out = w.shape()[0];
                    let mut deq = self.fold_dequant.then(|| TensorF32::zeros(&[n_out]));
                    let (y, sat) = qlinear::qlinear_fwd_fused_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        ctx.act_qp[l],
                        self.relu,
                        deq.as_mut().map(|t| t.data_mut()),
                        ctx.ops,
                    );
                    ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                    if let Some(d) = deq {
                        ctx.staged = Some(Act::F(d));
                    }
                    y
                } else {
                    qlinear::qlinear_fwd_sel(sel, xq, w, &bq, ctx.act_qp[l], self.relu, ctx.ops)
                }
            }
            // Packed sub-byte weights: the `_pa` twins unpack the weight
            // lanes into scratch ahead of the matvec (bit-exact with the
            // u8 path at every width).
            LayerParams::Qp { w, bias } => {
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                if self.fused {
                    let n_out = w.shape()[0];
                    let mut deq = self.fold_dequant.then(|| TensorF32::zeros(&[n_out]));
                    let (y, sat) = qlinear::qlinear_fwd_fused_pa_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        ctx.act_qp[l],
                        self.relu,
                        deq.as_mut().map(|t| t.data_mut()),
                        ctx.scratch,
                        ctx.ops,
                    );
                    ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                    if let Some(d) = deq {
                        ctx.staged = Some(Act::F(d));
                    }
                    y
                } else {
                    qlinear::qlinear_fwd_pa_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        ctx.act_qp[l],
                        self.relu,
                        ctx.scratch,
                        ctx.ops,
                    )
                }
            }
            other => panic!(
                "layer {l} ({}): expected quantized linear params, found {}",
                self.name,
                other.flavor()
            ),
        };
        ctx.acts.push(Act::Q(y));
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let trace = ctx.trace.expect("backward needs a forward trace");
        let mut err = ctx.err.take().expect("backward error not set");
        // Absorb the folded boundary's error quantization (see
        // QConvOp::backward).
        if self.fold_dequant {
            err = match err {
                Act::F(t) => {
                    let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
                    let o = &mut obs[l];
                    o.observe(t.data());
                    Act::Q(QTensor::quantize_with(&t, o.qparams()))
                }
                q => q,
            };
        }
        let trainable = ctx.layers[l].trainable;
        let keep = sparse_keep(ctx, l, trainable, &err);
        let lin_raw: &Act = if l == 0 { &trace.input } else { &trace.acts[l - 1] };
        let coerced = match lin_raw {
            Act::F(t) => Some(Act::Q(QTensor::quantize_with(t, self.in_qp.resolve(ctx)))),
            Act::Q(_) => None,
        };
        let xq = match coerced.as_ref().unwrap_or(lin_raw) {
            Act::Q(x) => x,
            Act::F(_) => panic!(
                "layer {l} ({}): backward expected a quantized input activation, found float32",
                self.name
            ),
        };
        let eq = match &mut err {
            Act::Q(e) => e,
            Act::F(_) => panic!(
                "layer {l} ({}): backward expected a quantized error, found float32",
                self.name
            ),
        };
        if self.relu {
            if let Act::Q(y) = &trace.acts[l] {
                qconv::relu_bwd_mask_q(eq, y, ctx.ops);
            }
        }
        if trainable {
            let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.bwd_weight));
            let (gw, gb) = qlinear::qlinear_bwd_weight_gemm_sel(
                sel,
                eq,
                xq,
                keep.as_deref(),
                ctx.scratch,
                ctx.ops,
            );
            let total = eq.len();
            let kept = kept_count(keep.as_deref(), total);
            ctx.grads[l] = Some(LayerGrads { gw, gb, kept: (kept, total) });
        }
        if l > ctx.stop {
            let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
            let out_qp = propagate_qp(&mut obs[l - 1], eq, ctx.ops);
            let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.bwd_input));
            let next = match &ctx.params[l] {
                LayerParams::Q { w, .. } => Act::Q(if self.fused {
                    qlinear::qlinear_bwd_input_gemm_fused_sel(
                        sel,
                        eq,
                        w,
                        out_qp,
                        keep.as_deref(),
                        ctx.scratch,
                        ctx.ops,
                    )
                } else {
                    qlinear::qlinear_bwd_input_gemm_sel(
                        sel,
                        eq,
                        w,
                        out_qp,
                        keep.as_deref(),
                        ctx.scratch,
                        ctx.ops,
                    )
                }),
                // The weight matrix is the GEMM's B operand here, so the
                // `_pa` twins unpack the whole packed matrix into the
                // `wq_u8` lane span before the row GEMM.
                LayerParams::Qp { w, .. } => Act::Q(if self.fused {
                    qlinear::qlinear_bwd_input_gemm_fused_pa_sel(
                        sel,
                        eq,
                        w,
                        out_qp,
                        keep.as_deref(),
                        ctx.scratch,
                        ctx.ops,
                    )
                } else {
                    qlinear::qlinear_bwd_input_gemm_pa_sel(
                        sel,
                        eq,
                        w,
                        out_qp,
                        keep.as_deref(),
                        ctx.scratch,
                        ctx.ops,
                    )
                }),
                other => panic!(
                    "layer {l} ({}): backward expected quantized linear params, found {}",
                    self.name,
                    other.flavor()
                ),
            };
            observe_saturation(&mut obs[l - 1], &next);
            ctx.err = Some(next);
        }
    }
}

/// Float fully connected layer.
pub struct FLinearOp {
    pub layer: usize,
    pub name: String,
    pub relu: bool,
}

impl LayerOp for FLinearOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("flinear@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let xf = match input {
            Act::F(t) => t,
            Act::Q(_) => panic!(
                "layer {l} ({}): expected a float32 input activation, found quantized",
                self.name
            ),
        };
        let (w, bias) = match &ctx.params[l] {
            LayerParams::F { w, bias } => (w, bias),
            other => panic!(
                "layer {l} ({}): expected float32 linear params, found {}",
                self.name,
                other.flavor()
            ),
        };
        let y = flinear::flinear_fwd(xf, w, bias, self.relu, ctx.ops);
        ctx.acts.push(Act::F(y));
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let trace = ctx.trace.expect("backward needs a forward trace");
        let mut err = ctx.err.take().expect("backward error not set");
        let trainable = ctx.layers[l].trainable;
        let keep = sparse_keep(ctx, l, trainable, &err);
        let lin_raw: &Act = if l == 0 { &trace.input } else { &trace.acts[l - 1] };
        let coerced = match lin_raw {
            Act::Q(t) => Some(Act::F(t.dequantize())),
            Act::F(_) => None,
        };
        let xf = match coerced.as_ref().unwrap_or(lin_raw) {
            Act::F(x) => x,
            Act::Q(_) => panic!(
                "layer {l} ({}): backward expected a float32 input activation, found quantized",
                self.name
            ),
        };
        let ef = match &mut err {
            Act::F(e) => e,
            Act::Q(_) => panic!(
                "layer {l} ({}): backward expected a float32 error, found quantized",
                self.name
            ),
        };
        if self.relu {
            if let Act::F(y) = &trace.acts[l] {
                fconv::relu_bwd_mask_f(ef, y, ctx.ops);
            }
        }
        let (w, _) = match &ctx.params[l] {
            LayerParams::F { w, bias } => (w, bias),
            other => panic!(
                "layer {l} ({}): backward expected float32 linear params, found {}",
                self.name,
                other.flavor()
            ),
        };
        if trainable {
            let (gw, gb) = flinear::flinear_bwd_weight_gemm(ef, xf, keep.as_deref(), ctx.ops);
            let total = ef.len();
            let kept = kept_count(keep.as_deref(), total);
            ctx.grads[l] = Some(LayerGrads { gw, gb, kept: (kept, total) });
        }
        if l > ctx.stop {
            let next = Act::F(flinear::flinear_bwd_input_gemm(
                ef,
                w,
                keep.as_deref(),
                ctx.scratch,
                ctx.ops,
            ));
            ctx.err = Some(next);
        }
    }
}
