//! Convolution layer ops (quantized and float). Non-depthwise convolutions
//! route through the im2col/GEMM engine exactly as the pre-plan executor
//! did; depthwise convolutions route through the register-blocked
//! depthwise engine (`kernels::dwconv`) — both bit-exact with the scalar
//! MCU-faithful kernels the reference executor retains.

use crate::graph::act::{observe_saturation, propagate_qp, Act, LayerParams};
use crate::graph::exec::LayerGrads;
use crate::graph::ops::{fwd_input, sparse_keep, ExecCtx, LayerOp, QpSlot};
use crate::kernels::simd::{self, KernelSel};
use crate::kernels::{dwconv, fconv, kept_count, qconv, ConvGeom};
use crate::quant::{quantize_bias, QTensor};
use crate::tensor::TensorF32;

/// Quantized (uint8) convolution, with pre-resolved geometry, input spatial
/// extent and input-quantization slot.
pub struct QConvOp {
    pub layer: usize,
    pub name: String,
    pub geom: ConvGeom,
    pub relu: bool,
    pub in_qp: QpSlot,
    pub in_h: usize,
    pub in_w: usize,
    /// Route through the fused-epilogue kernel twins (requantize the
    /// register tile, count saturation) instead of the two-pass oracle.
    pub fused: bool,
    /// The dequantize boundary that followed this layer was folded into its
    /// epilogue: forward emits the float staging tensor directly, backward
    /// absorbs the boundary's error quantization.
    pub fold_dequant: bool,
}

impl LayerOp for QConvOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("qconv@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let xq = match input {
            Act::Q(t) => t,
            Act::F(_) => panic!(
                "layer {l} ({}): expected a quantized input activation, found float32",
                self.name
            ),
        };
        let out_qp = ctx.act_qp[l];
        // Resolve the plan's autotuned preference against the runtime
        // kernel mode and the detected ISA — once per op, not per tile.
        let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.fwd));
        let y = match &ctx.params[l] {
            LayerParams::Q { w, bias } => {
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                if self.geom.depthwise {
                    if self.fused {
                        let (y, sat) = dwconv::qdwconv2d_fwd_fused_sel(
                            sel, xq, w, &bq, &self.geom, out_qp, self.relu, ctx.ops,
                        );
                        ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                        y
                    } else {
                        dwconv::qdwconv2d_fwd_sel(
                            sel, xq, w, &bq, &self.geom, out_qp, self.relu, ctx.ops,
                        )
                    }
                } else if self.fused {
                    // A folded dequantize boundary is emitted here: the
                    // epilogue fills the float staging tensor from the
                    // register tile while requantizing, so the consumer
                    // finds it pre-staged and the boundary op never runs.
                    let (oh, ow) = self.geom.out_hw(self.in_h, self.in_w);
                    let mut deq =
                        self.fold_dequant.then(|| TensorF32::zeros(&[self.geom.cout, oh, ow]));
                    let (y, sat) = qconv::qconv2d_fwd_gemm_fused_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        &self.geom,
                        out_qp,
                        self.relu,
                        deq.as_mut().map(|t| t.data_mut()),
                        ctx.scratch,
                        ctx.ops,
                    );
                    ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                    if let Some(d) = deq {
                        ctx.staged = Some(Act::F(d));
                    }
                    y
                } else {
                    qconv::qconv2d_fwd_gemm_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        &self.geom,
                        out_qp,
                        self.relu,
                        ctx.scratch,
                        ctx.ops,
                    )
                }
            }
            // Packed sub-byte weights: the same engine routing through the
            // `_pa` twins, which unpack the weight lanes into scratch
            // before the tile loop (bit-exact with the u8 path at every
            // width — see `tests/plan_parity.rs`).
            LayerParams::Qp { w, bias } => {
                let bq = quantize_bias(bias, xq.qp.scale, w.qp.scale);
                if self.geom.depthwise {
                    if self.fused {
                        let (y, sat) = dwconv::qdwconv2d_fwd_fused_pa_sel(
                            sel,
                            xq,
                            w,
                            &bq,
                            &self.geom,
                            out_qp,
                            self.relu,
                            ctx.scratch,
                            ctx.ops,
                        );
                        ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                        y
                    } else {
                        dwconv::qdwconv2d_fwd_pa_sel(
                            sel,
                            xq,
                            w,
                            &bq,
                            &self.geom,
                            out_qp,
                            self.relu,
                            ctx.scratch,
                            ctx.ops,
                        )
                    }
                } else if self.fused {
                    let (oh, ow) = self.geom.out_hw(self.in_h, self.in_w);
                    let mut deq =
                        self.fold_dequant.then(|| TensorF32::zeros(&[self.geom.cout, oh, ow]));
                    let (y, sat) = qconv::qconv2d_fwd_gemm_fused_pa_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        &self.geom,
                        out_qp,
                        self.relu,
                        deq.as_mut().map(|t| t.data_mut()),
                        ctx.scratch,
                        ctx.ops,
                    );
                    ctx.sat[l] = Some((sat as usize, y.len().max(1)));
                    if let Some(d) = deq {
                        ctx.staged = Some(Act::F(d));
                    }
                    y
                } else {
                    qconv::qconv2d_fwd_gemm_pa_sel(
                        sel,
                        xq,
                        w,
                        &bq,
                        &self.geom,
                        out_qp,
                        self.relu,
                        ctx.scratch,
                        ctx.ops,
                    )
                }
            }
            other => panic!(
                "layer {l} ({}): expected quantized conv params, found {}",
                self.name,
                other.flavor()
            ),
        };
        ctx.acts.push(Act::Q(y));
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let trace = ctx.trace.expect("backward needs a forward trace");
        let mut err = ctx.err.take().expect("backward error not set");
        // A folded dequantize boundary's backward is absorbed here: observe
        // the incoming float error into this layer's error observer and
        // quantize it with the freshened parameters — exactly what the
        // deleted `DequantizeOp` did one schedule step earlier, before any
        // mask or ReLU processing sees the error.
        if self.fold_dequant {
            err = match err {
                Act::F(t) => {
                    let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
                    let o = &mut obs[l];
                    o.observe(t.data());
                    Act::Q(QTensor::quantize_with(&t, o.qparams()))
                }
                q => q,
            };
        }
        let trainable = ctx.layers[l].trainable;
        let keep = sparse_keep(ctx, l, trainable, &err);
        // Layer input from the trace, coerced into this layer's precision
        // (as in forward).
        let lin_raw: &Act = if l == 0 { &trace.input } else { &trace.acts[l - 1] };
        let coerced = match lin_raw {
            Act::F(t) => Some(Act::Q(QTensor::quantize_with(t, self.in_qp.resolve(ctx)))),
            Act::Q(_) => None,
        };
        let xq = match coerced.as_ref().unwrap_or(lin_raw) {
            Act::Q(x) => x,
            Act::F(_) => panic!(
                "layer {l} ({}): backward expected a quantized input activation, found float32",
                self.name
            ),
        };
        let eq = match &mut err {
            Act::Q(e) => e,
            Act::F(_) => panic!(
                "layer {l} ({}): backward expected a quantized error, found float32",
                self.name
            ),
        };
        if self.relu {
            if let Act::Q(y) = &trace.acts[l] {
                qconv::relu_bwd_mask_q(eq, y, ctx.ops);
            }
        }
        if trainable {
            let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.bwd_weight));
            let (gw, gb) = if self.geom.depthwise {
                dwconv::qdwconv2d_bwd_weight_sel(sel, eq, xq, &self.geom, keep.as_deref(), ctx.ops)
            } else {
                qconv::qconv2d_bwd_weight_gemm_sel(
                    sel,
                    eq,
                    xq,
                    &self.geom,
                    keep.as_deref(),
                    ctx.scratch,
                    ctx.ops,
                )
            };
            let total = self.geom.cout;
            let kept = kept_count(keep.as_deref(), total);
            ctx.grads[l] = Some(LayerGrads { gw, gb, kept: (kept, total) });
        }
        if l > ctx.stop {
            let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
            let out_qp = propagate_qp(&mut obs[l - 1], eq, ctx.ops);
            // Dense backward reads the plan-owned flipped-weight pack when
            // it is fresh for this layer's parameter version; sparse masks
            // (per-sample row subsets) and stale entries fall back to
            // packing into scratch — bit-identical either way. Depthwise
            // packs are per-channel, so the cached pack also serves masked
            // calls (a mask skips whole planes); only a stale entry takes
            // the scratch-packing bypass. Packed sub-byte layers follow the
            // same routing on the `_pa` twins, with width-tagged cache
            // slots (`wt_u8_packed` / `dw_u8_packed`).
            let sel = ctx.packs.choice(l).map_or(KernelSel::Auto, |c| simd::resolve(c.bwd_input));
            let next = match &ctx.params[l] {
                LayerParams::Q { w, .. } => {
                    if self.geom.depthwise {
                        let dw_pack = ctx.packs.dw_u8(l, ctx.param_versions[l]);
                        Act::Q(match dw_pack {
                            Some(pack) => dwconv::qdwconv2d_bwd_input_packed_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.ops,
                            ),
                            None => dwconv::qdwconv2d_bwd_input_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            ),
                        })
                    } else if let Some(pack) = (keep.is_none())
                        .then(|| ctx.packs.wt_u8(l, ctx.param_versions[l]))
                        .flatten()
                    {
                        Act::Q(if self.fused {
                            qconv::qconv2d_bwd_input_gemm_packed_fused_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                ctx.scratch,
                                ctx.ops,
                            )
                        } else {
                            qconv::qconv2d_bwd_input_gemm_packed_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                ctx.scratch,
                                ctx.ops,
                            )
                        })
                    } else {
                        Act::Q(if self.fused {
                            qconv::qconv2d_bwd_input_gemm_fused_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            )
                        } else {
                            qconv::qconv2d_bwd_input_gemm_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            )
                        })
                    }
                }
                LayerParams::Qp { w, .. } => {
                    if self.geom.depthwise {
                        let dw_pack = ctx.packs.dw_u8_packed(l, ctx.param_versions[l]);
                        Act::Q(match dw_pack {
                            Some((pack, bits)) => dwconv::qdwconv2d_bwd_input_packed_pa_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                bits,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            ),
                            None => dwconv::qdwconv2d_bwd_input_pa_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            ),
                        })
                    } else if let Some((pack, bits)) = (keep.is_none())
                        .then(|| ctx.packs.wt_u8_packed(l, ctx.param_versions[l]))
                        .flatten()
                    {
                        Act::Q(if self.fused {
                            qconv::qconv2d_bwd_input_gemm_packed_fused_pa_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                bits,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                ctx.scratch,
                                ctx.ops,
                            )
                        } else {
                            qconv::qconv2d_bwd_input_gemm_packed_pa_sel(
                                sel,
                                eq,
                                w,
                                pack,
                                bits,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                ctx.scratch,
                                ctx.ops,
                            )
                        })
                    } else {
                        Act::Q(if self.fused {
                            qconv::qconv2d_bwd_input_gemm_fused_pa_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            )
                        } else {
                            qconv::qconv2d_bwd_input_gemm_pa_sel(
                                sel,
                                eq,
                                w,
                                &self.geom,
                                self.in_h,
                                self.in_w,
                                out_qp,
                                keep.as_deref(),
                                ctx.scratch,
                                ctx.ops,
                            )
                        })
                    }
                }
                other => panic!(
                    "layer {l} ({}): backward expected quantized conv params, found {}",
                    self.name,
                    other.flavor()
                ),
            };
            observe_saturation(&mut obs[l - 1], &next);
            ctx.err = Some(next);
        }
    }
}

/// Float convolution, mirroring [`QConvOp`] on the float kernel set.
pub struct FConvOp {
    pub layer: usize,
    pub name: String,
    pub geom: ConvGeom,
    pub relu: bool,
    pub in_h: usize,
    pub in_w: usize,
}

impl LayerOp for FConvOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("fconv@{}", self.layer)
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let staged = ctx.staged.take();
        let input = fwd_input(&staged, &ctx.input, &ctx.acts, l);
        let xf = match input {
            Act::F(t) => t,
            Act::Q(_) => panic!(
                "layer {l} ({}): expected a float32 input activation, found quantized",
                self.name
            ),
        };
        let (w, bias) = match &ctx.params[l] {
            LayerParams::F { w, bias } => (w, bias),
            other => panic!(
                "layer {l} ({}): expected float32 conv params, found {}",
                self.name,
                other.flavor()
            ),
        };
        let y = if self.geom.depthwise {
            dwconv::fdwconv2d_fwd(xf, w, bias, &self.geom, self.relu, ctx.ops)
        } else {
            fconv::fconv2d_fwd_gemm(xf, w, bias, &self.geom, self.relu, ctx.scratch, ctx.ops)
        };
        ctx.acts.push(Act::F(y));
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let l = self.layer;
        let trace = ctx.trace.expect("backward needs a forward trace");
        let mut err = ctx.err.take().expect("backward error not set");
        let trainable = ctx.layers[l].trainable;
        let keep = sparse_keep(ctx, l, trainable, &err);
        let lin_raw: &Act = if l == 0 { &trace.input } else { &trace.acts[l - 1] };
        let coerced = match lin_raw {
            Act::Q(t) => Some(Act::F(t.dequantize())),
            Act::F(_) => None,
        };
        let xf = match coerced.as_ref().unwrap_or(lin_raw) {
            Act::F(x) => x,
            Act::Q(_) => panic!(
                "layer {l} ({}): backward expected a float32 input activation, found quantized",
                self.name
            ),
        };
        let ef = match &mut err {
            Act::F(e) => e,
            Act::Q(_) => panic!(
                "layer {l} ({}): backward expected a float32 error, found quantized",
                self.name
            ),
        };
        if self.relu {
            if let Act::F(y) = &trace.acts[l] {
                fconv::relu_bwd_mask_f(ef, y, ctx.ops);
            }
        }
        let (w, _) = match &ctx.params[l] {
            LayerParams::F { w, bias } => (w, bias),
            other => panic!(
                "layer {l} ({}): backward expected float32 conv params, found {}",
                self.name,
                other.flavor()
            ),
        };
        if trainable {
            let (gw, gb) = if self.geom.depthwise {
                dwconv::fdwconv2d_bwd_weight(ef, xf, &self.geom, keep.as_deref(), ctx.ops)
            } else {
                fconv::fconv2d_bwd_weight_gemm(
                    ef,
                    xf,
                    &self.geom,
                    keep.as_deref(),
                    ctx.scratch,
                    ctx.ops,
                )
            };
            let total = self.geom.cout;
            let kept = kept_count(keep.as_deref(), total);
            ctx.grads[l] = Some(LayerGrads { gw, gb, kept: (kept, total) });
        }
        if l > ctx.stop {
            // Same pack-cache routing as the quantized op (see QConvOp).
            let cached = if keep.is_none() && !self.geom.depthwise {
                ctx.packs.wt_f32(l, ctx.param_versions[l])
            } else {
                None
            };
            let next = if self.geom.depthwise {
                let dw_pack = ctx.packs.dw_f32(l, ctx.param_versions[l]);
                Act::F(match dw_pack {
                    Some(pack) => dwconv::fdwconv2d_bwd_input_packed(
                        ef,
                        pack,
                        &self.geom,
                        self.in_h,
                        self.in_w,
                        keep.as_deref(),
                        ctx.ops,
                    ),
                    None => dwconv::fdwconv2d_bwd_input(
                        ef,
                        w,
                        &self.geom,
                        self.in_h,
                        self.in_w,
                        keep.as_deref(),
                        ctx.scratch,
                        ctx.ops,
                    ),
                })
            } else if let Some(pack) = cached {
                Act::F(fconv::fconv2d_bwd_input_gemm_packed(
                    ef,
                    pack,
                    &self.geom,
                    self.in_h,
                    self.in_w,
                    ctx.scratch,
                    ctx.ops,
                ))
            } else {
                Act::F(fconv::fconv2d_bwd_input_gemm(
                    ef,
                    w,
                    &self.geom,
                    self.in_h,
                    self.in_w,
                    keep.as_deref(),
                    ctx.scratch,
                    ctx.ops,
                ))
            };
            ctx.err = Some(next);
        }
    }
}
