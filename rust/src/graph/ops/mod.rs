//! The compiled layer operations executed by the plan
//! ([`crate::graph::plan::ExecPlan`]).
//!
//! Each [`LayerOp`] is one step of the compiled schedule, carrying
//! everything that is static across samples — geometry, precision,
//! pre-resolved input-quantization slots, layer indices — so the per-sample
//! passes are pure dispatch over a `Vec<Box<dyn LayerOp>>` with no shape
//! inference, precision matching or parameter probing on the hot path.
//!
//! Two op families exist:
//!
//!  * **compute ops** (`QConvOp`, `FConvOp`, `QLinearOp`, `FLinearOp`,
//!    `MaxPoolOp`, `GlobalAvgPoolOp`, `FlattenOp`) — one per graph layer,
//!    calling the exact same kernels as the pre-plan executor did, so
//!    outputs and [`OpCounter`] accounting are bit-identical;
//!  * **boundary ops** (`QuantizeOp`, `DequantizeOp`) — the precision
//!    coercions that previously hid inside the forward/backward loops,
//!    made explicit plan steps. In the forward direction they coerce the
//!    running activation into the next layer's precision; in the backward
//!    direction they coerce the error tensor the opposite way (observing
//!    float errors into the per-layer min/max observers exactly as
//!    before).
//!
//! The numerics contract is strict: for every model × configuration the
//! planned passes produce bit-identical activations, logits, gradients,
//! observer states and op counts to the straight-line reference executor
//! ([`crate::graph::reference`]) — enforced by `tests/plan_parity.rs`.

mod conv;
mod linear;
mod shape;

pub use conv::{FConvOp, QConvOp};
pub use linear::{FLinearOp, QLinearOp};
pub use shape::{FlattenOp, GlobalAvgPoolOp, MaxPoolOp};

use crate::graph::act::{structure_norms, Act, LayerParams};
use crate::graph::exec::{FwdTrace, LayerGrads, MaskProvider};
use crate::graph::packs::PackCache;
use crate::graph::{LayerDef, Precision};
use crate::kernels::OpCounter;
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::quant::{QParams, QTensor};

/// Where a layer's input quantization parameters live, resolved at plan
/// time: the nearest preceding producer layer (conv / linear / global
/// average pool), or the network input. The *values* are read at run time
/// because activation-range adaptation moves them between steps.
#[derive(Clone, Copy, Debug)]
pub enum QpSlot {
    /// The network input's quantization parameters.
    Input,
    /// The activation parameters of producer layer `j`.
    Layer(usize),
}

impl QpSlot {
    pub fn resolve(&self, ctx: &ExecCtx) -> QParams {
        match self {
            QpSlot::Input => ctx.input_qp,
            QpSlot::Layer(j) => ctx.act_qp[*j],
        }
    }
}

/// Mutable execution state threaded through the plan ops. Forward passes
/// populate `acts`/`argmax`; backward passes consume a [`FwdTrace`] and
/// populate `grads`. Model state (parameters, precisions, quantization
/// parameters) is borrowed read-only, so concurrent workers can execute
/// the same plan over a shared model snapshot.
pub struct ExecCtx<'a> {
    /// Per-layer deployed parameters (read-only).
    pub params: &'a [LayerParams],
    /// Per-layer precision under the deployed configuration.
    pub prec: &'a [Precision],
    /// Per-layer activation quantization parameters.
    pub act_qp: &'a [QParams],
    /// Network-input quantization parameters.
    pub input_qp: QParams,
    /// Layer definitions (names, trainable flags).
    pub layers: &'a [LayerDef],
    /// Earliest layer the backward pass reaches (first trainable layer).
    pub stop: usize,
    /// GEMM scratch arena (im2col packings, accumulators).
    pub scratch: &'a mut Scratch,
    /// Plan-owned dense backward weight packs (read-only — shared across
    /// concurrent batch workers; see `graph::packs`).
    pub packs: &'a PackCache,
    /// Per-layer parameter versions, the pack cache's freshness key.
    pub param_versions: &'a [u64],
    /// Arithmetic accounting.
    pub ops: &'a mut OpCounter,
    /// Forward: the precision-coerced network input.
    pub input: Option<Act>,
    /// Forward: per-layer outputs, pushed in execution order.
    pub acts: Vec<Act>,
    /// Forward: max-pool argmax routes.
    pub argmax: Vec<Option<Vec<u32>>>,
    /// Forward: per-layer `(saturated, total)` output-range saturation
    /// counts, recorded by the fused kernel epilogues as they requantize
    /// the register tile. `None` for layers the fused path did not visit
    /// (float layers, depthwise-boundary cases, or unfused plans).
    pub sat: Vec<Option<(usize, usize)>>,
    /// Forward: output of a boundary op awaiting the next compute op.
    pub staged: Option<Act>,
    /// Backward: the forward trace being differentiated.
    pub trace: Option<&'a FwdTrace>,
    /// Backward: error w.r.t. the current layer's output.
    pub err: Option<Act>,
    /// Backward: per-layer error observers.
    pub err_obs: Option<&'a mut [MinMaxObserver]>,
    /// Backward: sparse-update mask provider (§III-B controller).
    pub masks: Option<&'a mut dyn MaskProvider>,
    /// Backward: per-layer gradients, aligned with the layer list.
    pub grads: Vec<Option<LayerGrads>>,
}

/// Resolve a compute op's forward input: the staged boundary output if one
/// exists, else the previous layer's activation (the network input for
/// layer 0). Takes the needed context fields separately so callers keep
/// `ctx.scratch` / `ctx.ops` mutably borrowable while the input is live.
pub(crate) fn fwd_input<'a>(
    staged: &'a Option<Act>,
    input: &'a Option<Act>,
    acts: &'a [Act],
    layer: usize,
) -> &'a Act {
    match staged {
        Some(a) => a,
        None if layer == 0 => input.as_ref().expect("forward input not set"),
        None => &acts[layer - 1],
    }
}

/// Ask the §III-B controller for this layer's structure mask (trainable
/// layers only), computed from the pre-ReLU error norms — the exact call
/// sequence of the reference executor, which keeps the controller's
/// internal state bit-identical between the two paths.
pub(crate) fn sparse_keep(
    ctx: &mut ExecCtx,
    layer: usize,
    trainable: bool,
    err: &Act,
) -> Option<Vec<bool>> {
    if !trainable {
        return None;
    }
    let norms = structure_norms(err);
    ctx.masks.as_mut().expect("backward mask provider not set").mask(layer, &norms)
}

/// One compiled step of the execution plan. `forward` consumes the previous
/// layer's activation (or the staged boundary output) and pushes its own;
/// `backward` consumes `ctx.err` and replaces it with the error w.r.t. its
/// input, filling `ctx.grads` for trainable layers.
pub trait LayerOp: Send + Sync {
    /// Index of the graph layer this op belongs to (boundary ops carry the
    /// index of the layer they feed).
    fn layer(&self) -> usize;

    /// Short diagnostic label, e.g. `"qconv@3"`.
    fn describe(&self) -> String;

    /// Whether this op participates in a backward pass that stops at layer
    /// `stop`. Compute ops run down to and including `stop`; boundary ops
    /// sit *between* layers and only run while the error still propagates
    /// past them.
    fn runs_backward(&self, stop: usize) -> bool {
        self.layer() >= stop
    }

    fn forward(&self, ctx: &mut ExecCtx);

    fn backward(&self, ctx: &mut ExecCtx);
}

/// Forward boundary: quantize the running float activation into the target
/// layer's uint8 representation. Backward: dequantize the error crossing
/// the same boundary in reverse.
///
/// None of the three shipping `DnnConfig`s produce a float→uint8 crossing
/// (`Mixed` crosses uint8→float exactly once), so this op is compiled only
/// for future configurations; it is the exact mirror of [`DequantizeOp`],
/// whose path the parity suite does exercise.
pub struct QuantizeOp {
    pub layer: usize,
    pub qp: QpSlot,
}

impl LayerOp for QuantizeOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("quantize@{}", self.layer)
    }

    fn runs_backward(&self, stop: usize) -> bool {
        self.layer > stop
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let qp = self.qp.resolve(ctx);
        let src = &ctx.acts[self.layer - 1];
        let staged = match src {
            Act::F(t) => Act::Q(QTensor::quantize_with(t, qp)),
            Act::Q(_) => panic!(
                "boundary op before layer {}: expected a float activation to quantize",
                self.layer
            ),
        };
        ctx.staged = Some(staged);
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let err = ctx.err.take().expect("backward error not set at quantize boundary");
        let next = match err {
            Act::Q(t) => Act::F(t.dequantize()),
            Act::F(t) => Act::F(t),
        };
        ctx.err = Some(next);
    }
}

/// Forward boundary: dequantize the running uint8 activation for a float
/// target layer. Backward: observe the float error into the previous
/// layer's min/max observer and quantize it (the fully quantized error
/// path of §III-A).
pub struct DequantizeOp {
    pub layer: usize,
}

impl LayerOp for DequantizeOp {
    fn layer(&self) -> usize {
        self.layer
    }

    fn describe(&self) -> String {
        format!("dequantize@{}", self.layer)
    }

    fn runs_backward(&self, stop: usize) -> bool {
        self.layer > stop
    }

    fn forward(&self, ctx: &mut ExecCtx) {
        let src = &ctx.acts[self.layer - 1];
        let staged = match src {
            Act::Q(t) => Act::F(t.dequantize()),
            Act::F(_) => panic!(
                "boundary op before layer {}: expected a quantized activation to dequantize",
                self.layer
            ),
        };
        ctx.staged = Some(staged);
    }

    fn backward(&self, ctx: &mut ExecCtx) {
        let err = ctx.err.take().expect("backward error not set at dequantize boundary");
        let next = match err {
            Act::F(t) => {
                let obs = ctx.err_obs.as_mut().expect("backward error observers not set");
                let o = &mut obs[self.layer - 1];
                o.observe(t.data());
                Act::Q(QTensor::quantize_with(&t, o.qparams()))
            }
            Act::Q(t) => Act::Q(t),
        };
        ctx.err = Some(next);
    }
}
