//! Batched/threaded execution engine: shard minibatch samples across
//! `std::thread` workers against a frozen model snapshot, with a
//! deterministic sample-order merge — bit-identical results for every
//! worker count (the determinism contract; see DESIGN.md).

use crate::graph::exec::{BwdResult, DenseUpdates, NativeModel};
use crate::kernels::{softmax, OpCounter};
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::tensor::TensorF32;

/// Result of one batched training pass ([`NativeModel::train_batch`]):
/// per-sample outputs in sample order plus fwd/bwd op totals.
pub struct BatchResult {
    pub losses: Vec<f32>,
    pub preds: Vec<usize>,
    /// Per-sample gradients, in sample order. Feed them to the optimizer in
    /// this order — gradient accumulation then stays bit-identical to the
    /// one-worker path regardless of how samples were sharded.
    pub grads: Vec<BwdResult>,
    pub fwd_ops: OpCounter,
    pub bwd_ops: OpCounter,
}

/// One sample's worth of work inside a batch (worker-side record; merged
/// deterministically on the coordinating thread).
struct SamplePass {
    loss: f32,
    pred: usize,
    grads: BwdResult,
    err_obs: Vec<MinMaxObserver>,
    sat: Vec<Option<(usize, usize)>>,
    fwd_ops: OpCounter,
    bwd_ops: OpCounter,
}

impl NativeModel {
    /// One sample of a batch, computed against the *frozen* model snapshot
    /// (`&self`): forward + saturation telemetry + backward against a local
    /// copy of the error observers. Shard-independent by construction.
    fn batch_sample_pass(&self, x: &TensorF32, label: usize, scratch: &mut Scratch) -> SamplePass {
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        let trace = self.forward_in(x, scratch, &mut fwd_ops);
        let sat = self.measure_saturation(&trace, &mut fwd_ops);
        let (loss, probs, err) = softmax::softmax_ce(&trace.logits, label, &mut bwd_ops);
        let pred = softmax::predict(&probs);
        let mut err_obs = self.err_obs.clone();
        let grads = self.backward_with(
            &trace,
            err,
            &mut DenseUpdates,
            &mut err_obs,
            scratch,
            &mut bwd_ops,
        );
        SamplePass { loss, pred, grads, err_obs, sat, fwd_ops, bwd_ops }
    }

    /// Batched training pass: run forward+backward for every sample of a
    /// minibatch, sharding samples across `workers` `std::thread` workers.
    ///
    /// Semantics (chosen so results are **bit-identical for every worker
    /// count**, including 1):
    ///
    ///  * every sample is evaluated against the same model snapshot — the
    ///    state at batch entry (activation ranges, error observers,
    ///    weights);
    ///  * each sample's backward runs against a private copy of the error
    ///    observers taken at batch entry;
    ///  * after all samples finish, the per-sample observer ranges and
    ///    activation-saturation telemetry are folded into the model
    ///    **in sample order** on the coordinating thread.
    ///
    /// Gradient application stays with the caller: [`BatchResult::grads`]
    /// holds per-sample gradients in sample order, so feeding them to an
    /// optimizer reproduces the sequential accumulation bit-for-bit. The
    /// dynamic sparse controller is inherently sequential (its Eq. 9 state
    /// advances per sample), so the batch engine always computes dense
    /// gradients; sparse runs stay on [`NativeModel::train_sample`].
    ///
    /// Each worker builds its scratch arena at spawn — pre-sized from the
    /// compiled plan, so it never grows — and reuses it across its samples.
    pub fn train_batch(&mut self, xs: &[&TensorF32], ys: &[usize], workers: usize) -> BatchResult {
        assert_eq!(xs.len(), ys.len(), "one label per sample");
        let n = xs.len();
        let workers = workers.max(1).min(n.max(1));
        let mut passes: Vec<Option<SamplePass>> = (0..n).map(|_| None).collect();

        if workers <= 1 {
            let mut scratch = self.make_scratch();
            for i in 0..n {
                passes[i] = Some(self.batch_sample_pass(xs[i], ys[i], &mut scratch));
            }
        } else {
            let model: &NativeModel = self;
            let chunk = n.div_ceil(workers);
            let results: Vec<Vec<(usize, SamplePass)>> = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for wi in 0..workers {
                    let lo = wi * chunk;
                    let hi = ((wi + 1) * chunk).min(n);
                    if lo >= hi {
                        break;
                    }
                    let wxs = &xs[lo..hi];
                    let wys = &ys[lo..hi];
                    handles.push(s.spawn(move || {
                        let mut scratch = model.make_scratch();
                        let mut out = Vec::with_capacity(wxs.len());
                        for (j, (&x, &y)) in wxs.iter().zip(wys.iter()).enumerate() {
                            out.push((lo + j, model.batch_sample_pass(x, y, &mut scratch)));
                        }
                        out
                    }));
                }
                handles.into_iter().map(|h| h.join().expect("batch worker panicked")).collect()
            });
            for (i, p) in results.into_iter().flatten() {
                passes[i] = Some(p);
            }
        }

        // Deterministic merge, in sample order.
        let mut losses = Vec::with_capacity(n);
        let mut preds = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        for p in passes.into_iter() {
            let p = p.expect("every batch sample must produce a pass");
            self.apply_range_adaptation(&p.sat);
            for (obs, local) in self.err_obs.iter_mut().zip(p.err_obs.iter()) {
                if let Some((lo, hi)) = local.range() {
                    obs.observe_range(lo, hi);
                }
            }
            fwd_ops.add(&p.fwd_ops);
            bwd_ops.add(&p.bwd_ops);
            losses.push(p.loss);
            preds.push(p.pred);
            grads.push(p.grads);
        }
        BatchResult { losses, preds, grads, fwd_ops, bwd_ops }
    }
}
