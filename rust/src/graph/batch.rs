//! Batched/threaded execution engine: shard minibatch samples across a
//! **persistent worker pool** against a frozen model snapshot, with a
//! deterministic sample-order merge — bit-identical results for every
//! worker count (the determinism contract; see DESIGN.md).
//!
//! PRs 1–3 spawned fresh `std::thread` workers for every minibatch; the
//! spawn/join cost and the per-spawn scratch construction sat on the hot
//! path. [`WorkerPool`] keeps the threads alive for the whole training
//! run: each worker owns one persistent [`Scratch`] arena (grown to the
//! plan's working set on its first batch, reused ever after), jobs arrive
//! over per-worker channels, and the scoped dispatch
//! [`WorkerPool::run_scope`] blocks until every job of the batch has
//! acknowledged — which is what makes lending the workers non-`'static`
//! borrows (the model snapshot, the batch's sample slices) sound.
//!
//! Determinism is untouched by pooling: each sample's pass depends only
//! on the frozen model snapshot and its own inputs (scratch contents are
//! fully overwritten per call), results land in per-sample slots of a
//! pre-split output vector, and the merge folds them in sample order on
//! the coordinating thread — so any sharding, any worker count, and any
//! completion order produce bit-identical weights.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::graph::exec::{BwdResult, DenseUpdates, NativeModel};
use crate::kernels::{softmax, OpCounter};
use crate::memplan::Scratch;
use crate::quant::observer::MinMaxObserver;
use crate::tensor::TensorF32;

/// A unit of pool work, bounded by the dispatching scope's borrows. It
/// runs against the executing worker's persistent scratch arena.
pub type ScopedJob<'env> = Box<dyn FnOnce(&mut Scratch) + Send + 'env>;

/// The `'static` form that actually crosses the channel (see the SAFETY
/// argument in [`WorkerPool::run_scope`]).
type Job = ScopedJob<'static>;

/// A job's completion acknowledgement: `Err` carries a panic payload to
/// re-raise on the coordinating thread.
type Ack = Result<(), Box<dyn std::any::Any + Send + 'static>>;

/// A persistent, channel-fed worker pool. Owned by the training loop (one
/// pool per run — see `train::loop_::train_batched`) or any other batch
/// driver; [`NativeModel::train_batch`] spins up a transient one for
/// callers without a run-long pool.
pub struct WorkerPool {
    txs: Vec<Sender<Job>>,
    done_rx: Receiver<Ack>,
    handles: Vec<JoinHandle<()>>,
    /// Persistent scratch for batches that use a single worker: those run
    /// inline on the dispatching thread (no channel hop when there is no
    /// parallelism to gain), against this arena instead of a pool
    /// thread's.
    inline: Scratch,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` pool threads, each owning a persistent
    /// scratch arena that serves every job it ever runs.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let (done_tx, done_rx) = channel::<Ack>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                // The worker-lifetime arena: grows to the compiled plan's
                // working set on the first batch, then serves every
                // subsequent minibatch of the run with zero growth.
                let mut scratch = Scratch::new();
                while let Ok(job) = rx.recv() {
                    let ack = catch_unwind(AssertUnwindSafe(|| job(&mut scratch)));
                    if done.send(ack).is_err() {
                        break;
                    }
                }
            }));
            txs.push(tx);
        }
        WorkerPool { txs, done_rx, handles, inline: Scratch::new() }
    }

    /// The dispatching-thread arena backing single-worker batches (see
    /// the `inline` field).
    fn inline_scratch(&mut self) -> &mut Scratch {
        &mut self.inline
    }

    /// Number of pool threads.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Dispatch `jobs` round-robin across the pool and block until every
    /// one has completed. Panics from jobs are re-raised here (after all
    /// jobs finished, so no borrow outlives the scope).
    ///
    /// Takes `&mut self` deliberately: the soundness of the lifetime
    /// erasure below requires that the acks drained here belong to *this*
    /// dispatch — exclusive access makes overlapping dispatches (which
    /// could steal each other's acks and return early) a compile error
    /// rather than a convention.
    pub fn run_scope(&mut self, jobs: Vec<ScopedJob<'_>>) {
        let mut sent = 0usize;
        let mut dispatch_failed = false;
        for (wi, job) in jobs.into_iter().enumerate() {
            // SAFETY: the job is only lengthened from 'env to 'static for
            // the channel crossing (identical layout — both are boxed
            // trait objects). Every borrow it captures stays valid until
            // this function returns, and this function does not return
            // until each sent job has either acknowledged completion or
            // been dropped unexecuted (its worker exited, closing the ack
            // channel) — so no job can run, or exist, after 'env ends.
            // `&mut self` guarantees no concurrent dispatch interleaves
            // its acks with ours.
            let job: Job = unsafe { std::mem::transmute::<ScopedJob<'_>, Job>(job) };
            if self.txs[wi % self.txs.len()].send(job).is_err() {
                dispatch_failed = true;
                break;
            }
            sent += 1;
        }
        let mut payload = None;
        for _ in 0..sent {
            match self.done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(p)) => payload = payload.or(Some(p)),
                // Disconnected: every worker exited, so no sent job is
                // still running (undelivered ones were dropped with the
                // queues) — safe to stop draining.
                Err(_) => break,
            }
        }
        if let Some(p) = payload {
            resume_unwind(p);
        }
        assert!(!dispatch_failed, "batch worker pool: a worker exited unexpectedly");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channels ends the worker loops; join to make
        // thread shutdown deterministic.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Result of one batched training pass ([`NativeModel::train_batch`]):
/// per-sample outputs in sample order plus fwd/bwd op totals.
pub struct BatchResult {
    pub losses: Vec<f32>,
    pub preds: Vec<usize>,
    /// Per-sample gradients, in sample order. Feed them to the optimizer in
    /// this order — gradient accumulation then stays bit-identical to the
    /// one-worker path regardless of how samples were sharded.
    pub grads: Vec<BwdResult>,
    pub fwd_ops: OpCounter,
    pub bwd_ops: OpCounter,
}

/// One sample's worth of work inside a batch (worker-side record; merged
/// deterministically on the coordinating thread).
struct SamplePass {
    loss: f32,
    pred: usize,
    grads: BwdResult,
    err_obs: Vec<MinMaxObserver>,
    sat: Vec<Option<(usize, usize)>>,
    fwd_ops: OpCounter,
    bwd_ops: OpCounter,
}

impl NativeModel {
    /// One sample of a batch, computed against the *frozen* model snapshot
    /// (`&self`): forward + saturation telemetry + backward against a local
    /// copy of the error observers. Shard-independent by construction.
    fn batch_sample_pass(&self, x: &TensorF32, label: usize, scratch: &mut Scratch) -> SamplePass {
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        let trace = self.forward_in(x, scratch, &mut fwd_ops);
        let sat = self.measure_saturation(&trace, &mut fwd_ops);
        let (loss, probs, err) = softmax::softmax_ce(&trace.logits, label, &mut bwd_ops);
        let pred = softmax::predict(&probs);
        let mut err_obs = self.state.err_obs.clone();
        let grads = self.backward_with(
            &trace,
            err,
            &mut DenseUpdates,
            &mut err_obs,
            scratch,
            &mut bwd_ops,
        );
        SamplePass { loss, pred, grads, err_obs, sat, fwd_ops, bwd_ops }
    }

    /// [`NativeModel::train_batch`] against a caller-owned persistent
    /// [`WorkerPool`] — the hot-loop entry point: the training loop owns
    /// one pool for the whole run, so no threads are spawned and no
    /// scratch arenas are constructed per minibatch.
    ///
    /// Semantics (chosen so results are **bit-identical for every worker
    /// count**, including 1):
    ///
    ///  * every sample is evaluated against the same model snapshot — the
    ///    state at batch entry (activation ranges, error observers,
    ///    weights, packed-weight cache — warmed here, before sharding, so
    ///    concurrent workers only ever read it);
    ///  * each sample's backward runs against a private copy of the error
    ///    observers taken at batch entry;
    ///  * after all samples finish, the per-sample observer ranges and
    ///    activation-saturation telemetry are folded into the model
    ///    **in sample order** on the coordinating thread.
    ///
    /// Gradient application stays with the caller: [`BatchResult::grads`]
    /// holds per-sample gradients in sample order, so feeding them to an
    /// optimizer reproduces the sequential accumulation bit-for-bit. The
    /// dynamic sparse controller is inherently sequential (its Eq. 9 state
    /// advances per sample), so the batch engine always computes dense
    /// gradients; sparse runs stay on [`NativeModel::train_sample`].
    pub fn train_batch_pooled(
        &mut self,
        xs: &[&TensorF32],
        ys: &[usize],
        pool: &mut WorkerPool,
    ) -> BatchResult {
        assert_eq!(xs.len(), ys.len(), "one label per sample");
        let n = xs.len();
        if n == 0 {
            return BatchResult {
                losses: Vec::new(),
                preds: Vec::new(),
                grads: Vec::new(),
                fwd_ops: OpCounter::new(),
                bwd_ops: OpCounter::new(),
            };
        }
        // Re-pack any backward pack the optimizer invalidated since the
        // last batch, while the model is still exclusively borrowed.
        self.warm_packs();

        let used = pool.workers().min(n);
        let chunk = n.div_ceil(used);
        let mut passes: Vec<Option<SamplePass>> = (0..n).map(|_| None).collect();
        if used <= 1 {
            // No parallelism to gain: run inline on this thread against
            // the pool's persistent inline arena (zero channel hops,
            // identical per-sample results — determinism is per-sample).
            let scratch = pool.inline_scratch();
            for (i, (&x, &y)) in xs.iter().zip(ys.iter()).enumerate() {
                passes[i] = Some(self.batch_sample_pass(x, y, scratch));
            }
        } else {
            let model: &NativeModel = self;
            let jobs: Vec<ScopedJob<'_>> = passes
                .chunks_mut(chunk)
                .zip(xs.chunks(chunk))
                .zip(ys.chunks(chunk))
                .map(|((pslice, wxs), wys)| {
                    Box::new(move |scratch: &mut Scratch| {
                        for ((p, &x), &y) in pslice.iter_mut().zip(wxs.iter()).zip(wys.iter()) {
                            *p = Some(model.batch_sample_pass(x, y, scratch));
                        }
                    }) as ScopedJob<'_>
                })
                .collect();
            pool.run_scope(jobs);
        }

        // Deterministic merge, in sample order.
        let mut losses = Vec::with_capacity(n);
        let mut preds = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        let mut fwd_ops = OpCounter::new();
        let mut bwd_ops = OpCounter::new();
        for p in passes.into_iter() {
            let p = p.expect("every batch sample must produce a pass");
            self.apply_range_adaptation(&p.sat);
            for (obs, local) in self.state.err_obs.iter_mut().zip(p.err_obs.iter()) {
                if let Some((lo, hi)) = local.range() {
                    obs.observe_range(lo, hi);
                }
            }
            fwd_ops.add(&p.fwd_ops);
            bwd_ops.add(&p.bwd_ops);
            losses.push(p.loss);
            preds.push(p.pred);
            grads.push(p.grads);
        }
        BatchResult { losses, preds, grads, fwd_ops, bwd_ops }
    }

    /// Batched training pass over a transient pool of `workers` threads.
    /// Convenience wrapper over [`NativeModel::train_batch_pooled`] for
    /// callers without a run-long pool; hot loops should build one
    /// [`WorkerPool`] per run and call the pooled variant directly.
    pub fn train_batch(&mut self, xs: &[&TensorF32], ys: &[usize], workers: usize) -> BatchResult {
        let mut pool = WorkerPool::new(workers.max(1).min(xs.len().max(1)));
        self.train_batch_pooled(xs, ys, &mut pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_jobs_against_persistent_scratch() {
        let mut pool = WorkerPool::new(1);
        // Two scoped dispatches on the same worker: the second observes
        // the arena capacity the first one grew (persistence across
        // batches).
        let mut grew = 0usize;
        {
            let grew = &mut grew;
            pool.run_scope(vec![Box::new(move |s: &mut Scratch| {
                let _ = s.qconv_bufs(128, 64);
                *grew = s.reserved_bytes();
            })]);
        }
        assert!(grew > 0);
        let mut still = 0usize;
        {
            let still = &mut still;
            pool.run_scope(vec![Box::new(move |s: &mut Scratch| {
                let bytes = s.reserved_bytes();
                *still = bytes;
            })]);
        }
        assert_eq!(still, grew, "worker scratch must persist across dispatches");
    }

    #[test]
    fn pool_completes_all_jobs_across_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<ScopedJob<'_>> = (0..7)
            .map(|_| {
                let c = &counter;
                Box::new(move |_: &mut Scratch| {
                    let _ = c.fetch_add(1, Ordering::SeqCst);
                }) as ScopedJob<'_>
            })
            .collect();
        pool.run_scope(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn pool_propagates_job_panics_after_the_batch() {
        let mut pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_scope(vec![
                Box::new(|_: &mut Scratch| {}),
                Box::new(|_: &mut Scratch| panic!("boom")),
            ]);
        }));
        assert!(r.is_err(), "job panic must reach the dispatching thread");
        // the pool survives a panicked job
        pool.run_scope(vec![Box::new(|_: &mut Scratch| {})]);
    }
}
