//! Model-level tests of the executor ([`crate::graph::exec`]) and the
//! batch engine ([`crate::graph::batch`]), kept in their own file so no
//! graph source file outgrows the ~400-line budget. Plan-vs-reference
//! golden parity lives in `tests/plan_parity.rs`.

use crate::graph::exec::*;
use crate::graph::{models, DnnConfig};
use crate::kernels::OpCounter;
use crate::quant::QTensor;
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

fn toy_data(
    rng: &mut Pcg32,
    n: usize,
    shape: &[usize],
    classes: usize,
) -> (Vec<TensorF32>, Vec<usize>) {
    // Two-class-separable synthetic data: class k biases channel mean.
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n {
        let y = i % classes;
        let mut x = TensorF32::zeros(shape);
        rng.fill_normal(x.data_mut(), 0.5);
        for v in x.data_mut().iter_mut() {
            *v += y as f32 * 0.8;
        }
        xs.push(x);
        ys.push(y);
    }
    (xs, ys)
}

fn deployed(cfg: DnnConfig, seed: u64) -> (NativeModel, Vec<TensorF32>, Vec<usize>) {
    let mut rng = Pcg32::seeded(seed);
    let def = models::mnist_cnn(&[1, 12, 12], 3);
    let fp = FloatParams::init(&def, &mut rng);
    let (xs, ys) = toy_data(&mut rng, 12, &[1, 12, 12], 3);
    let calib = calibrate(&def, &fp, &xs[..4]);
    (NativeModel::build(def, cfg, &fp, &calib), xs, ys)
}

#[test]
fn forward_shapes_all_configs() {
    for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
        let (m, xs, _) = deployed(cfg, 61);
        let mut ops = OpCounter::new();
        let t = m.forward(&xs[0], &mut ops);
        assert_eq!(t.logits.len(), 3, "{cfg:?}");
        assert_eq!(t.acts.len(), m.shared.def.layers.len());
        assert!(ops.total_macs() > 0);
    }
}

#[test]
fn quantized_forward_tracks_float_forward() {
    let (mq, xs, _) = deployed(DnnConfig::Uint8, 62);
    let (mf, _, _) = deployed(DnnConfig::Float32, 62);
    let mut ops = OpCounter::new();
    // identical float masters (same seed) -> logits should correlate
    let lq = mq.forward(&xs[0], &mut ops).logits;
    let lf = mf.forward(&xs[0], &mut ops).logits;
    // rank agreement on the toy problem is enough (quantization noise)
    let aq = crate::util::stats::argmax(&lq);
    let af = crate::util::stats::argmax(&lf);
    assert_eq!(aq, af, "lq={lq:?} lf={lf:?}");
}

#[test]
fn uint8_uses_integer_macs_float_uses_float_macs() {
    let (mq, xs, _) = deployed(DnnConfig::Uint8, 63);
    let mut ops = OpCounter::new();
    mq.forward(&xs[0], &mut ops);
    assert!(ops.int_macs > 0);
    assert_eq!(ops.float_macs, 0);

    let (mf, _, _) = deployed(DnnConfig::Float32, 63);
    let mut ops2 = OpCounter::new();
    mf.forward(&xs[0], &mut ops2);
    assert!(ops2.float_macs > 0);
    assert_eq!(ops2.int_macs, 0);
}

#[test]
fn mixed_config_crosses_boundary_once() {
    let (m, xs, _) = deployed(DnnConfig::Mixed, 64);
    let mut ops = OpCounter::new();
    let t = m.forward(&xs[0], &mut ops);
    // feature extractor quantized, head float
    assert!(matches!(t.acts[0], Act::Q(_)));
    assert!(matches!(t.acts.last().unwrap(), Act::F(_)));
    assert!(ops.int_macs > 0 && ops.float_macs > 0);
}

#[test]
fn backward_produces_grads_for_trainable_layers_only() {
    for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
        let (mut m, xs, ys) = deployed(cfg, 65);
        let mut ops = OpCounter::new();
        let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
        for (i, l) in m.shared.def.layers.iter().enumerate() {
            assert_eq!(bwd.grads[i].is_some(), l.trainable, "layer {i} {cfg:?}");
        }
    }
}

#[test]
fn grad_shapes_match_weights() {
    let (mut m, xs, ys) = deployed(DnnConfig::Uint8, 66);
    let mut ops = OpCounter::new();
    let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
    for (i, g) in bwd.grads.iter().enumerate() {
        if let Some(g) = g {
            match &m.state.params[i] {
                LayerParams::Q { w, bias } => {
                    assert_eq!(g.gw.shape(), w.shape());
                    assert_eq!(g.gb.len(), bias.len());
                }
                LayerParams::Qp { w, bias } => {
                    assert_eq!(g.gw.shape(), w.shape());
                    assert_eq!(g.gb.len(), bias.len());
                }
                LayerParams::F { w, bias } => {
                    assert_eq!(g.gw.shape(), w.shape());
                    assert_eq!(g.gb.len(), bias.len());
                }
                LayerParams::None => panic!("grads on weightless layer"),
            }
        }
    }
}

#[test]
fn transfer_mode_stops_backprop_early() {
    let mut rng = Pcg32::seeded(67);
    let mut def = models::mnist_cnn(&[1, 12, 12], 3);
    def.set_trainable_tail(2); // only the two linear layers
    let fp = FloatParams::init(&def, &mut rng);
    let (xs, ys) = toy_data(&mut rng, 6, &[1, 12, 12], 3);
    let calib = calibrate(&def, &fp, &xs[..2]);
    let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);

    let mut ops_full = OpCounter::new();
    let (_, _, bwd) = m.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops_full);
    assert!(bwd.grads[0].is_none());
    assert!(bwd.grads[4].is_some() && bwd.grads[5].is_some());

    // transfer-learning bwd must be cheaper than fwd (Fig. 4b property)
    let mut ops_fwd = OpCounter::new();
    m.forward(&xs[0], &mut ops_fwd);
    let bwd_macs = ops_full.total_macs().saturating_sub(ops_fwd.total_macs());
    assert!(bwd_macs < ops_fwd.total_macs(), "bwd={} fwd={}", bwd_macs, ops_fwd.total_macs());
}

#[test]
fn structure_norms_match_dequantized_l1() {
    let t = TensorF32::from_vec(&[2, 2], vec![1.0, -1.0, 0.5, 0.25]);
    let nf = structure_norms(&Act::F(t.clone()));
    assert!((nf[0] - 2.0).abs() < 1e-6);
    assert!((nf[1] - 0.75).abs() < 1e-6);
    let q = QTensor::quantize(&t);
    let nq = structure_norms(&Act::Q(q));
    assert!((nq[0] - 2.0).abs() < 0.1);
    assert!((nq[1] - 0.75).abs() < 0.1);
}

/// The batch engine must be worker-count invariant: identical losses,
/// predictions, gradients, op totals and post-batch model state
/// (adapted ranges, observers) for 1 and many workers.
#[test]
fn train_batch_is_worker_count_invariant() {
    let (mut m1, xs, ys) = deployed(DnnConfig::Uint8, 70);
    let (mut m2, _, _) = deployed(DnnConfig::Uint8, 70);
    let refs: Vec<&TensorF32> = xs.iter().collect();
    let r1 = m1.train_batch(&refs, &ys, 1);
    let r2 = m2.train_batch(&refs, &ys, 4);
    assert_eq!(r1.losses, r2.losses);
    assert_eq!(r1.preds, r2.preds);
    assert_eq!(r1.fwd_ops, r2.fwd_ops);
    assert_eq!(r1.bwd_ops, r2.bwd_ops);
    for (a, b) in r1.grads.iter().zip(r2.grads.iter()) {
        for (ga, gb) in a.grads.iter().zip(b.grads.iter()) {
            match (ga, gb) {
                (Some(ga), Some(gb)) => {
                    assert_eq!(ga.gw.data(), gb.gw.data());
                    assert_eq!(ga.gb.data(), gb.gb.data());
                    assert_eq!(ga.kept, gb.kept);
                }
                (None, None) => {}
                _ => panic!("gradient presence differs between worker counts"),
            }
        }
    }
    for (a, b) in m1.state.act_qp.iter().zip(m2.state.act_qp.iter()) {
        assert_eq!(a, b, "adapted activation ranges must match");
    }
    for (a, b) in m1.state.err_obs.iter().zip(m2.state.err_obs.iter()) {
        assert_eq!(a.range(), b.range(), "merged observer state must match");
    }
}

/// Batched gradients must match the per-sample path when the model
/// state is frozen (same snapshot semantics): sample 0 sees identical
/// conditions in both engines.
#[test]
fn train_batch_first_sample_matches_sequential() {
    let (mut mb, xs, ys) = deployed(DnnConfig::Uint8, 71);
    let (mut ms, _, _) = deployed(DnnConfig::Uint8, 71);
    let refs: Vec<&TensorF32> = xs.iter().take(1).collect();
    let rb = mb.train_batch(&refs, &ys[..1], 2);
    let mut ops = OpCounter::new();
    let (loss, pred, bwd) = ms.train_sample(&xs[0], ys[0], &mut DenseUpdates, &mut ops);
    assert_eq!(rb.losses[0], loss);
    assert_eq!(rb.preds[0], pred);
    for (a, b) in rb.grads[0].grads.iter().zip(bwd.grads.iter()) {
        if let (Some(a), Some(b)) = (a, b) {
            assert_eq!(a.gw.data(), b.gw.data());
        }
    }
}

/// A few FQT steps on the toy problem must reduce the loss — the
/// integration smoke test of the whole fwd/bwd stack (full training is
/// exercised by `train::` and the benches).
#[test]
fn quantized_training_reduces_loss_smoke() {
    use crate::train::Optimizer;
    let (mut m, xs, ys) = deployed(DnnConfig::Uint8, 68);
    let mut opt = crate::train::fqt::FqtSgd::new(&m, 0.01, 4);
    let mut first = 0.0;
    let mut last = 0.0;
    let mut ops = OpCounter::new();
    for epoch in 0..12 {
        let mut tot = 0.0;
        for (x, &y) in xs.iter().zip(ys.iter()) {
            let (loss, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
            opt.accumulate(&mut m, &bwd, &mut ops);
            tot += loss;
        }
        if epoch == 0 {
            first = tot;
        }
        last = tot;
    }
    assert!(last < first * 0.9, "loss did not drop: first={first} last={last}");
}

/// The flatten layer of the planned executor is a zero-copy view: its
/// trace activation aliases the pool output's buffer.
#[test]
fn flatten_activation_aliases_its_input() {
    let (m, xs, _) = deployed(DnnConfig::Uint8, 72);
    let mut ops = OpCounter::new();
    let t = m.forward(&xs[0], &mut ops);
    let i = m
        .shared
        .def
        .layers
        .iter()
        .position(|l| matches!(l.kind, crate::graph::LayerKind::Flatten))
        .expect("mnist_cnn has a flatten layer");
    match (&t.acts[i - 1], &t.acts[i]) {
        (Act::Q(a), Act::Q(b)) => {
            assert!(b.values.shares_data(&a.values), "flatten must alias its input buffer");
            assert_eq!(b.len(), a.len());
            assert_eq!(b.shape().len(), 1);
        }
        other => panic!("unexpected activation flavors around flatten: {other:?}"),
    }
}
