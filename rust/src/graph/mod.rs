//! Model graphs: layer definitions, shape / parameter / MAC inference, and
//! the builders for the three architectures of the evaluation:
//!
//!  * [`models::mnist_cnn`] — the full-on-device-training network of §IV-D
//!    (2 conv + maxpool + 2 linear, ReLU and folded BatchNorm throughout);
//!  * [`models::mbednet`] — the paper's MobileNetV3-derived *MbedNet*
//!    (§IV-A), a depthwise-separable stack scaled for MCU budgets, with
//!    compact final layers (the property Fig. 4b/9 hinges on);
//!  * [`models::mcunet5fps`] — an MCUNet-5FPS stand-in matched to the
//!    paper's reported ~23 M MACs / 0.48 M params with *large* final
//!    blocks (Tab. IV / Fig. 9 comparator).
//!
//! BatchNorm is folded into the preceding conv/linear at deployment (the
//! paper's monolithic QConv block, Fig. 2b), so it never appears as a graph
//! node.

pub mod act;
pub mod batch;
pub mod exec;
#[cfg(test)]
mod exec_tests;
pub mod models;
pub mod ops;
pub mod packs;
pub mod plan;
pub mod reference;
mod reference_bwd;

use crate::kernels::ConvGeom;

/// One layer of a sequential model.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Folded conv (+bias +optional ReLU). Quantized or float depending on
    /// the DNN configuration.
    Conv { geom: ConvGeom, relu: bool },
    /// Fully connected (+bias +optional ReLU).
    Linear { n_in: usize, n_out: usize, relu: bool },
    /// Square max pool, window == stride == `k`.
    MaxPool { k: usize },
    /// Global average pool `[C,H,W] -> [C]`.
    GlobalAvgPool,
    /// `[C,H,W] -> [C·H·W]`.
    Flatten,
}

/// A named layer plus its training attributes.
#[derive(Clone, Debug)]
pub struct LayerDef {
    pub name: String,
    pub kind: LayerKind,
    /// Whether this layer's weights are updated on-device. Non-trainable
    /// weights live in Flash; trainable ones in RAM (§IV-A).
    pub trainable: bool,
}

impl LayerDef {
    pub fn has_weights(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::Linear { .. })
    }
}

/// Per-layer precision under a DNN configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Uint8,
    Float32,
}

/// The three DNN configurations of the evaluation (§IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DnnConfig {
    /// Fully quantized (FQT).
    Uint8,
    /// Quantized feature extractor, float classification head.
    Mixed,
    /// Full float reference.
    Float32,
}

impl DnnConfig {
    pub fn parse(s: &str) -> Option<DnnConfig> {
        match s {
            "uint8" => Some(DnnConfig::Uint8),
            "mixed" => Some(DnnConfig::Mixed),
            "float32" | "float" => Some(DnnConfig::Float32),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DnnConfig::Uint8 => "uint8",
            DnnConfig::Mixed => "mixed",
            DnnConfig::Float32 => "float32",
        }
    }
}

/// A sequential model definition.
#[derive(Clone, Debug)]
pub struct ModelDef {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub layers: Vec<LayerDef>,
}

impl ModelDef {
    /// Output shape of every layer (index i = output of layer i).
    pub fn shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = Vec::with_capacity(self.layers.len());
        let mut cur = self.input_shape.clone();
        for l in &self.layers {
            cur = match &l.kind {
                LayerKind::Conv { geom, .. } => {
                    assert_eq!(cur.len(), 3, "conv input must be [C,H,W] ({})", l.name);
                    assert_eq!(cur[0], geom.cin, "channel mismatch at {}", l.name);
                    let (oh, ow) = geom.out_hw(cur[1], cur[2]);
                    vec![geom.cout, oh, ow]
                }
                LayerKind::Linear { n_in, n_out, .. } => {
                    let flat: usize = cur.iter().product();
                    assert_eq!(flat, *n_in, "linear input mismatch at {}", l.name);
                    vec![*n_out]
                }
                LayerKind::MaxPool { k } => {
                    let kh = (*k).min(cur[1]).max(1);
                    let kw = (*k).min(cur[2]).max(1);
                    vec![cur[0], cur[1] / kh, cur[2] / kw]
                }
                LayerKind::GlobalAvgPool => vec![cur[0]],
                LayerKind::Flatten => vec![cur.iter().product()],
            };
            shapes.push(cur.clone());
        }
        shapes
    }

    /// Input shape of layer `i`.
    pub fn in_shape(&self, i: usize) -> Vec<usize> {
        if i == 0 {
            self.input_shape.clone()
        } else {
            self.shapes()[i - 1].clone()
        }
    }

    /// Weight + bias parameter count per layer.
    pub fn params_per_layer(&self) -> Vec<usize> {
        self.layers
            .iter()
            .map(|l| match &l.kind {
                LayerKind::Conv { geom, .. } => geom.weights() + geom.cout,
                LayerKind::Linear { n_in, n_out, .. } => n_in * n_out + n_out,
                _ => 0,
            })
            .collect()
    }

    pub fn total_params(&self) -> usize {
        self.params_per_layer().iter().sum()
    }

    /// Forward MACs per layer for one sample.
    pub fn fwd_macs_per_layer(&self) -> Vec<u64> {
        let mut macs = Vec::with_capacity(self.layers.len());
        let mut cur = self.input_shape.clone();
        for l in &self.layers {
            let m = match &l.kind {
                LayerKind::Conv { geom, .. } => geom.fwd_macs(cur[1], cur[2]),
                LayerKind::Linear { n_in, n_out, .. } => (*n_in * *n_out) as u64,
                _ => 0,
            };
            macs.push(m);
            cur = match &l.kind {
                LayerKind::Conv { geom, .. } => {
                    let (oh, ow) = geom.out_hw(cur[1], cur[2]);
                    vec![geom.cout, oh, ow]
                }
                LayerKind::Linear { n_out, .. } => vec![*n_out],
                LayerKind::MaxPool { k } => {
                    let kh = (*k).min(cur[1]).max(1);
                    let kw = (*k).min(cur[2]).max(1);
                    vec![cur[0], cur[1] / kh, cur[2] / kw]
                }
                LayerKind::GlobalAvgPool => vec![cur[0]],
                LayerKind::Flatten => vec![cur.iter().product()],
            };
        }
        macs
    }

    pub fn total_fwd_macs(&self) -> u64 {
        self.fwd_macs_per_layer().iter().sum()
    }

    /// Index of the earliest trainable layer (BP stops there).
    pub fn first_trainable(&self) -> Option<usize> {
        self.layers.iter().position(|l| l.trainable)
    }

    /// Mark only the last `n` weighted layers trainable (transfer learning,
    /// §IV-A "we set the last five layers to random values").
    pub fn set_trainable_tail(&mut self, n: usize) {
        let mut remaining = n;
        for l in self.layers.iter_mut().rev() {
            if l.has_weights() {
                l.trainable = remaining > 0;
                if remaining > 0 {
                    remaining -= 1;
                }
            } else {
                l.trainable = false;
            }
        }
    }

    /// Mark every weighted layer trainable (full on-device training, §IV-D).
    pub fn set_all_trainable(&mut self) {
        for l in self.layers.iter_mut() {
            l.trainable = l.has_weights();
        }
    }

    /// Per-layer precision under a configuration: `Mixed` keeps the
    /// classification head (the trailing Linear layers) in float.
    pub fn precisions(&self, cfg: DnnConfig) -> Vec<Precision> {
        match cfg {
            DnnConfig::Uint8 => vec![Precision::Uint8; self.layers.len()],
            DnnConfig::Float32 => vec![Precision::Float32; self.layers.len()],
            DnnConfig::Mixed => {
                // Head = the contiguous trailing run of Linear/Flatten/GAP
                // layers; the feature extractor (everything through the last
                // conv/pool over spatial maps) stays quantized.
                let mut prec = vec![Precision::Uint8; self.layers.len()];
                let last_conv = self
                    .layers
                    .iter()
                    .rposition(|l| matches!(l.kind, LayerKind::Conv { .. }))
                    .map(|i| i as isize)
                    .unwrap_or(-1);
                for (i, p) in prec.iter_mut().enumerate() {
                    if (i as isize) > last_conv {
                        *p = Precision::Float32;
                    }
                }
                prec
            }
        }
    }

    /// Count of weighted layers.
    pub fn weighted_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.has_weights()).count()
    }
}

/// Helper for building sequential models.
pub struct ModelBuilder {
    def: ModelDef,
    cur: Vec<usize>,
    n: usize,
}

impl ModelBuilder {
    pub fn new(name: &str, input_shape: &[usize], num_classes: usize) -> Self {
        ModelBuilder {
            def: ModelDef {
                name: name.to_string(),
                input_shape: input_shape.to_vec(),
                num_classes,
                layers: Vec::new(),
            },
            cur: input_shape.to_vec(),
            n: 0,
        }
    }

    fn push(&mut self, kind: LayerKind, tag: &str) -> &mut Self {
        let name = format!("{}{}_{}", tag, self.n, self.def.name);
        self.n += 1;
        self.cur = match &kind {
            LayerKind::Conv { geom, .. } => {
                let (oh, ow) = geom.out_hw(self.cur[1], self.cur[2]);
                vec![geom.cout, oh, ow]
            }
            LayerKind::Linear { n_out, .. } => vec![*n_out],
            LayerKind::MaxPool { k } => {
                let kh = (*k).min(self.cur[1]).max(1);
                let kw = (*k).min(self.cur[2]).max(1);
                vec![self.cur[0], self.cur[1] / kh, self.cur[2] / kw]
            }
            LayerKind::GlobalAvgPool => vec![self.cur[0]],
            LayerKind::Flatten => vec![self.cur.iter().product()],
        };
        self.def.layers.push(LayerDef { name, kind, trainable: false });
        self
    }

    pub fn conv(&mut self, cout: usize, k: usize, stride: usize, relu: bool) -> &mut Self {
        let geom = ConvGeom {
            cin: self.cur[0],
            cout,
            kh: if self.cur[1] == 1 { 1 } else { k },
            kw: k,
            stride,
            pad_h: if self.cur[1] == 1 { 0 } else { k / 2 },
            pad_w: k / 2,
            depthwise: false,
        };
        self.push(LayerKind::Conv { geom, relu }, "conv")
    }

    pub fn dwconv(&mut self, k: usize, stride: usize, relu: bool) -> &mut Self {
        let c = self.cur[0];
        let geom = ConvGeom {
            cin: c,
            cout: c,
            kh: if self.cur[1] == 1 { 1 } else { k },
            kw: k,
            stride,
            pad_h: if self.cur[1] == 1 { 0 } else { k / 2 },
            pad_w: k / 2,
            depthwise: true,
        };
        self.push(LayerKind::Conv { geom, relu }, "dwconv")
    }

    pub fn pwconv(&mut self, cout: usize, relu: bool) -> &mut Self {
        let geom = ConvGeom {
            cin: self.cur[0],
            cout,
            kh: 1,
            kw: 1,
            stride: 1,
            pad_h: 0, pad_w: 0,
            depthwise: false,
        };
        self.push(LayerKind::Conv { geom, relu }, "pwconv")
    }

    pub fn maxpool(&mut self, k: usize) -> &mut Self {
        self.push(LayerKind::MaxPool { k }, "pool")
    }

    pub fn gap(&mut self) -> &mut Self {
        self.push(LayerKind::GlobalAvgPool, "gap")
    }

    pub fn flatten(&mut self) -> &mut Self {
        self.push(LayerKind::Flatten, "flat")
    }

    pub fn linear(&mut self, n_out: usize, relu: bool) -> &mut Self {
        let n_in: usize = self.cur.iter().product();
        assert_eq!(self.cur.len(), 1, "call flatten()/gap() before linear()");
        self.push(LayerKind::Linear { n_in, n_out, relu }, "fc")
    }

    pub fn build(&self) -> ModelDef {
        self.def.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelDef {
        let mut b = ModelBuilder::new("tiny", &[1, 8, 8], 4);
        b.conv(4, 3, 2, true).maxpool(2).flatten().linear(4, false);
        b.build()
    }

    #[test]
    fn shape_inference_chain() {
        let m = tiny();
        let shapes = m.shapes();
        assert_eq!(shapes[0], vec![4, 4, 4]);
        assert_eq!(shapes[1], vec![4, 2, 2]);
        assert_eq!(shapes[2], vec![16]);
        assert_eq!(shapes[3], vec![4]);
    }

    #[test]
    fn params_and_macs() {
        let m = tiny();
        let p = m.params_per_layer();
        assert_eq!(p[0], 4 * 1 * 9 + 4);
        assert_eq!(p[3], 16 * 4 + 4);
        let macs = m.fwd_macs_per_layer();
        assert_eq!(macs[0], (4 * 4 * 4 * 9) as u64);
        assert_eq!(macs[3], 64);
    }

    #[test]
    fn trainable_tail_marks_weighted_layers_only() {
        let mut m = tiny();
        m.set_trainable_tail(1);
        assert!(!m.layers[0].trainable);
        assert!(m.layers[3].trainable);
        assert_eq!(m.first_trainable(), Some(3));
        m.set_all_trainable();
        assert!(m.layers[0].trainable);
        assert!(!m.layers[1].trainable); // pool has no weights
    }

    #[test]
    fn mixed_precision_splits_at_last_conv() {
        let m = tiny();
        let prec = m.precisions(DnnConfig::Mixed);
        assert_eq!(prec[0], Precision::Uint8);
        assert_eq!(prec[1], Precision::Float32); // pool after last conv
        assert_eq!(prec[3], Precision::Float32);
        assert!(m.precisions(DnnConfig::Uint8).iter().all(|&p| p == Precision::Uint8));
        assert!(m.precisions(DnnConfig::Float32).iter().all(|&p| p == Precision::Float32));
    }

    #[test]
    fn time_series_input_uses_1d_kernels() {
        let mut b = ModelBuilder::new("ts", &[1, 1, 64], 3);
        b.conv(8, 3, 2, true);
        let m = b.build();
        match &m.layers[0].kind {
            LayerKind::Conv { geom, .. } => {
                assert_eq!(geom.kh, 1);
                assert_eq!(geom.kw, 3);
            }
            other => panic!("layer 0 of the time-series model must be a conv, found {other:?}"),
        }
        assert_eq!(m.shapes()[0], vec![8, 1, 32]);
    }
}
