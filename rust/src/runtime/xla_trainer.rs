//! FQT training driven through the AOT HLO artifact — the XLA backend.
//!
//! [`XlaFqtTrainer`] owns the same on-device state as the native backend
//! (quantized uint8 weights, float biases, activation/error quantization
//! parameters) but executes the fused forward+backward train-step graph
//! via PJRT instead of the native kernels. The optimizer (Eqs. 5–8), the
//! activation-range adaptation and the error observers all run in Rust —
//! the artifact is pure compute, everything stateful stays on this side.
//!
//! The input/output tuple layout matches `python/compile/model.py`
//! (`fqt_train_step` / `QP_LEN`); the manifest validates it at load time.

use crate::util::error::{Context, Result};

use crate::quant::observer::MinMaxObserver;
use crate::quant::QParams;
use crate::runtime::{lit_f32, lit_u8, Artifact};
use crate::tensor::TensorF32;
use crate::util::prng::Pcg32;

/// Architecture constants (must match `python/compile/model.py`).
pub const IN_SHAPE: [usize; 3] = [1, 28, 28];
pub const N_CLASSES: usize = 10;
const LAYER_SHAPES: [(usize, usize); 4] = [(16, 9), (32, 144), (64, 288), (10, 64)];
const QP_LEN: usize = 26;

struct QLayer {
    w: Vec<u8>,
    qp: QParams,
    bias: Vec<f32>,
    rows: usize,
    cols: usize,
    // gradient accumulation + per-row running stats (Eq. 8)
    gw: Vec<f32>,
    gb: Vec<f32>,
    n: Vec<u64>,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl QLayer {
    fn init(rows: usize, cols: usize, rng: &mut Pcg32) -> QLayer {
        let std = (2.0 / cols as f32).sqrt();
        let mut wf = vec![0f32; rows * cols];
        rng.fill_normal(&mut wf, std);
        let qp = QParams::observe(&wf);
        let w = wf.iter().map(|&f| qp.quantize(f)).collect();
        QLayer {
            w,
            qp,
            bias: vec![0.0; rows],
            rows,
            cols,
            gw: vec![0.0; rows * cols],
            gb: vec![0.0; rows],
            n: vec![0; rows],
            mean: vec![0.0; rows],
            m2: vec![0.0; rows],
        }
    }

    fn accumulate(&mut self, gw: &[f32], gb: &[f32]) {
        for r in 0..self.rows {
            for c in 0..self.cols {
                let g = gw[r * self.cols + c];
                self.gw[r * self.cols + c] += g;
                self.n[r] += 1;
                let d = g as f64 - self.mean[r];
                self.mean[r] += d / self.n[r] as f64;
                self.m2[r] += d * (g as f64 - self.mean[r]);
            }
            self.gb[r] += gb[r];
        }
    }

    /// Eqs. 5–8: standardized float-space descent + requantization at
    /// freshly derived parameters.
    fn step(&mut self, lr: f32, inv_b: f32) {
        let mut wf: Vec<f32> = self.w.iter().map(|&q| self.qp.dequantize(q)).collect();
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for r in 0..self.rows {
            let rms = if self.n[r] < 2 {
                1.0
            } else {
                let var = self.m2[r] / self.n[r] as f64;
                let rms = (var + self.mean[r] * self.mean[r]).sqrt() as f32;
                if rms > 1e-8 {
                    rms
                } else {
                    1.0
                }
            };
            let mu = self.mean[r] as f32;
            for c in 0..self.cols {
                let i = r * self.cols + c;
                let ghat = ((self.gw[i] * inv_b - mu) / rms).clamp(-10.0, 10.0);
                wf[i] -= lr * ghat;
                lo = lo.min(wf[i]);
                hi = hi.max(wf[i]);
            }
            self.bias[r] -= lr * self.gb[r] * inv_b;
        }
        self.qp = QParams::from_min_max(lo, hi);
        for (q, &f) in self.w.iter_mut().zip(wf.iter()) {
            *q = self.qp.quantize(f);
        }
        self.gw.fill(0.0);
        self.gb.fill(0.0);
    }
}

/// The XLA-backed FQT trainer for the §IV-D network.
pub struct XlaFqtTrainer {
    art: Artifact,
    layers: Vec<QLayer>,
    pub input_qp: QParams,
    act_qp: [QParams; 4],
    err_obs: [MinMaxObserver; 4],
    pub lr: f32,
    pub batch: usize,
    count: usize,
    pub steps: u64,
}

impl XlaFqtTrainer {
    /// Fresh random model. `input_range` is the (min, max) of the input
    /// data distribution (replaces PTQ calibration for the input tensor;
    /// activation ranges start wide and adapt online from the saturation
    /// telemetry the artifact returns).
    pub fn new(
        art: Artifact,
        input_range: (f32, f32),
        lr: f32,
        batch: usize,
        seed: u64,
    ) -> Result<Self> {
        crate::ensure!(
            art.manifest.inputs.len() == 11 && art.manifest.outputs.len() == 12,
            "unexpected artifact interface for {}",
            art.manifest.name
        );
        let mut rng = Pcg32::new(seed, 0xA0);
        let layers = LAYER_SHAPES.iter().map(|&(r, c)| QLayer::init(r, c, &mut rng)).collect();
        Ok(XlaFqtTrainer {
            art,
            layers,
            input_qp: QParams::from_min_max(input_range.0, input_range.1),
            act_qp: [
                QParams::from_min_max(0.0, 4.0),
                QParams::from_min_max(0.0, 6.0),
                QParams::from_min_max(0.0, 6.0),
                QParams::from_min_max(-6.0, 6.0),
            ],
            err_obs: core::array::from_fn(|_| MinMaxObserver::online()),
            lr,
            batch: batch.max(1),
            count: 0,
            steps: 0,
        })
    }

    fn qp_vec(&self) -> Vec<f32> {
        let mut qp = vec![0f32; QP_LEN];
        qp[0] = self.input_qp.scale;
        qp[1] = self.input_qp.zero_point as f32;
        for (i, l) in self.layers.iter().enumerate() {
            qp[2 + 4 * i] = l.qp.scale;
            qp[3 + 4 * i] = l.qp.zero_point as f32;
            qp[4 + 4 * i] = self.act_qp[i].scale;
            qp[5 + 4 * i] = self.act_qp[i].zero_point as f32;
        }
        for (i, obs) in self.err_obs.iter().enumerate() {
            let e = obs.qparams();
            qp[18 + 2 * i] = e.scale;
            qp[19 + 2 * i] = e.zero_point as f32;
        }
        qp
    }

    fn run(&self, x: &TensorF32, label: usize) -> Result<Vec<xla::Literal>> {
        let xq: Vec<u8> = x.data().iter().map(|&f| self.input_qp.quantize(f)).collect();
        let mut onehot = vec![0f32; N_CLASSES];
        onehot[label.min(N_CLASSES - 1)] = 1.0;
        let l = &self.layers;
        let inputs = vec![
            lit_u8(&IN_SHAPE, &xq)?,
            lit_f32(&[N_CLASSES], &onehot)?,
            lit_u8(&[l[0].rows, l[0].cols], &l[0].w)?,
            lit_f32(&[l[0].rows], &l[0].bias)?,
            lit_u8(&[l[1].rows, l[1].cols], &l[1].w)?,
            lit_f32(&[l[1].rows], &l[1].bias)?,
            lit_u8(&[l[2].rows, l[2].cols], &l[2].w)?,
            lit_f32(&[l[2].rows], &l[2].bias)?,
            lit_u8(&[l[3].rows, l[3].cols], &l[3].w)?,
            lit_f32(&[l[3].rows], &l[3].bias)?,
            lit_f32(&[QP_LEN], &self.qp_vec())?,
        ];
        self.art.execute(&inputs)
    }

    /// Inference through the artifact (same graph; gradients discarded —
    /// the in-place property means there is no separate inference model).
    pub fn predict(&self, x: &TensorF32) -> Result<usize> {
        let outs = self.run(x, 0)?;
        let logits = outs[1].to_vec::<f32>()?;
        Ok(crate::util::stats::argmax(&logits))
    }

    pub fn evaluate(&self, xs: &[TensorF32], ys: &[usize]) -> Result<f32> {
        let mut correct = 0;
        for (x, &y) in xs.iter().zip(ys) {
            if self.predict(x)? == y {
                correct += 1;
            }
        }
        Ok(correct as f32 / xs.len().max(1) as f32)
    }

    /// One training-sample pass: execute the fused fwd+bwd artifact,
    /// accumulate gradients, update observers and activation ranges from
    /// the telemetry outputs, and apply the FQT step at batch boundaries.
    pub fn train_step(&mut self, x: &TensorF32, label: usize) -> Result<(f32, usize)> {
        let outs = self.run(x, label)?;
        let loss = outs[0].to_vec::<f32>()?[0];
        let logits = outs[1].to_vec::<f32>()?;
        let pred = crate::util::stats::argmax(&logits);

        // gradients: outputs 2..10 = gw1, gb1, gw2, gb2, gw4, gb4, gw5, gb5
        for i in 0..4 {
            let gw = outs[2 + 2 * i].to_vec::<f32>()?;
            let gb = outs[3 + 2 * i].to_vec::<f32>()?;
            self.layers[i].accumulate(&gw, &gb);
        }

        // error observers from float-space min/max (Eqs. 6–7 analogue)
        let mm = outs[10].to_vec::<f32>()?;
        for (i, obs) in self.err_obs.iter_mut().enumerate() {
            obs.observe_range(mm[2 * i], mm[2 * i + 1]);
        }
        // activation-range adaptation from saturation telemetry
        let sat = outs[11].to_vec::<f32>()?;
        for (i, &s) in sat.iter().enumerate() {
            if s > 0.01 {
                let qp = self.act_qp[i];
                let lo = (0 - qp.zero_point) as f32 * qp.scale;
                let hi = (255 - qp.zero_point) as f32 * qp.scale;
                self.act_qp[i] = if i < 3 {
                    QParams::from_min_max(lo, hi * 1.25) // folded ReLU: upper only
                } else {
                    QParams::from_min_max(lo * 1.25, hi * 1.25)
                };
            }
        }

        self.count += 1;
        self.steps += 1;
        if self.count >= self.batch {
            let inv_b = 1.0 / self.count as f32;
            for l in self.layers.iter_mut() {
                l.step(self.lr, inv_b);
            }
            self.count = 0;
        }
        Ok((loss, pred))
    }

    /// Flush a partial minibatch.
    pub fn finish(&mut self) {
        if self.count > 0 {
            let inv_b = 1.0 / self.count as f32;
            for l in self.layers.iter_mut() {
                l.step(self.lr, inv_b);
            }
            self.count = 0;
        }
    }

    /// Weight quantization parameters of layer `i` (diagnostics).
    pub fn layer_qp(&self, i: usize) -> QParams {
        self.layers[i].qp
    }
}

/// Convenience: load the uint8 train artifact and build a trainer.
pub fn load_fqt_trainer(
    dir: &std::path::Path,
    input_range: (f32, f32),
    lr: f32,
    batch: usize,
    seed: u64,
) -> Result<XlaFqtTrainer> {
    let rt = crate::runtime::Runtime::cpu()?;
    let art = rt.load_artifact(dir, "mnist_cnn_uint8_train").context("loading FQT artifact")?;
    XlaFqtTrainer::new(art, input_range, lr, batch, seed)
}
