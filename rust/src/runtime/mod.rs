//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from Rust.
//!
//! Python runs exactly once (`make artifacts`); afterwards the binary is
//! self-contained: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → compile → execute. Each artifact ships a JSON manifest describing its
//! input/output tuple (names/dtypes/shapes) which [`Artifact`] validates
//! against at load time, so a drifted artifact fails loudly instead of
//! feeding garbage.
//!
//! # The `pjrt` feature
//!
//! Everything that touches the `xla` crate is compiled only under the
//! off-by-default `pjrt` cargo feature, so the default build needs neither
//! network access nor the PJRT plugin. The manifest parsing and the
//! artifact-directory plumbing stay available unconditionally (they are
//! plain std + `util::json`). To build the PJRT path, uncomment the `xla`
//! dependency in `Cargo.toml` and pass `--features pjrt`.

#[cfg(feature = "pjrt")]
pub mod xla_trainer;

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Tensor spec from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest of one artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest json")?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)
                .as_arr()
                .context("manifest missing array")?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        dtype: t.get("dtype").as_str().context("dtype")?.to_string(),
                        shape: t
                            .get("shape")
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                    })
                })
                .collect()
        };
        Ok(Manifest {
            name: v.get("name").as_str().unwrap_or("?").to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Default artifact directory (next to the workspace root).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("TT_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{lit_f32, lit_u8, Artifact, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::{Path, PathBuf};

    use super::Manifest;
    use crate::bail;
    use crate::util::error::{Context, Error, Result};

    impl From<xla::Error> for Error {
        fn from(e: xla::Error) -> Error {
            Error::msg(e)
        }
    }

    /// Lazily constructed PJRT CPU client (compilation is cached per
    /// artifact).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// A loaded, compiled artifact.
    pub struct Artifact {
        pub manifest: Manifest,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { client: xla::PjRtClient::cpu()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.json` and compile.
        pub fn load_artifact(&self, dir: &Path, name: &str) -> Result<Artifact> {
            let hlo: PathBuf = dir.join(format!("{name}.hlo.txt"));
            let man: PathBuf = dir.join(format!("{name}.json"));
            if !hlo.exists() {
                bail!("artifact {} not found — run `make artifacts` first", hlo.display());
            }
            let manifest = Manifest::load(&man)?;
            let proto = xla::HloModuleProto::from_text_file(
                hlo.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Artifact { manifest, exe })
        }
    }

    impl Artifact {
        /// Execute with positional inputs; returns the decomposed output
        /// tuple. Input count and element counts are validated against the
        /// manifest.
        pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            if inputs.len() != self.manifest.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.manifest.name,
                    self.manifest.inputs.len(),
                    inputs.len()
                );
            }
            for (i, (lit, spec)) in inputs.iter().zip(&self.manifest.inputs).enumerate() {
                if lit.element_count() != spec.elements() {
                    bail!(
                        "{}: input {i} has {} elements, manifest says {:?}",
                        self.manifest.name,
                        lit.element_count(),
                        spec.shape
                    );
                }
            }
            let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            let outs = result.to_tuple()?;
            if outs.len() != self.manifest.outputs.len() {
                bail!(
                    "{}: got {} outputs, manifest says {}",
                    self.manifest.name,
                    outs.len(),
                    self.manifest.outputs.len()
                );
            }
            Ok(outs)
        }
    }

    /// Build a u8 literal with the given logical shape. (`u8` has no
    /// `NativeType` impl in the xla crate, so the untyped-bytes path is
    /// used.)
    pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<xla::Literal> {
        Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, shape, data)?)
    }

    /// Build an f32 literal with the given logical shape.
    pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_spec_shapes() {
        let dir = std::env::temp_dir().join("tt_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.json");
        std::fs::write(
            &p,
            r#"{"name":"m","inputs":[{"dtype":"uint8","shape":[2,3]}],"outputs":[{"dtype":"float32","shape":[4]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.inputs[0].shape, vec![2, 3]);
        assert_eq!(m.inputs[0].elements(), 6);
        assert_eq!(m.outputs[0].dtype, "float32");
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent/m.json")).is_err());
    }
}
