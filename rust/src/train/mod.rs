//! On-device training: the FQT optimizer (§III-A), the baseline optimizers
//! used in the Tab. IV comparison, the dynamic sparse gradient update
//! controller (§III-B), and the training loop driver.

pub mod fqt;
pub mod loop_;
pub mod optim;
pub mod sparse;

use crate::graph::exec::{BwdResult, NativeModel};
use crate::kernels::OpCounter;

/// Common optimizer interface: feed one sample's backward result; the
/// optimizer accumulates gradients (memory-efficient minibatching, §III-A
/// option (b)) and applies a weight update every `batch` samples.
pub trait Optimizer {
    /// Accumulate one sample's gradients; applies the update internally
    /// when a full minibatch has been gathered.
    fn accumulate(&mut self, model: &mut NativeModel, bwd: &BwdResult, ops: &mut OpCounter);

    /// Flush a partial minibatch (end of epoch).
    fn finish(&mut self, model: &mut NativeModel, ops: &mut OpCounter);

    /// Bytes of optimizer state (gradient buffers + running statistics) —
    /// feeds the RAM accounting of Fig. 4c.
    fn state_bytes(&self) -> usize;
}
