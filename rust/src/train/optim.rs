//! Baseline optimizers for the Tab. IV comparison:
//!
//!  * [`SgdM`] — float SGD with momentum 0.9 (the fp32 reference row; also
//!    the float-head optimizer when combined with quantized features).
//!  * [`NaiveQSgdM`] — momentum SGD applied to dequantized weights and
//!    requantized at the **original, frozen** quantization parameters, no
//!    gradient conditioning. This is the "int8 SGD-M" row that degrades
//!    badly (64.9 % avg in the paper) because small updates vanish under
//!    the fixed scale and large ones clip.
//!  * [`QasSgdM`] — SGD+M+QAS (Lin et al., NeurIPS'22): like the naive
//!    optimizer but with quantization-aware scaling, multiplying each
//!    layer's weight gradient by `s_w²` to undo the scale distortion that
//!    quantization imposes on gradient magnitudes (their Eq.: ∇q ≈ ∇w / s²,
//!    so scaling by s² recovers the float-gradient magnitude), which
//!    restores fp32-level accuracy without per-element statistics.
//!
//! All three share the gradient-accumulation minibatching of the FQT
//! optimizer so the comparison isolates the *update rule*.

use crate::graph::exec::{BwdResult, LayerParams, NativeModel};
use crate::kernels::OpCounter;
use crate::quant::subbyte::PackedQTensor;
use crate::quant::QTensor;
use crate::tensor::TensorF32;
use crate::train::Optimizer;

/// Which update rule a [`QOptimizer`] applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Plain momentum SGD (float layers; dequant->requant at frozen params
    /// for quantized layers).
    SgdM,
    /// Momentum SGD with quantization-aware scaling (s_w² gradient scaling)
    /// on quantized layers.
    QasSgdM,
}

/// Shared implementation for the baseline optimizers.
pub struct QOptimizer {
    pub lr: f32,
    pub momentum: f32,
    pub batch: usize,
    rule: Rule,
    count: usize,
    /// Per-layer gradient accumulators and momentum (velocity) buffers.
    acc: Vec<Option<(TensorF32, TensorF32)>>,
    vel: Vec<Option<(TensorF32, TensorF32)>>,
}

/// Float SGD-M (fp32 row of Tab. IV).
pub struct SgdM(pub QOptimizer);
/// Naive quantized SGD-M (int8 SGD-M row of Tab. IV).
pub struct NaiveQSgdM(pub QOptimizer);
/// SGD+M+QAS (Lin et al. row of Tab. IV).
pub struct QasSgdM(pub QOptimizer);

impl QOptimizer {
    pub fn new(model: &NativeModel, lr: f32, batch: usize, rule: Rule) -> QOptimizer {
        let mk = |p: &LayerParams, trainable: bool| -> Option<(TensorF32, TensorF32)> {
            if !trainable {
                return None;
            }
            match p {
                LayerParams::Q { w, bias } => {
                    Some((TensorF32::zeros(w.shape()), TensorF32::zeros(&[bias.len()])))
                }
                LayerParams::Qp { w, bias } => {
                    Some((TensorF32::zeros(w.shape()), TensorF32::zeros(&[bias.len()])))
                }
                LayerParams::F { w, bias } => {
                    Some((TensorF32::zeros(w.shape()), TensorF32::zeros(&[bias.len()])))
                }
                LayerParams::None => None,
            }
        };
        let acc: Vec<_> = model
            .state
            .params
            .iter()
            .zip(&model.shared.def.layers)
            .map(|(p, l)| mk(p, l.trainable))
            .collect();
        let vel = acc.clone();
        QOptimizer { lr, momentum: 0.9, batch: batch.max(1), rule, count: 0, acc, vel }
    }

    fn step(&mut self, model: &mut NativeModel, ops: &mut OpCounter) {
        if self.count == 0 {
            return;
        }
        let inv_b = 1.0 / self.count as f32;
        for i in 0..self.acc.len() {
            let Some((ga, gba)) = self.acc[i].as_mut() else { continue };
            let (gv, gbv) = self.vel[i].as_mut().unwrap();
            match &mut model.state.params[i] {
                LayerParams::Q { w, bias } => {
                    // dequantize, momentum step (optionally QAS-scaled),
                    // requantize at the ORIGINAL frozen parameters.
                    let qp = w.qp;
                    let gscale = match self.rule {
                        Rule::QasSgdM => qp.scale * qp.scale,
                        Rule::SgdM => 1.0,
                    };
                    let mut wf = w.dequantize();
                    for j in 0..wf.len() {
                        let g = ga.data()[j] * inv_b * gscale;
                        gv.data_mut()[j] = self.momentum * gv.data()[j] + g;
                        wf.data_mut()[j] -= self.lr * gv.data()[j];
                    }
                    for c in 0..bias.len() {
                        let g = gba.data()[c] * inv_b;
                        gbv.data_mut()[c] = self.momentum * gbv.data()[c] + g;
                        bias[c] -= self.lr * gbv.data_mut()[c];
                    }
                    *w = QTensor::quantize_with(&wf, qp);
                    ops.float_ops += (wf.len() * 4) as u64;
                    ops.int_ops += wf.len() as u64;
                }
                LayerParams::Qp { w, bias } => {
                    // Same frozen-parameter rule, quantize-on-write back
                    // into the packed representation at the layer's width
                    // (bit-identical to the Q arm at 8-bit lanes).
                    let qp = w.qp;
                    let bits = w.bits;
                    let gscale = match self.rule {
                        Rule::QasSgdM => qp.scale * qp.scale,
                        Rule::SgdM => 1.0,
                    };
                    let mut wf = w.dequantize();
                    for j in 0..wf.len() {
                        let g = ga.data()[j] * inv_b * gscale;
                        gv.data_mut()[j] = self.momentum * gv.data()[j] + g;
                        wf.data_mut()[j] -= self.lr * gv.data()[j];
                    }
                    for c in 0..bias.len() {
                        let g = gba.data()[c] * inv_b;
                        gbv.data_mut()[c] = self.momentum * gbv.data()[c] + g;
                        bias[c] -= self.lr * gbv.data_mut()[c];
                    }
                    *w = PackedQTensor::quantize_with_bits(&wf, qp, bits);
                    ops.float_ops += (wf.len() * 4) as u64;
                    ops.int_ops += wf.len() as u64;
                }
                LayerParams::F { w, bias } => {
                    for j in 0..w.len() {
                        let g = ga.data()[j] * inv_b;
                        gv.data_mut()[j] = self.momentum * gv.data()[j] + g;
                        w.data_mut()[j] -= self.lr * gv.data()[j];
                    }
                    for c in 0..bias.len() {
                        let g = gba.data()[c] * inv_b;
                        gbv.data_mut()[c] = self.momentum * gbv.data()[c] + g;
                        bias[c] -= self.lr * gbv.data_mut()[c];
                    }
                    ops.float_ops += (w.len() * 4) as u64;
                }
                LayerParams::None => {}
            }
            // Dirty bit: the write above invalidates this layer's cached
            // backward weight pack (see `graph::packs`).
            model.touch_layer(i);
            ga.data_mut().fill(0.0);
            gba.data_mut().fill(0.0);
        }
        self.count = 0;
    }

    fn accumulate_impl(&mut self, model: &mut NativeModel, bwd: &BwdResult, ops: &mut OpCounter) {
        for (i, g) in bwd.grads.iter().enumerate() {
            if let (Some(g), Some((ga, gba))) = (g, self.acc[i].as_mut()) {
                for (a, &v) in ga.data_mut().iter_mut().zip(g.gw.data()) {
                    *a += v;
                }
                for (a, &v) in gba.data_mut().iter_mut().zip(g.gb.data()) {
                    *a += v;
                }
                ops.float_ops += g.gw.len() as u64;
            }
        }
        self.count += 1;
        if self.count >= self.batch {
            self.step(model, ops);
        }
    }

    fn bytes(&self) -> usize {
        self.acc
            .iter()
            .flatten()
            .chain(self.vel.iter().flatten())
            .map(|(a, b)| (a.len() + b.len()) * 4)
            .sum()
    }
}

macro_rules! forward_optimizer {
    ($t:ty) => {
        impl Optimizer for $t {
            fn accumulate(
                &mut self,
                model: &mut NativeModel,
                bwd: &BwdResult,
                ops: &mut OpCounter,
            ) {
                self.0.accumulate_impl(model, bwd, ops)
            }
            fn finish(&mut self, model: &mut NativeModel, ops: &mut OpCounter) {
                self.0.step(model, ops)
            }
            fn state_bytes(&self) -> usize {
                self.0.bytes()
            }
        }
    };
}

impl SgdM {
    pub fn new(model: &NativeModel, lr: f32, batch: usize) -> SgdM {
        SgdM(QOptimizer::new(model, lr, batch, Rule::SgdM))
    }
}

impl NaiveQSgdM {
    pub fn new(model: &NativeModel, lr: f32, batch: usize) -> NaiveQSgdM {
        NaiveQSgdM(QOptimizer::new(model, lr, batch, Rule::SgdM))
    }
}

impl QasSgdM {
    pub fn new(model: &NativeModel, lr: f32, batch: usize) -> QasSgdM {
        QasSgdM(QOptimizer::new(model, lr, batch, Rule::QasSgdM))
    }
}

forward_optimizer!(SgdM);
forward_optimizer!(NaiveQSgdM);
forward_optimizer!(QasSgdM);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::exec::{calibrate, DenseUpdates, FloatParams};
    use crate::graph::{models, DnnConfig};
    use crate::util::prng::Pcg32;

    fn setup(cfg: DnnConfig, seed: u64) -> (NativeModel, Vec<TensorF32>, Vec<usize>) {
        let mut rng = Pcg32::seeded(seed);
        let def = models::mnist_cnn(&[1, 12, 12], 2);
        let fp = FloatParams::init(&def, &mut rng);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..16 {
            let y = i % 2;
            let mut x = TensorF32::zeros(&[1, 12, 12]);
            rng.fill_normal(x.data_mut(), 0.4);
            for v in x.data_mut().iter_mut() {
                *v += y as f32;
            }
            xs.push(x);
            ys.push(y);
        }
        let calib = calibrate(&def, &fp, &xs[..4]);
        (NativeModel::build(def, cfg, &fp, &calib), xs, ys)
    }

    fn train(
        m: &mut NativeModel,
        opt: &mut dyn Optimizer,
        xs: &[TensorF32],
        ys: &[usize],
        epochs: usize,
    ) -> f32 {
        let mut ops = OpCounter::new();
        for _ in 0..epochs {
            for (x, &y) in xs.iter().zip(ys) {
                let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                opt.accumulate(m, &bwd, &mut ops);
            }
            opt.finish(m, &mut ops);
        }
        m.evaluate(xs, ys)
    }

    #[test]
    fn float_sgdm_learns_toy() {
        let (mut m, xs, ys) = setup(DnnConfig::Float32, 81);
        let mut opt = SgdM::new(&m, 0.01, 4);
        let acc = train(&mut m, &mut opt, &xs, &ys, 15);
        assert!(acc >= 0.85, "acc={acc}");
    }

    #[test]
    fn qas_beats_or_matches_naive_on_toy() {
        // With frozen quantization params, QAS conditions the gradient; on
        // a toy run both may learn, but QAS must never be much worse.
        let (mut m1, xs, ys) = setup(DnnConfig::Uint8, 82);
        let mut naive = NaiveQSgdM::new(&m1, 0.01, 4);
        let a_naive = train(&mut m1, &mut naive, &xs, &ys, 15);
        let (mut m2, xs2, ys2) = setup(DnnConfig::Uint8, 82);
        let mut qas = QasSgdM::new(&m2, 0.01, 4);
        let a_qas = train(&mut m2, &mut qas, &xs2, &ys2, 15);
        assert!(a_qas + 0.15 >= a_naive, "qas={a_qas} naive={a_naive}");
    }

    #[test]
    fn naive_keeps_quant_params_frozen() {
        let (mut m, xs, ys) = setup(DnnConfig::Uint8, 83);
        let head = m.shared.def.layers.len() - 1;
        let qp0 = match &m.state.params[head] {
            LayerParams::Q { w, .. } => w.qp,
            other => panic!(
                "head layer of the uint8 config must hold quantized params, found {}",
                other.flavor()
            ),
        };
        let mut opt = NaiveQSgdM::new(&m, 0.05, 4);
        train(&mut m, &mut opt, &xs, &ys, 5);
        let qp1 = match &m.state.params[head] {
            LayerParams::Q { w, .. } => w.qp,
            other => panic!(
                "head layer of the uint8 config must hold quantized params, found {}",
                other.flavor()
            ),
        };
        assert_eq!(qp0, qp1, "baselines must not adapt quantization params");
    }

    #[test]
    fn momentum_state_counted() {
        let (m, _, _) = setup(DnnConfig::Uint8, 84);
        let opt = SgdM::new(&m, 0.01, 4);
        // acc + vel: twice the gradient-buffer footprint
        assert!(opt.state_bytes() > 0);
        let fqt = crate::train::fqt::FqtSgd::new(&m, 0.01, 4);
        assert!(opt.state_bytes() > fqt.state_bytes(), "momentum needs more state than FQT");
    }
}
