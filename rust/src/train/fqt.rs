//! The paper's FQT optimizer: quantized SGD with gradient accumulation,
//! per-structure gradient standardization, and dynamic re-derivation of the
//! weight quantization parameters.
//!
//! Per minibatch and per trainable layer (Eqs. 5–8):
//!
//! 1. accumulate float gradients over `b` successive single-sample steps
//!    (no batch dimension anywhere — §III-A option (b));
//! 2. standardize the averaged gradient per structure with *running*
//!    mean/std gathered across the whole training so far (Eq. 8, the
//!    RMSProp-like stabilization);
//! 3. descend in float space: `w_f = (w_q − z)·s − ℓ·ĝ` (Eq. 5);
//! 4. re-derive scale and zero point from the min/max of `w_f`
//!    (Eqs. 6–7) and requantize — the weight tensor's 8-bit range tracks
//!    the weight distribution as training moves it.
//!
//! Biases are updated with plain float SGD (they are stored in float and
//! cost `Cout` values per layer).
//!
//! Sparse updates: structures whose accumulated gradient is exactly zero
//! (masked by the §III-B controller, or genuinely zero) are skipped — they
//! receive no descent step and do not pollute the running statistics.

use crate::graph::exec::{BwdResult, LayerParams, NativeModel};
use crate::graph::Precision;
use crate::kernels::OpCounter;
use crate::quant::subbyte::PackedQTensor;
use crate::quant::{QParams, QTensor};
use crate::tensor::TensorF32;
use crate::train::Optimizer;

/// Per-layer gradient accumulation buffer plus running per-structure
/// statistics (Welford over gradient elements, maintained across the whole
/// training run).
struct GradBuf {
    gw: TensorF32,
    gb: TensorF32,
    /// Structures that received any gradient this minibatch.
    touched: Vec<bool>,
    /// Running per-structure statistics.
    n: Vec<u64>,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl GradBuf {
    fn new(w_shape: &[usize], n_out: usize) -> GradBuf {
        GradBuf {
            gw: TensorF32::zeros(w_shape),
            gb: TensorF32::zeros(&[n_out]),
            touched: vec![false; n_out],
            n: vec![0; n_out],
            mean: vec![0.0; n_out],
            m2: vec![0.0; n_out],
        }
    }

    /// Add one sample's gradient; update running stats for non-zero
    /// structures.
    fn push(&mut self, gw: &TensorF32, gb: &TensorF32) {
        debug_assert_eq!(gw.shape(), self.gw.shape());
        let structures = self.touched.len();
        for c in 0..structures {
            let src = gw.outer(c);
            let zero = src.iter().all(|&v| v == 0.0) && gb.data()[c] == 0.0;
            if zero {
                continue;
            }
            self.touched[c] = true;
            let dst = self.gw.outer_mut(c);
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += s;
                // Welford over gradient elements of this structure
                self.n[c] += 1;
                let delta = s as f64 - self.mean[c];
                self.mean[c] += delta / self.n[c] as f64;
                self.m2[c] += delta * (s as f64 - self.mean[c]);
            }
            self.gb.data_mut()[c] += gb.data()[c];
        }
    }

    /// Standardization denominator: the running RMS of the structure's
    /// gradient elements, `sqrt(σ² + µ²)` (the paper motivates Eq. 8 "similar
    /// to the intuition of RMSProp"; a pure σ denominator explodes when a
    /// structure's gradients are near-constant, so the RMS form is used).
    fn std(&self, c: usize) -> f32 {
        if self.n[c] < 2 {
            return 1.0;
        }
        let var = self.m2[c] / self.n[c] as f64;
        let rms = (var + self.mean[c] * self.mean[c]).sqrt() as f32;
        if rms > 1e-8 {
            rms
        } else {
            1.0
        }
    }

    fn clear_batch(&mut self) {
        self.gw.data_mut().fill(0.0);
        self.gb.data_mut().fill(0.0);
        self.touched.fill(false);
    }

    fn bytes(&self) -> usize {
        // gradient buffers + per-structure running stats, as held on-device
        (self.gw.len() + self.gb.len()) * 4 + self.touched.len() * (8 + 4 + 4 + 1)
    }
}

/// The FQT optimizer (ours).
pub struct FqtSgd {
    pub lr: f32,
    pub batch: usize,
    count: usize,
    bufs: Vec<Option<GradBuf>>,
    /// Standardize gradients (Eq. 8). On by default; the ablation bench
    /// switches it off to reproduce the naive-FQT degradation.
    pub standardize: bool,
    /// Re-derive weight scale/zero-point every step (Eqs. 6–7). On by
    /// default; off freezes the deployed quantization parameters (the
    /// failure mode of the naive int8 baseline).
    pub adapt_range: bool,
}

impl FqtSgd {
    pub fn new(model: &NativeModel, lr: f32, batch: usize) -> FqtSgd {
        let bufs = model
            .state
            .params
            .iter()
            .zip(&model.shared.def.layers)
            .map(|(p, l)| {
                if !l.trainable {
                    return None;
                }
                match p {
                    LayerParams::Q { w, bias } => Some(GradBuf::new(w.shape(), bias.len())),
                    LayerParams::Qp { w, bias } => Some(GradBuf::new(w.shape(), bias.len())),
                    LayerParams::F { w, bias } => Some(GradBuf::new(w.shape(), bias.len())),
                    LayerParams::None => None,
                }
            })
            .collect();
        FqtSgd { lr, batch: batch.max(1), count: 0, bufs, standardize: true, adapt_range: true }
    }

    /// Apply the accumulated minibatch (Eqs. 5–8) and clear the buffers.
    fn step(&mut self, model: &mut NativeModel, ops: &mut OpCounter) {
        if self.count == 0 {
            return;
        }
        let scale = 1.0 / self.count as f32;
        for (i, buf) in self.bufs.iter_mut().enumerate() {
            let Some(buf) = buf else { continue };
            if !buf.touched.iter().any(|&t| t) {
                continue;
            }
            match (&mut model.state.params[i], model.shared.prec[i]) {
                (LayerParams::Q { w, bias }, _) => {
                    update_quantized(
                        w,
                        bias,
                        buf,
                        self.lr,
                        scale,
                        self.standardize,
                        self.adapt_range,
                        ops,
                    );
                }
                (LayerParams::Qp { w, bias }, _) => {
                    update_quantized_packed(
                        w,
                        bias,
                        buf,
                        self.lr,
                        scale,
                        self.standardize,
                        self.adapt_range,
                        ops,
                    );
                }
                (LayerParams::F { w, bias }, Precision::Float32) => {
                    update_float(w, bias, buf, self.lr, scale, self.standardize, ops);
                }
                _ => {}
            }
            // Dirty bit: the update invalidates this layer's cached
            // backward weight pack (see `graph::packs`); the next
            // `warm_packs` re-packs exactly the touched layers.
            model.touch_layer(i);
            buf.clear_batch();
        }
        self.count = 0;
    }
}

/// Eq. 5/8 + Eqs. 6–7: float-space descent on dequantized weights with
/// standardized gradients, then requantization at freshly derived params.
#[allow(clippy::too_many_arguments)]
fn update_quantized(
    w: &mut QTensor,
    bias: &mut [f32],
    buf: &GradBuf,
    lr: f32,
    inv_b: f32,
    standardize: bool,
    adapt_range: bool,
    ops: &mut OpCounter,
) {
    let structures = buf.touched.len();
    let old = w.qp;
    // 1) dequantize + descend (touched structures only)
    let mut wf = w.dequantize();
    let mut fmin = f32::INFINITY;
    let mut fmax = f32::NEG_INFINITY;
    for c in 0..structures {
        let gsrc = buf.gw.outer(c);
        let dst = wf.outer_mut(c);
        if buf.touched[c] {
            let (mu, sd) = if standardize {
                (buf.mean[c] as f32, buf.std(c))
            } else {
                (0.0, 1.0)
            };
            for (v, &g) in dst.iter_mut().zip(gsrc.iter()) {
                let ghat = ((g * inv_b - mu) / sd).clamp(-10.0, 10.0);
                *v -= lr * ghat;
            }
            bias[c] -= lr * buf.gb.data()[c] * inv_b;
        }
        for &v in dst.iter() {
            fmin = fmin.min(v);
            fmax = fmax.max(v);
        }
    }
    // 2) Eqs. 6–7: new quantization parameters from the float intermediate
    // (or the original frozen parameters when range adaptation is ablated)
    let qp = if adapt_range { QParams::from_min_max(fmin, fmax) } else { old };
    *w = QTensor::quantize_with(&wf, qp);
    ops.float_ops += (wf.len() * 3) as u64;
    ops.int_ops += wf.len() as u64; // requantization
    ops.bytes += (wf.len() * 5) as u64;
}

/// [`update_quantized`] twin for packed sub-byte layers: identical descent
/// and range re-derivation, but the quantization grid spans `2^bits` levels
/// ([`QParams::from_min_max_bits`]) and the requantized lanes are written
/// back packed — the quantize-on-write contract that keeps demoted layers
/// at their planned storage width across the whole training run. At 8-bit
/// lanes the grid and the written bytes match the [`QTensor`] arm exactly.
#[allow(clippy::too_many_arguments)]
fn update_quantized_packed(
    w: &mut PackedQTensor,
    bias: &mut [f32],
    buf: &GradBuf,
    lr: f32,
    inv_b: f32,
    standardize: bool,
    adapt_range: bool,
    ops: &mut OpCounter,
) {
    let structures = buf.touched.len();
    let old = w.qp;
    let bits = w.bits;
    let mut wf = w.dequantize();
    let mut fmin = f32::INFINITY;
    let mut fmax = f32::NEG_INFINITY;
    for c in 0..structures {
        let gsrc = buf.gw.outer(c);
        let dst = wf.outer_mut(c);
        if buf.touched[c] {
            let (mu, sd) = if standardize {
                (buf.mean[c] as f32, buf.std(c))
            } else {
                (0.0, 1.0)
            };
            for (v, &g) in dst.iter_mut().zip(gsrc.iter()) {
                let ghat = ((g * inv_b - mu) / sd).clamp(-10.0, 10.0);
                *v -= lr * ghat;
            }
            bias[c] -= lr * buf.gb.data()[c] * inv_b;
        }
        for &v in dst.iter() {
            fmin = fmin.min(v);
            fmax = fmax.max(v);
        }
    }
    let qp = if adapt_range { QParams::from_min_max_bits(fmin, fmax, bits) } else { old };
    *w = PackedQTensor::quantize_with_bits(&wf, qp, bits);
    ops.float_ops += (wf.len() * 3) as u64;
    ops.int_ops += wf.len() as u64; // requantization
    // float read-modify-write plus the packed store (== len at 8-bit).
    ops.bytes += (wf.len() * 4 + w.packed_bytes()) as u64;
}

/// Float SGD for float-precision layers (the paper's mixed / float32
/// configurations train those layers in floating point). The same Eq. 8
/// per-structure standardization is applied — without BatchNorm (folded
/// away at deployment, Fig. 2b) the deeper MbedNet stack vanishes under
/// raw-gradient SGD, and the paper presents standardization as part of its
/// training method rather than of the quantized path specifically.
fn update_float(
    w: &mut TensorF32,
    bias: &mut [f32],
    buf: &GradBuf,
    lr: f32,
    inv_b: f32,
    standardize: bool,
    ops: &mut OpCounter,
) {
    let structures = buf.touched.len();
    for c in 0..structures {
        if !buf.touched[c] {
            continue;
        }
        let (mu, sd) = if standardize { (buf.mean[c] as f32, buf.std(c)) } else { (0.0, 1.0) };
        let gsrc = buf.gw.outer(c);
        for (v, &g) in w.outer_mut(c).iter_mut().zip(gsrc.iter()) {
            let ghat = ((g * inv_b - mu) / sd).clamp(-10.0, 10.0);
            *v -= lr * ghat;
        }
        bias[c] -= lr * buf.gb.data()[c] * inv_b;
    }
    ops.float_ops += (w.len() * 3) as u64;
    ops.bytes += (w.len() * 8) as u64;
}

impl Optimizer for FqtSgd {
    fn accumulate(&mut self, model: &mut NativeModel, bwd: &BwdResult, ops: &mut OpCounter) {
        for (i, g) in bwd.grads.iter().enumerate() {
            if let (Some(g), Some(buf)) = (g, self.bufs[i].as_mut()) {
                buf.push(&g.gw, &g.gb);
                ops.float_ops += g.gw.len() as u64;
            }
        }
        self.count += 1;
        if self.count >= self.batch {
            self.step(model, ops);
        }
    }

    fn finish(&mut self, model: &mut NativeModel, ops: &mut OpCounter) {
        self.step(model, ops);
    }

    fn state_bytes(&self) -> usize {
        self.bufs.iter().flatten().map(|b| b.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::exec::{calibrate, DenseUpdates, FloatParams};
    use crate::graph::{models, DnnConfig};
    use crate::util::prng::Pcg32;

    fn setup(cfg: DnnConfig) -> (NativeModel, Vec<TensorF32>, Vec<usize>) {
        let mut rng = Pcg32::seeded(71);
        let def = models::mnist_cnn(&[1, 12, 12], 2);
        let fp = FloatParams::init(&def, &mut rng);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..16 {
            let y = i % 2;
            let mut x = TensorF32::zeros(&[1, 12, 12]);
            rng.fill_normal(x.data_mut(), 0.4);
            for v in x.data_mut().iter_mut() {
                *v += y as f32;
            }
            xs.push(x);
            ys.push(y);
        }
        let calib = calibrate(&def, &fp, &xs[..4]);
        (NativeModel::build(def, cfg, &fp, &calib), xs, ys)
    }

    #[test]
    fn weight_scale_adapts_during_training() {
        let (mut m, xs, ys) = setup(DnnConfig::Uint8);
        let head = m.shared.def.layers.len() - 1;
        let qp_before = match &m.state.params[head] {
            LayerParams::Q { w, .. } => w.qp,
            other => panic!(
                "head layer of the uint8 config must hold quantized params, found {}",
                other.flavor()
            ),
        };
        let mut opt = FqtSgd::new(&m, 0.05, 4);
        let mut ops = OpCounter::new();
        for _ in 0..3 {
            for (x, &y) in xs.iter().zip(&ys) {
                let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                opt.accumulate(&mut m, &bwd, &mut ops);
            }
        }
        let qp_after = match &m.state.params[head] {
            LayerParams::Q { w, .. } => w.qp,
            other => panic!(
                "head layer of the uint8 config must hold quantized params, found {}",
                other.flavor()
            ),
        };
        assert_ne!(qp_before, qp_after, "Eqs. 6-7 should move the weight range");
    }

    #[test]
    fn training_improves_toy_accuracy_all_configs() {
        for cfg in [DnnConfig::Uint8, DnnConfig::Mixed, DnnConfig::Float32] {
            let (mut m, xs, ys) = setup(cfg);
            let acc0 = m.evaluate(&xs, &ys);
            let mut opt = FqtSgd::new(&m, 0.02, 4);
            let mut ops = OpCounter::new();
            for _ in 0..15 {
                for (x, &y) in xs.iter().zip(&ys) {
                    let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                    opt.accumulate(&mut m, &bwd, &mut ops);
                }
                opt.finish(&mut m, &mut ops);
            }
            let acc1 = m.evaluate(&xs, &ys);
            assert!(acc1 >= acc0.max(0.7), "{cfg:?}: acc {acc0} -> {acc1}");
        }
    }

    #[test]
    fn batch_boundary_applies_update() {
        let (mut m, xs, ys) = setup(DnnConfig::Uint8);
        let mut opt = FqtSgd::new(&m, 0.05, 4);
        let snapshot = |m: &NativeModel| -> Vec<u8> {
            m.state.params
                .iter()
                .filter_map(|p| match p {
                    LayerParams::Q { w, .. } => Some(w.values.data().to_vec()),
                    _ => None,
                })
                .flatten()
                .collect()
        };
        let s0 = snapshot(&m);
        let mut ops = OpCounter::new();
        // 3 samples: no update yet
        for i in 0..3 {
            let (_, _, bwd) = m.train_sample(&xs[i], ys[i], &mut DenseUpdates, &mut ops);
            opt.accumulate(&mut m, &bwd, &mut ops);
        }
        assert_eq!(snapshot(&m), s0, "update must wait for the batch boundary");
        let (_, _, bwd) = m.train_sample(&xs[3], ys[3], &mut DenseUpdates, &mut ops);
        opt.accumulate(&mut m, &bwd, &mut ops);
        assert_ne!(snapshot(&m), s0, "4th sample completes the minibatch");
    }

    #[test]
    fn state_bytes_counts_trainable_layers_only() {
        let (m, _, _) = setup(DnnConfig::Uint8);
        let opt_full = FqtSgd::new(&m, 0.01, 8);
        let mut def2 = m.shared.def.clone();
        def2.set_trainable_tail(1);
        let mut rng = Pcg32::seeded(5);
        let fp = FloatParams::init(&def2, &mut rng);
        let calib = calibrate(&def2, &fp, &[TensorF32::zeros(&[1, 12, 12])]);
        let m2 = NativeModel::build(def2, DnnConfig::Uint8, &fp, &calib);
        let opt_tail = FqtSgd::new(&m2, 0.01, 8);
        assert!(opt_tail.state_bytes() < opt_full.state_bytes());
        assert!(opt_tail.state_bytes() > 0);
    }

    #[test]
    fn finish_flushes_partial_batch() {
        let (mut m, xs, ys) = setup(DnnConfig::Uint8);
        let mut opt = FqtSgd::new(&m, 0.05, 100); // batch larger than data
        let mut ops = OpCounter::new();
        let before = m.evaluate(&xs, &ys);
        for _ in 0..10 {
            for (x, &y) in xs.iter().zip(&ys) {
                let (_, _, bwd) = m.train_sample(x, y, &mut DenseUpdates, &mut ops);
                opt.accumulate(&mut m, &bwd, &mut ops);
            }
            opt.finish(&mut m, &mut ops);
        }
        let after = m.evaluate(&xs, &ys);
        assert!(after >= before.max(0.7), "finish() must apply partial batches: {before}->{after}");
    }
}
