//! Dynamic sparse gradient updates (§III-B).
//!
//! Per training sample and per trainable layer, the controller ranks the
//! error tensor's *structures* (out-channels of conv layers, rows of linear
//! layers) by their L1 norm and keeps only the top-k; masked structures are
//! skipped by both backward kernels (no weight gradient, no contribution to
//! the backpropagated error).
//!
//! k follows Eq. 9:
//!
//! ```text
//! k = ⌊ min(λ_min + |ε|·(λ_max − λ_min), 1) · N ⌋
//! ```
//!
//! with `|ε|` the current sample's loss normalized by the maximum loss
//! observed over the whole training so far — as the loss converges toward
//! zero, the update rate converges toward `λ_min` (fewer structures worth
//! updating late in training, Fig. 3's third observation).
//!
//! **No-history fallback (λ_max):** before any loss has been observed the
//! normalizer `max_loss` is zero, so `|ε| = loss / max_loss` is undefined.
//! The controller defines `|ε| = 1` in that state — the *conservative*
//! choice: the very first sample (and any zero-loss sample before real
//! history exists) trains at the full `λ_max` rate rather than risking a
//! spuriously sparse update off an empty normalizer. Once history exists,
//! a zero loss pins the rate at `λ_min` as Eq. 9 prescribes. See
//! DESIGN.md §2 ("sparse row-skip contract") for how the resulting masks
//! reach the backward kernels.

use crate::graph::exec::MaskProvider;
use crate::util::stats::top_k_indices;

/// The Eq. 9 controller — the shipping [`MaskProvider`] implementation.
/// Create once per training run; call [`DynamicSparse::begin_sample`]
/// with the sample's loss before the backward pass (the training loop
/// does this).
#[derive(Clone, Debug)]
pub struct DynamicSparse {
    pub lambda_min: f32,
    pub lambda_max: f32,
    max_loss: f32,
    cur_eps: f32,
    /// Accounting: structures kept / total across all masked layers.
    pub kept: u64,
    pub total: u64,
}

impl DynamicSparse {
    pub fn new(lambda_min: f32, lambda_max: f32) -> DynamicSparse {
        assert!(
            (0.0..=1.0).contains(&lambda_min) && lambda_min <= lambda_max && lambda_max <= 1.0,
            "need 0 <= λ_min <= λ_max <= 1"
        );
        DynamicSparse { lambda_min, lambda_max, max_loss: 0.0, cur_eps: 1.0, kept: 0, total: 0 }
    }

    /// Pre-seed the running maximum loss — puts the controller in the
    /// late-training regime (`|ε| → 0`, rate → λ_min) without replaying a
    /// training run. Used when measuring the Fig. 6d steady-state speedup.
    pub fn seed_max_loss(&mut self, max_loss: f32) {
        self.max_loss = self.max_loss.max(max_loss);
    }

    /// Register the sample's loss; updates the running maximum and computes
    /// `|ε| = loss / max_loss ∈ [0, 1]`. With no history (`max_loss` still
    /// zero — e.g. an exactly-zero first loss) `|ε|` falls back to 1, so
    /// [`DynamicSparse::rate`] returns the conservative λ_max full rate
    /// instead of dividing by zero (see the module docs).
    pub fn begin_sample(&mut self, loss: f32) {
        self.max_loss = self.max_loss.max(loss.abs());
        self.cur_eps =
            if self.max_loss > 0.0 { (loss.abs() / self.max_loss).clamp(0.0, 1.0) } else { 1.0 };
    }

    /// The current per-layer update rate `min(λ_min + |ε|(λ_max−λ_min), 1)`.
    pub fn rate(&self) -> f32 {
        (self.lambda_min + self.cur_eps * (self.lambda_max - self.lambda_min)).min(1.0)
    }

    /// Fraction of structures actually kept so far.
    pub fn kept_fraction(&self) -> f32 {
        if self.total == 0 {
            1.0
        } else {
            self.kept as f32 / self.total as f32
        }
    }
}

impl MaskProvider for DynamicSparse {
    fn mask(&mut self, _layer: usize, norms: &[f32]) -> Option<Vec<bool>> {
        let n = norms.len();
        self.total += n as u64;
        let k = ((self.rate() * n as f32).floor() as usize).clamp(1, n);
        if k == n {
            self.kept += n as u64;
            return None; // dense — skip the masking overhead entirely
        }
        self.kept += k as u64;
        let mut keep = vec![false; n];
        for i in top_k_indices(norms, k) {
            keep[i] = true;
        }
        Some(keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_interpolates_with_loss() {
        let mut c = DynamicSparse::new(0.1, 1.0);
        c.begin_sample(2.0); // first sample defines max -> eps = 1
        assert!((c.rate() - 1.0).abs() < 1e-6);
        c.begin_sample(0.2); // converged to 10% of max
        assert!((c.rate() - (0.1 + 0.1 * 0.9)).abs() < 1e-6);
        c.begin_sample(0.0);
        assert!((c.rate() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn max_loss_is_monotone() {
        let mut c = DynamicSparse::new(0.5, 1.0);
        c.begin_sample(1.0);
        c.begin_sample(4.0); // new max
        c.begin_sample(1.0); // eps = 0.25 now
        assert!((c.rate() - (0.5 + 0.25 * 0.5)).abs() < 1e-6);
    }

    #[test]
    fn mask_keeps_top_k_by_norm() {
        let mut c = DynamicSparse::new(0.5, 0.5); // fixed 50%
        c.begin_sample(1.0);
        let norms = [0.1f32, 5.0, 0.2, 3.0];
        let m = c.mask(0, &norms).unwrap();
        assert_eq!(m, vec![false, true, false, true]);
        assert_eq!(c.kept, 2);
        assert_eq!(c.total, 4);
    }

    #[test]
    fn full_rate_returns_dense_none() {
        let mut c = DynamicSparse::new(1.0, 1.0);
        c.begin_sample(1.0);
        assert!(c.mask(0, &[1.0, 2.0, 3.0]).is_none());
        assert_eq!(c.kept_fraction(), 1.0);
    }

    #[test]
    fn at_least_one_structure_kept() {
        let mut c = DynamicSparse::new(0.0, 0.0);
        c.begin_sample(1.0);
        let m = c.mask(0, &[0.5, 0.9]).unwrap();
        assert_eq!(m.iter().filter(|&&b| b).count(), 1);
        assert!(m[1]); // the larger norm survives
    }

    #[test]
    #[should_panic(expected = "λ_min")]
    fn rejects_bad_lambdas() {
        DynamicSparse::new(0.9, 0.1);
    }

    /// Edge case: a zero loss before any history leaves `max_loss` at 0 —
    /// the controller must fall back to the conservative full rate (λ_max),
    /// not divide by zero.
    #[test]
    fn zero_loss_without_history_uses_full_rate() {
        let mut c = DynamicSparse::new(0.2, 0.8);
        c.begin_sample(0.0);
        assert!((c.rate() - 0.8).abs() < 1e-6);
        assert!(c.rate().is_finite());
    }

    /// Edge case: a zero loss after history pins the rate at λ_min.
    #[test]
    fn zero_loss_after_history_uses_lambda_min() {
        let mut c = DynamicSparse::new(0.2, 0.8);
        c.begin_sample(3.0);
        c.begin_sample(0.0);
        assert!((c.rate() - 0.2).abs() < 1e-6);
    }

    /// Edge case: a loss above the running maximum becomes the new maximum
    /// (|ε| = 1 exactly, never above) and rescales subsequent samples.
    #[test]
    fn loss_above_running_max_resets_normalizer() {
        let mut c = DynamicSparse::new(0.1, 1.0);
        c.begin_sample(2.0);
        c.begin_sample(8.0); // above the max: |ε| must clamp to exactly 1
        assert!((c.rate() - 1.0).abs() < 1e-6);
        c.begin_sample(2.0); // now normalized by 8, not by 2
        assert!((c.rate() - (0.1 + 0.25 * 0.9)).abs() < 1e-6);
    }

    /// Edge case: negative losses participate via |loss| (the controller
    /// normalizes magnitudes, not signed values).
    #[test]
    fn negative_loss_uses_magnitude() {
        let mut c = DynamicSparse::new(0.1, 1.0);
        c.begin_sample(-4.0);
        assert!((c.rate() - 1.0).abs() < 1e-6);
        c.begin_sample(-1.0);
        assert!((c.rate() - (0.1 + 0.25 * 0.9)).abs() < 1e-6);
    }

    #[test]
    fn kept_fraction_tracks_rate() {
        let mut c = DynamicSparse::new(0.1, 1.0);
        c.begin_sample(10.0);
        c.begin_sample(0.01); // tiny loss -> rate ~ 0.1
        for _ in 0..50 {
            let norms: Vec<f32> = (0..20).map(|i| i as f32).collect();
            let _ = c.mask(0, &norms);
        }
        assert!(c.kept_fraction() < 0.2, "kept={}", c.kept_fraction());
    }
}
