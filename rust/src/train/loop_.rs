//! Training loop driver: epochs over a dataset, single-sample steps with
//! gradient-accumulation minibatching, optional dynamic sparse updates,
//! per-epoch metrics and fwd/bwd op accounting (the split behind
//! Figs. 4b/7b).

use crate::graph::batch::WorkerPool;
use crate::graph::exec::{DenseUpdates, NativeModel};
use crate::kernels::{softmax, OpCounter};
use crate::tensor::TensorF32;
use crate::train::sparse::DynamicSparse;
use crate::train::Optimizer;
use crate::util::prng::Pcg32;

/// Sparsity setting for a run.
pub enum Sparsity {
    /// Full gradient updates (λ_min = λ_max = 1).
    Dense,
    /// Eq. 9 controller with the given (λ_min, λ_max).
    Dynamic(DynamicSparse),
}

/// One epoch's metrics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub train_loss: f32,
    pub train_acc: f32,
    pub test_acc: f32,
}

/// Full-run report.
#[derive(Debug)]
pub struct TrainReport {
    pub epochs: Vec<EpochStats>,
    /// Total forward-pass ops across the run.
    pub fwd_ops: OpCounter,
    /// Total backward+update ops across the run.
    pub bwd_ops: OpCounter,
    pub samples_seen: u64,
    /// Fraction of gradient structures actually updated (1.0 when dense).
    pub kept_fraction: f32,
}

impl TrainReport {
    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }
}

/// A labeled dataset split.
pub struct Split {
    pub xs: Vec<TensorF32>,
    pub ys: Vec<usize>,
}

impl Split {
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }
}

/// Run `epochs` of on-device training. Samples are shuffled per epoch with
/// the supplied PRNG; the loss of each sample is fed to the sparse
/// controller before its backward pass (Eq. 9's `|ε|`).
pub fn train(
    model: &mut NativeModel,
    opt: &mut dyn Optimizer,
    train_split: &Split,
    test_split: &Split,
    epochs: usize,
    sparsity: &mut Sparsity,
    rng: &mut Pcg32,
) -> TrainReport {
    let mut fwd_ops = OpCounter::new();
    let mut bwd_ops = OpCounter::new();
    let mut epoch_stats = Vec::with_capacity(epochs);
    let mut samples_seen = 0u64;
    // One scratch arena for the whole run, pre-sized from the model's
    // compiled execution plan (exact per-op requirements, all precisions)
    // and reused by every forward and backward pass with zero growth.
    let mut scratch = model.make_scratch();

    for _ in 0..epochs {
        let order = rng.permutation(train_split.len());
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        for &i in &order {
            let x = &train_split.xs[i];
            let y = train_split.ys[i];
            let trace = model.forward_adapt_in(x, &mut scratch, &mut fwd_ops);
            let (loss, probs, err) = softmax::softmax_ce(&trace.logits, y, &mut bwd_ops);
            loss_sum += loss;
            if softmax::predict(&probs) == y {
                correct += 1;
            }
            let bwd = match sparsity {
                Sparsity::Dense => {
                    model.backward_in(&trace, err, &mut DenseUpdates, &mut scratch, &mut bwd_ops)
                }
                Sparsity::Dynamic(ctl) => {
                    ctl.begin_sample(loss);
                    model.backward_in(&trace, err, ctl, &mut scratch, &mut bwd_ops)
                }
            };
            opt.accumulate(model, &bwd, &mut bwd_ops);
            samples_seen += 1;
        }
        opt.finish(model, &mut bwd_ops);
        epoch_stats.push(EpochStats {
            train_loss: loss_sum / train_split.len().max(1) as f32,
            train_acc: correct as f32 / train_split.len().max(1) as f32,
            test_acc: model.evaluate(&test_split.xs, &test_split.ys),
        });
    }

    let kept_fraction = match sparsity {
        Sparsity::Dense => 1.0,
        Sparsity::Dynamic(ctl) => ctl.kept_fraction(),
    };
    TrainReport { epochs: epoch_stats, fwd_ops, bwd_ops, samples_seen, kept_fraction }
}

/// Batched/threaded variant of [`train`]: each shuffled epoch is processed
/// in `batch`-sized slices through [`NativeModel::train_batch_pooled`],
/// with samples sharded across a **persistent worker pool** owned by this
/// loop — one [`WorkerPool`] (and thus one thread set plus one
/// per-worker scratch arena) for the whole run, not per minibatch.
///
/// Within a slice every sample sees the same model snapshot and the
/// activation-range / error-observer updates are folded in afterwards in
/// sample order, so the resulting weights are **bit-identical for every
/// worker count** (the determinism contract of the batch engine; see
/// `NativeModel::train_batch_pooled`). The dynamic sparse controller is
/// inherently per-sample-sequential, so this path always runs dense
/// updates — sparse experiments stay on [`train`].
#[allow(clippy::too_many_arguments)]
pub fn train_batched(
    model: &mut NativeModel,
    opt: &mut dyn Optimizer,
    train_split: &Split,
    test_split: &Split,
    epochs: usize,
    batch: usize,
    workers: usize,
    rng: &mut Pcg32,
) -> TrainReport {
    let mut fwd_ops = OpCounter::new();
    let mut bwd_ops = OpCounter::new();
    let mut epoch_stats = Vec::with_capacity(epochs);
    let mut samples_seen = 0u64;
    let batch = batch.max(1);
    // The run-long worker pool (TT_WORKERS semantics unchanged: `workers`
    // threads, each batch uses at most one per sample).
    let mut pool = WorkerPool::new(workers.max(1));

    for _ in 0..epochs {
        let order = rng.permutation(train_split.len());
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        for chunk in order.chunks(batch) {
            let xs: Vec<&TensorF32> = chunk.iter().map(|&i| &train_split.xs[i]).collect();
            let ys: Vec<usize> = chunk.iter().map(|&i| train_split.ys[i]).collect();
            let res = model.train_batch_pooled(&xs, &ys, &mut pool);
            fwd_ops.add(&res.fwd_ops);
            bwd_ops.add(&res.bwd_ops);
            for (k, bwd) in res.grads.iter().enumerate() {
                loss_sum += res.losses[k];
                if res.preds[k] == ys[k] {
                    correct += 1;
                }
                opt.accumulate(model, bwd, &mut bwd_ops);
                samples_seen += 1;
            }
        }
        opt.finish(model, &mut bwd_ops);
        epoch_stats.push(EpochStats {
            train_loss: loss_sum / train_split.len().max(1) as f32,
            train_acc: correct as f32 / train_split.len().max(1) as f32,
            test_acc: model.evaluate(&test_split.xs, &test_split.ys),
        });
    }

    TrainReport { epochs: epoch_stats, fwd_ops, bwd_ops, samples_seen, kept_fraction: 1.0 }
}

/// Measure per-sample fwd/bwd op counts of the *current* model state,
/// without updating weights (the "averaged over 1000 consecutive training
/// steps" instrumentation of Figs. 4b/5/7b — op counts are deterministic
/// per sample here, so one representative pass per sample suffices).
pub fn measure_step_ops(
    model: &mut NativeModel,
    split: &Split,
    n_samples: usize,
    sparsity: &mut Sparsity,
) -> (OpCounter, OpCounter) {
    let mut fwd = OpCounter::new();
    let mut bwd = OpCounter::new();
    let n = n_samples.min(split.len()).max(1);
    for i in 0..n {
        let trace = model.forward(&split.xs[i], &mut fwd);
        let (loss, _, err) = softmax::softmax_ce(&trace.logits, split.ys[i], &mut bwd);
        match sparsity {
            Sparsity::Dense => {
                model.backward(&trace, err, &mut DenseUpdates, &mut bwd);
            }
            Sparsity::Dynamic(ctl) => {
                ctl.begin_sample(loss);
                model.backward(&trace, err, ctl, &mut bwd);
            }
        }
    }
    // normalize to per-sample counts
    let div = |c: &OpCounter| OpCounter {
        int_macs: c.int_macs / n as u64,
        float_macs: c.float_macs / n as u64,
        int_ops: c.int_ops / n as u64,
        float_ops: c.float_ops / n as u64,
        bytes: c.bytes / n as u64,
    };
    (div(&fwd), div(&bwd))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::exec::{calibrate, FloatParams};
    use crate::graph::{models, DnnConfig};
    use crate::train::fqt::FqtSgd;

    fn toy() -> (NativeModel, Split, Split) {
        let mut rng = Pcg32::seeded(91);
        let def = models::mnist_cnn(&[1, 12, 12], 2);
        let fp = FloatParams::init(&def, &mut rng);
        let mut mk = |n: usize| -> Split {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for i in 0..n {
                let y = i % 2;
                let mut x = TensorF32::zeros(&[1, 12, 12]);
                rng.fill_normal(x.data_mut(), 0.4);
                for v in x.data_mut().iter_mut() {
                    *v += y as f32;
                }
                xs.push(x);
                ys.push(y);
            }
            Split { xs, ys }
        };
        let tr = mk(16);
        let te = mk(8);
        let calib = calibrate(&def, &fp, &tr.xs[..4]);
        (NativeModel::build(def, DnnConfig::Uint8, &fp, &calib), tr, te)
    }

    #[test]
    fn loop_learns_and_reports() {
        let (mut m, tr, te) = toy();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let mut rng = Pcg32::seeded(1);
        let rep = train(&mut m, &mut opt, &tr, &te, 12, &mut Sparsity::Dense, &mut rng);
        assert_eq!(rep.epochs.len(), 12);
        assert!(rep.final_test_acc() >= 0.7, "acc={}", rep.final_test_acc());
        assert!(rep.epochs.last().unwrap().train_loss < rep.epochs[0].train_loss);
        assert_eq!(rep.samples_seen, 12 * 16);
        assert!(rep.fwd_ops.total_macs() > 0 && rep.bwd_ops.total_macs() > 0);
        assert_eq!(rep.kept_fraction, 1.0);
    }

    /// Batched training must reach the same accuracy bar as the sequential
    /// loop on the toy problem, with correct bookkeeping.
    #[test]
    fn batched_loop_learns_and_reports() {
        let (mut m, tr, te) = toy();
        let mut opt = FqtSgd::new(&m, 0.01, 4);
        let mut rng = Pcg32::seeded(1);
        let rep = train_batched(&mut m, &mut opt, &tr, &te, 12, 4, 2, &mut rng);
        assert_eq!(rep.epochs.len(), 12);
        assert!(rep.final_test_acc() >= 0.7, "acc={}", rep.final_test_acc());
        assert_eq!(rep.samples_seen, 12 * 16);
        assert!(rep.fwd_ops.total_macs() > 0 && rep.bwd_ops.total_macs() > 0);
        assert_eq!(rep.kept_fraction, 1.0);
    }

    /// The headline determinism contract: a full batched training run must
    /// produce bit-identical weights for every worker count.
    #[test]
    fn batched_training_weights_invariant_to_worker_count() {
        use crate::graph::exec::LayerParams;
        let run = |workers: usize| -> (Vec<u8>, Vec<u32>) {
            let (mut m, tr, te) = toy();
            let mut opt = FqtSgd::new(&m, 0.01, 4);
            let mut rng = Pcg32::seeded(7);
            let _ = train_batched(&mut m, &mut opt, &tr, &te, 3, 4, workers, &mut rng);
            let mut wbits = Vec::new();
            let mut bbits = Vec::new();
            for p in &m.state.params {
                if let LayerParams::Q { w, bias } = p {
                    wbits.extend_from_slice(w.values.data());
                    bbits.extend(bias.iter().map(|b| b.to_bits()));
                }
            }
            (wbits, bbits)
        };
        let (w1, b1) = run(1);
        let (w3, b3) = run(3);
        assert_eq!(w1, w3, "quantized weights diverged across worker counts");
        assert_eq!(b1, b3, "float biases diverged across worker counts");
    }

    #[test]
    fn sparse_run_reduces_bwd_macs() {
        let (mut m1, tr, te) = toy();
        let mut opt1 = FqtSgd::new(&m1, 0.01, 4);
        let mut rng = Pcg32::seeded(2);
        let dense = train(&mut m1, &mut opt1, &tr, &te, 4, &mut Sparsity::Dense, &mut rng);

        let (mut m2, tr2, te2) = toy();
        let mut opt2 = FqtSgd::new(&m2, 0.01, 4);
        let mut rng2 = Pcg32::seeded(2);
        let mut sp = Sparsity::Dynamic(DynamicSparse::new(0.1, 1.0));
        let sparse = train(&mut m2, &mut opt2, &tr2, &te2, 4, &mut sp, &mut rng2);

        assert!(sparse.bwd_ops.total_macs() < dense.bwd_ops.total_macs());
        assert!(sparse.kept_fraction < 1.0);
        // forward cost is unaffected by sparse updates
        assert_eq!(sparse.fwd_ops.total_macs(), dense.fwd_ops.total_macs());
    }

    #[test]
    fn measure_step_ops_full_training_bwd_exceeds_fwd() {
        let (mut m, tr, _) = toy();
        let (fwd, bwd) = measure_step_ops(&mut m, &tr, 4, &mut Sparsity::Dense);
        // full training: backward ≈ 2× forward (§I-A), must at least exceed
        assert!(
            bwd.total_macs() > fwd.total_macs(),
            "bwd={} fwd={}",
            bwd.total_macs(),
            fwd.total_macs()
        );
    }

    #[test]
    fn measure_step_ops_transfer_fwd_exceeds_bwd() {
        let mut rng = Pcg32::seeded(93);
        let mut def = models::mbednet(&[3, 16, 16], 4);
        def.set_trainable_tail(2);
        let fp = FloatParams::init(&def, &mut rng);
        let mut xs = Vec::new();
        for _ in 0..4 {
            let mut x = TensorF32::zeros(&[3, 16, 16]);
            rng.fill_normal(x.data_mut(), 1.0);
            xs.push(x);
        }
        let calib = calibrate(&def, &fp, &xs);
        let mut m = NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
        let split = Split { xs, ys: vec![0, 1, 2, 3] };
        let (fwd, bwd) = measure_step_ops(&mut m, &split, 4, &mut Sparsity::Dense);
        // transfer learning: fwd dominates (Fig. 4b property)
        assert!(
            fwd.total_macs() > bwd.total_macs(),
            "fwd={} bwd={}",
            fwd.total_macs(),
            bwd.total_macs()
        );
    }
}
