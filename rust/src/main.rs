//! `tinytrain` CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   train     full on-device training (native or XLA backend)
//!   transfer  on-device transfer learning on a dataset stand-in
//!   plan      memory plan for a (model, dataset, config) deployment
//!   devices   print the Tab. II device inventory
//!   stream    run the streaming coordinator scenario (domain shift)

use tinytrain::coordinator::{stream::SampleStream, Coordinator, CoordinatorConfig};
use tinytrain::data::{spec_by_name, Domain};
use tinytrain::device;
use tinytrain::graph::{models, DnnConfig};
use tinytrain::harness::{self, Knobs};
use tinytrain::memplan;
use tinytrain::train::fqt::FqtSgd;
use tinytrain::train::loop_::Sparsity;
use tinytrain::util::argparse::Args;
use tinytrain::util::bench::fmt_duration;

const HELP: &str = "tinytrain — on-device FQT training (Deutel et al., TCAD'24 reproduction)

USAGE: tinytrain <command> [--options]

COMMANDS:
  train     --dataset <name> --config <uint8|mixed|float32> [--epochs N]
            [--backend native|xla] [--seed N]
  transfer  --dataset <name> --config <..> [--lambda-min F] [--epochs N]
  plan      --dataset <name> --config <..> [--model mbednet|mnist_cnn|mcunet5fps]
  devices
  stream    --dataset <name> [--samples N] [--rate HZ] [--device <name>]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{HELP}");
        return;
    };
    let args = Args::parse(&argv[1..], &["help"]).unwrap();
    let code = match cmd.as_str() {
        "train" => cmd_train(&args),
        "transfer" => cmd_transfer(&args),
        "plan" => cmd_plan(&args),
        "devices" => cmd_devices(),
        "stream" => cmd_stream(&args),
        _ => {
            print!("{HELP}");
            1
        }
    };
    std::process::exit(code);
}

fn config(args: &Args) -> DnnConfig {
    DnnConfig::parse(&args.get_or("config", "uint8")).unwrap_or(DnnConfig::Uint8)
}

fn cmd_train(args: &Args) -> i32 {
    let name = args.get_or("dataset", "emnist-digits");
    let Some(spec) = spec_by_name(&name) else {
        eprintln!("unknown dataset {name}");
        return 1;
    };
    let cfg = config(args);
    let seed = args.u64_or("seed", 1);
    let mut knobs = Knobs::from_env();
    knobs.epochs = args.usize_or("epochs", knobs.epochs);
    tinytrain::kernels::simd::set_mode(knobs.kernel);

    if args.get_or("backend", "native") == "xla" {
        // AOT HLO path (mnist-family shapes only — see python/compile).
        // Compiled only under the `pjrt` feature; the default offline build
        // reports how to enable it instead.
        #[cfg(feature = "pjrt")]
        {
            let dir = tinytrain::runtime::artifacts_dir();
            let mut t = match tinytrain::runtime::xla_trainer::load_fqt_trainer(
                &dir,
                (-2.0, 4.0),
                harness::LR,
                harness::BATCH,
                seed,
            ) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("{e:#}");
                    return 1;
                }
            };
            let dom = Domain::new(&spec, [1, 28, 28], seed);
            let mut rng = tinytrain::util::prng::Pcg32::seeded(seed);
            let (tr, te) = dom.splits(knobs.train_pc * 2, knobs.test_pc * 2, &mut rng);
            for ep in 0..knobs.epochs {
                let mut tot = 0.0;
                for (x, &y) in tr.xs.iter().zip(&tr.ys) {
                    tot += t.train_step(x, y).unwrap().0;
                }
                t.finish();
                let acc = t.evaluate(&te.xs, &te.ys).unwrap();
                println!("epoch {ep}: loss={:.4} test_acc={acc:.3}", tot / tr.len() as f32);
            }
            return 0;
        }
        #[cfg(not(feature = "pjrt"))]
        {
            eprintln!(
                "the xla backend requires the `pjrt` feature: enable the xla \
                 dependency in rust/Cargo.toml and rebuild with --features pjrt"
            );
            return 1;
        }
    }

    let (rep, _) = harness::run_full_training(&spec, cfg, &knobs, seed);
    for (i, e) in rep.epochs.iter().enumerate() {
        println!(
            "epoch {i}: loss={:.4} train_acc={:.3} test_acc={:.3}",
            e.train_loss, e.train_acc, e.test_acc
        );
    }
    0
}

fn cmd_transfer(args: &Args) -> i32 {
    let name = args.get_or("dataset", "cifar10");
    let Some(spec) = spec_by_name(&name) else {
        eprintln!("unknown dataset {name}");
        return 1;
    };
    let cfg = config(args);
    let lambda = args.f32_or("lambda-min", 1.0);
    let seed = args.u64_or("seed", 1);
    let mut knobs = Knobs::from_env();
    knobs.epochs = args.usize_or("epochs", knobs.epochs);
    tinytrain::kernels::simd::set_mode(knobs.kernel);

    let src = Domain::new(&spec, spec.reduced_shape, seed);
    let def = harness::mbednet_for(&spec, &spec.reduced_shape);
    println!("pretraining on source domain…");
    let (fp, base) = harness::pretrain(&def, &src, knobs.epochs, &knobs, seed ^ 1);
    println!("source baseline accuracy: {base:.3}");
    let mut scen = harness::tl_scenario(&spec, cfg, &fp, &src, &knobs, seed ^ 2);
    let rep = harness::run_tl(&mut scen, lambda, &knobs, seed ^ 3);
    for (i, e) in rep.epochs.iter().enumerate() {
        println!("epoch {i}: loss={:.4} test_acc={:.3}", e.train_loss, e.test_acc);
    }
    println!("kept gradient structures: {:.1}%", rep.kept_fraction * 100.0);
    0
}

fn cmd_plan(args: &Args) -> i32 {
    let name = args.get_or("dataset", "cifar10");
    let Some(spec) = spec_by_name(&name) else {
        eprintln!("unknown dataset {name}");
        return 1;
    };
    let cfg = config(args);
    let model_name = args.get_or("model", "mbednet");
    let Some(def) = models::by_name(&model_name, &spec.paper_shape, spec.classes) else {
        eprintln!("unknown model {model_name}");
        return 1;
    };
    let train_plan = memplan::plan(&def, cfg, true);
    let infer_plan = memplan::plan(&def, cfg, false);
    println!("{model_name} on {name} ({cfg:?}), paper shape {:?}:", spec.paper_shape);
    println!("  feature RAM (training):  {:>8} B", train_plan.feature_ram);
    println!("  weights+grads RAM:       {:>8} B", train_plan.weight_ram);
    println!("  total RAM (training):    {:>8} B", train_plan.total_ram());
    println!("  total RAM (inference):   {:>8} B", infer_plan.total_ram());
    println!("  Flash:                   {:>8} B", train_plan.flash);
    for d in device::all_devices() {
        let ok = d.fits(train_plan.total_ram(), train_plan.flash);
        println!("  fits {:<10} {}", d.name, if ok { "yes" } else { "NO" });
    }
    0
}

fn cmd_devices() -> i32 {
    println!(
        "{:<11} {:<11} {:>9} {:>10} {:>10} {:>8} {:>5} {:>5}",
        "name", "core", "clock", "idle (mA)", "flash", "ram", "fpu", "simd"
    );
    for d in device::all_devices() {
        println!(
            "{:<11} {:<11} {:>6} MHz {:>10.2} {:>9}K {:>7}K {:>5} {:>5}",
            d.name,
            d.core,
            (d.clock_hz / 1e6) as u64,
            d.idle_a * 1e3,
            d.flash_bytes / 1024,
            d.ram_bytes / 1024,
            d.has_fpu,
            d.has_dsp_simd
        );
    }
    0
}

fn cmd_stream(args: &Args) -> i32 {
    let name = args.get_or("dataset", "cifar10");
    let Some(mut spec) = spec_by_name(&name) else {
        eprintln!("unknown dataset {name}");
        return 1;
    };
    // shrink spatial dims so the stream demo stays interactive
    spec.reduced_shape = [
        spec.reduced_shape[0],
        spec.reduced_shape[1].min(16),
        spec.reduced_shape[2].min(16).max(8),
    ];
    let samples = args.usize_or("samples", 200);
    let rate = args.f32_or("rate", 10.0) as f64;
    let dev = device::by_name(&args.get_or("device", "imxrt1062")).unwrap_or(device::imxrt1062());
    let seed = args.u64_or("seed", 1);

    let mut rng = tinytrain::util::prng::Pcg32::seeded(seed);
    let shape = spec.reduced_shape;
    let dom = Domain::new(&spec, shape, seed);
    let def = models::mnist_cnn(&shape, spec.classes);
    let fp = tinytrain::graph::exec::FloatParams::init(&def, &mut rng);
    let (cal, _) = dom.splits(1, 0, &mut rng);
    let calib = tinytrain::graph::exec::calibrate(&def, &fp, &cal.xs);
    let model = tinytrain::graph::exec::NativeModel::build(def, DnnConfig::Uint8, &fp, &calib);
    let mut opt = FqtSgd::new(&model, harness::LR, harness::BATCH);
    let mut coord = Coordinator::builder(model, dev, &mut opt)
        .sparsity(Sparsity::Dense)
        .config(CoordinatorConfig::default())
        .seed(seed)
        .build();
    let shifted = dom.shifted(seed ^ 42);
    let mut stream =
        SampleStream::with_shift(&dom, &shifted, samples, samples / 2, 1.0 / rate, seed);
    let t = coord.run(&mut stream);
    println!("arrivals: {}  train steps: {}", t.arrivals, t.train_steps);
    println!("online accuracy: {:.3}", t.online_accuracy());
    println!(
        "utilization: {:.1}%  busy {}  elapsed {}",
        t.utilization() * 100.0,
        fmt_duration(t.busy_s),
        fmt_duration(t.elapsed_s)
    );
    println!("energy: {:.3} J", t.energy_j);
    0
}
