//! WGSL compute-shader sources for the GPU lowering of a compiled plan,
//! plus Rust scalar mirrors of their quantized arithmetic.
//!
//! Every plan step lowers to one entry point built from a shared prelude:
//!
//!  * **bindings** — `@binding(0)` is the whole per-run activation arena
//!    as one `array<u32>` (uint8 activations ride four lanes per word,
//!    float activations one bitcast word per value); `@binding(1)` holds
//!    the immutable constants (weights, quantized biases) uploaded once
//!    per plan; `@binding(2)` is a 32-word uniform with the per-step
//!    offsets and scalars (see [`slot`]). Binding the arena once with
//!    per-step offsets in the uniform sidesteps buffer-aliasing
//!    validation entirely — steps never bind overlapping sub-ranges.
//!  * **batching** — each sample occupies one arena region of
//!    [`slot::STRIDE_WORDS`] words; `global_invocation_id.y` selects the
//!    sample, `.x` the output word (uint8 shaders write one whole output
//!    word — four lanes — per invocation, so no read-modify-write races).
//!  * **numerics** — integer accumulation is exact; requantization uses
//!    [`round_half_away`], a `trunc`-based round-half-away-from-zero
//!    that is bit-identical to Rust `f32::round` for every finite input
//!    (`x - trunc(x)` is exact, so the 0.5 comparison never suffers the
//!    binade-boundary rounding of the `floor(x + 0.5)` trick). WGSL
//!    float→int conversion saturates, matching Rust `as` casts. The one
//!    caveat is the float→uint8 [`ShaderKind::Quantize`] boundary: WGSL
//!    division is only 2.5 ULP, so `x / scale` may differ from the
//!    host's correctly-rounded division — no shipping configuration
//!    produces that crossing (see `graph::plan::folds_dequant` docs),
//!    and the cross-validation grid never schedules it. Float layers are
//!    tolerance-tiered (WGSL may contract `a * b + c` to fma).
//!
//! The sources are plain strings: they compile — and their arithmetic is
//! unit-tested against [`crate::quant`]'s scalar formulas via the mirror
//! functions below — in the default dependency-free build. Only the
//! device plumbing (`backend::gpu`) needs the `wgpu` crate.

/// Number of `u32` words in the per-step uniform parameter block.
pub const PARAM_WORDS: usize = 32;

/// Invocations per workgroup along `x` (output words / elements).
pub const WORKGROUP_SIZE: u32 = 64;

/// Uniform-word indices of the per-step parameter block. One layout is
/// shared by every shader; unused slots stay zero. Integer-valued slots
/// are stored as the bit pattern of the `i32`/`u32`; float-valued slots
/// (`MULT`) as `f32::to_bits`.
pub mod slot {
    /// Input slot offset within a sample's arena region, in words.
    pub const IN_OFF: usize = 0;
    /// Output slot offset within a sample's arena region, in words.
    pub const OUT_OFF: usize = 1;
    /// Per-sample arena region stride, in words.
    pub const STRIDE_WORDS: usize = 2;
    /// Batch capacity the arena was sized for.
    pub const BATCH: usize = 3;
    /// Weight base offset into the constants buffer, in words.
    pub const W_OFF: usize = 4;
    /// Bias base offset into the constants buffer, in words.
    pub const B_OFF: usize = 5;
    /// Conv: input channels per filter (1 if depthwise). Linear: `n_in`.
    pub const CIN_PF: usize = 6;
    /// Linear alias of [`CIN_PF`].
    pub const N_IN: usize = 6;
    /// Conv kernel height; pool window height.
    pub const KH: usize = 8;
    /// Conv kernel width; pool window width.
    pub const KW: usize = 9;
    /// Conv stride.
    pub const CONV_STRIDE: usize = 10;
    /// Conv vertical padding (as `i32`).
    pub const PAD_H: usize = 11;
    /// Conv horizontal padding (as `i32`).
    pub const PAD_W: usize = 12;
    /// Conv: 1 if depthwise, 0 otherwise.
    pub const DEPTHWISE: usize = 13;
    /// Input spatial height.
    pub const IH: usize = 14;
    /// Input spatial width.
    pub const IW: usize = 15;
    /// Output spatial height.
    pub const OH: usize = 16;
    /// Output spatial width.
    pub const OW: usize = 17;
    /// Input zero point (`i32`); quantize/dequantize boundary zero point.
    pub const ZX: usize = 18;
    /// Weight zero point (`i32`).
    pub const ZW: usize = 19;
    /// Output zero point (`i32`).
    pub const Z_OUT: usize = 20;
    /// 1 to fold ReLU into the epilogue, 0 otherwise.
    pub const RELU: usize = 21;
    /// Requantization multiplier (`f32` bits); boundary scale for
    /// quantize/dequantize.
    pub const MULT: usize = 22;
    /// Number of output elements per sample.
    pub const OUT_ELEMS: usize = 23;
}

/// One compute shader per plan-step kind (see
/// [`crate::graph::plan::StepDesc`]; `Flatten` lowers to no dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShaderKind {
    /// Quantized convolution (dense or depthwise), requantizing epilogue.
    QConv,
    /// Float convolution.
    FConv,
    /// Quantized fully-connected layer, requantizing epilogue.
    QLinear,
    /// Float fully-connected layer.
    FLinear,
    /// Non-overlapping uint8 max pool.
    QMaxPool,
    /// Non-overlapping float max pool.
    FMaxPool,
    /// Uint8 global average pool (requantizing, Eq. 4 multiplier).
    QGap,
    /// Float global average pool.
    FGap,
    /// Float → uint8 precision boundary (see the division caveat above).
    Quantize,
    /// Uint8 → float precision boundary (exact).
    Dequantize,
}

/// Every shader kind, for exhaustive tests and pipeline warm-up.
pub const ALL_KINDS: [ShaderKind; 10] = [
    ShaderKind::QConv,
    ShaderKind::FConv,
    ShaderKind::QLinear,
    ShaderKind::FLinear,
    ShaderKind::QMaxPool,
    ShaderKind::FMaxPool,
    ShaderKind::QGap,
    ShaderKind::FGap,
    ShaderKind::Quantize,
    ShaderKind::Dequantize,
];

impl ShaderKind {
    /// Stable label used for pipeline/debug names and perf rows.
    pub fn name(&self) -> &'static str {
        match self {
            ShaderKind::QConv => "qconv",
            ShaderKind::FConv => "fconv",
            ShaderKind::QLinear => "qlinear",
            ShaderKind::FLinear => "flinear",
            ShaderKind::QMaxPool => "qmaxpool",
            ShaderKind::FMaxPool => "fmaxpool",
            ShaderKind::QGap => "qgap",
            ShaderKind::FGap => "fgap",
            ShaderKind::Quantize => "quantize",
            ShaderKind::Dequantize => "dequantize",
        }
    }
}

/// Shared prelude: bindings, uniform accessors, lane helpers, and the
/// requantization arithmetic every quantized epilogue funnels through.
const PRELUDE: &str = r#"
struct Params {
    v: array<vec4<u32>, 8>,
}

@group(0) @binding(0) var<storage, read_write> arena: array<u32>;
@group(0) @binding(1) var<storage, read> consts: array<u32>;
@group(0) @binding(2) var<uniform> p: Params;

fn pu(i: u32) -> u32 {
    return p.v[i / 4u][i % 4u];
}

fn pi(i: u32) -> i32 {
    return bitcast<i32>(pu(i));
}

fn pf(i: u32) -> f32 {
    return bitcast<f32>(pu(i));
}

fn arena_u8(base_w: u32, idx: u32) -> u32 {
    return (arena[base_w + idx / 4u] >> (8u * (idx % 4u))) & 0xFFu;
}

fn arena_f32(base_w: u32, idx: u32) -> f32 {
    return bitcast<f32>(arena[base_w + idx]);
}

fn const_u8(base_w: u32, idx: u32) -> u32 {
    return (consts[base_w + idx / 4u] >> (8u * (idx % 4u))) & 0xFFu;
}

fn const_i32(base_w: u32, idx: u32) -> i32 {
    return bitcast<i32>(consts[base_w + idx]);
}

fn const_f32(base_w: u32, idx: u32) -> f32 {
    return bitcast<f32>(consts[base_w + idx]);
}

// Round half away from zero, bit-identical to Rust f32::round for every
// finite x: x - trunc(x) is exact (Sterbenz), so the 0.5 comparison is
// decided on the true fraction. sign(x) is never taken at x == 0 inside
// the branch (|frac| >= 0.5 implies x != 0).
fn round_half_away(x: f32) -> f32 {
    let t = trunc(x);
    let fr = x - t;
    if abs(fr) >= 0.5 {
        return t + sign(x);
    }
    return t;
}

// Mirror of quant::requantize: f32->i32 conversion saturates in WGSL,
// matching Rust `as` casts.
fn requantize_q(acc: i32, mult: f32, z_out: i32, relu: u32) -> u32 {
    let v = i32(round_half_away(f32(acc) * mult)) + z_out;
    var lo = 0;
    if relu != 0u {
        lo = clamp(z_out, 0, 255);
    }
    return u32(clamp(v, lo, 255));
}
"#;

const QCONV: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    let out_words = (out_elems + 3u) / 4u;
    if gid.x >= out_words || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let w_off = pu(4u);
    let b_off = pu(5u);
    let cin_pf = pu(6u);
    let kh = pu(8u);
    let kw = pu(9u);
    let sv = pu(10u);
    let pad_h = pi(11u);
    let pad_w = pi(12u);
    let dw = pu(13u);
    let ih = pu(14u);
    let iw = pu(15u);
    let oh = pu(16u);
    let ow = pu(17u);
    let zx = pi(18u);
    let zw = pi(19u);
    var out_word = 0u;
    for (var lane = 0u; lane < 4u; lane = lane + 1u) {
        let idx = gid.x * 4u + lane;
        if idx >= out_elems {
            break;
        }
        let co = idx / (oh * ow);
        let oy = (idx / ow) % oh;
        let ox = idx % ow;
        var acc = const_i32(b_off, co);
        for (var cf = 0u; cf < cin_pf; cf = cf + 1u) {
            var ci = cf;
            if dw != 0u {
                ci = co;
            }
            for (var ky = 0u; ky < kh; ky = ky + 1u) {
                let iy = i32(oy * sv + ky) - pad_h;
                if iy < 0 || iy >= i32(ih) {
                    continue;
                }
                for (var kx = 0u; kx < kw; kx = kx + 1u) {
                    let ix = i32(ox * sv + kx) - pad_w;
                    if ix < 0 || ix >= i32(iw) {
                        continue;
                    }
                    let x_idx = (ci * ih + u32(iy)) * iw + u32(ix);
                    let w_idx = ((co * cin_pf + cf) * kh + ky) * kw + kx;
                    let xv = i32(arena_u8(in_base, x_idx)) - zx;
                    let wv = i32(const_u8(w_off, w_idx)) - zw;
                    acc = acc + xv * wv;
                }
            }
        }
        out_word = out_word | (requantize_q(acc, pf(22u), pi(20u), pu(21u)) << (8u * lane));
    }
    arena[out_base + gid.x] = out_word;
}
"#;

const FCONV: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    if gid.x >= out_elems || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let w_off = pu(4u);
    let b_off = pu(5u);
    let cin_pf = pu(6u);
    let kh = pu(8u);
    let kw = pu(9u);
    let sv = pu(10u);
    let pad_h = pi(11u);
    let pad_w = pi(12u);
    let dw = pu(13u);
    let ih = pu(14u);
    let iw = pu(15u);
    let oh = pu(16u);
    let ow = pu(17u);
    let idx = gid.x;
    let co = idx / (oh * ow);
    let oy = (idx / ow) % oh;
    let ox = idx % ow;
    var acc = const_f32(b_off, co);
    for (var cf = 0u; cf < cin_pf; cf = cf + 1u) {
        var ci = cf;
        if dw != 0u {
            ci = co;
        }
        for (var ky = 0u; ky < kh; ky = ky + 1u) {
            let iy = i32(oy * sv + ky) - pad_h;
            if iy < 0 || iy >= i32(ih) {
                continue;
            }
            for (var kx = 0u; kx < kw; kx = kx + 1u) {
                let ix = i32(ox * sv + kx) - pad_w;
                if ix < 0 || ix >= i32(iw) {
                    continue;
                }
                let x_idx = (ci * ih + u32(iy)) * iw + u32(ix);
                let w_idx = ((co * cin_pf + cf) * kh + ky) * kw + kx;
                acc = acc + arena_f32(in_base, x_idx) * const_f32(w_off, w_idx);
            }
        }
    }
    if pu(21u) != 0u {
        acc = max(acc, 0.0);
    }
    arena[out_base + idx] = bitcast<u32>(acc);
}
"#;

const QLINEAR: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    let out_words = (out_elems + 3u) / 4u;
    if gid.x >= out_words || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let w_off = pu(4u);
    let b_off = pu(5u);
    let n_in = pu(6u);
    let zx = pi(18u);
    let zw = pi(19u);
    var out_word = 0u;
    for (var lane = 0u; lane < 4u; lane = lane + 1u) {
        let o = gid.x * 4u + lane;
        if o >= out_elems {
            break;
        }
        var acc = const_i32(b_off, o);
        for (var j = 0u; j < n_in; j = j + 1u) {
            let xv = i32(arena_u8(in_base, j)) - zx;
            let wv = i32(const_u8(w_off, o * n_in + j)) - zw;
            acc = acc + xv * wv;
        }
        out_word = out_word | (requantize_q(acc, pf(22u), pi(20u), pu(21u)) << (8u * lane));
    }
    arena[out_base + gid.x] = out_word;
}
"#;

const FLINEAR: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    if gid.x >= out_elems || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let w_off = pu(4u);
    let b_off = pu(5u);
    let n_in = pu(6u);
    let o = gid.x;
    var acc = const_f32(b_off, o);
    for (var j = 0u; j < n_in; j = j + 1u) {
        acc = acc + arena_f32(in_base, j) * const_f32(w_off, o * n_in + j);
    }
    if pu(21u) != 0u {
        acc = max(acc, 0.0);
    }
    arena[out_base + o] = bitcast<u32>(acc);
}
"#;

const QMAXPOOL: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    let out_words = (out_elems + 3u) / 4u;
    if gid.x >= out_words || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let kh = pu(8u);
    let kw = pu(9u);
    let ih = pu(14u);
    let iw = pu(15u);
    let oh = pu(16u);
    let ow = pu(17u);
    var out_word = 0u;
    for (var lane = 0u; lane < 4u; lane = lane + 1u) {
        let idx = gid.x * 4u + lane;
        if idx >= out_elems {
            break;
        }
        let c = idx / (oh * ow);
        let oy = (idx / ow) % oh;
        let ox = idx % ow;
        var m = 0u;
        for (var ky = 0u; ky < kh; ky = ky + 1u) {
            for (var kx = 0u; kx < kw; kx = kx + 1u) {
                let x_idx = (c * ih + (oy * kh + ky)) * iw + (ox * kw + kx);
                m = max(m, arena_u8(in_base, x_idx));
            }
        }
        out_word = out_word | (m << (8u * lane));
    }
    arena[out_base + gid.x] = out_word;
}
"#;

const FMAXPOOL: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    if gid.x >= out_elems || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let kh = pu(8u);
    let kw = pu(9u);
    let ih = pu(14u);
    let iw = pu(15u);
    let oh = pu(16u);
    let ow = pu(17u);
    let idx = gid.x;
    let c = idx / (oh * ow);
    let oy = (idx / ow) % oh;
    let ox = idx % ow;
    var m = arena_f32(in_base, (c * ih + oy * kh) * iw + ox * kw);
    for (var ky = 0u; ky < kh; ky = ky + 1u) {
        for (var kx = 0u; kx < kw; kx = kx + 1u) {
            let x_idx = (c * ih + (oy * kh + ky)) * iw + (ox * kw + kx);
            m = max(m, arena_f32(in_base, x_idx));
        }
    }
    arena[out_base + idx] = bitcast<u32>(m);
}
"#;

const QGAP: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    let out_words = (out_elems + 3u) / 4u;
    if gid.x >= out_words || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let hw = pu(14u) * pu(15u);
    let zx = pi(18u);
    var out_word = 0u;
    for (var lane = 0u; lane < 4u; lane = lane + 1u) {
        let c = gid.x * 4u + lane;
        if c >= out_elems {
            break;
        }
        var acc = 0;
        for (var j = 0u; j < hw; j = j + 1u) {
            acc = acc + i32(arena_u8(in_base, c * hw + j)) - zx;
        }
        out_word = out_word | (requantize_q(acc, pf(22u), pi(20u), 0u) << (8u * lane));
    }
    arena[out_base + gid.x] = out_word;
}
"#;

const FGAP: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    if gid.x >= out_elems || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let hw = pu(14u) * pu(15u);
    let c = gid.x;
    var acc = 0.0;
    for (var j = 0u; j < hw; j = j + 1u) {
        acc = acc + arena_f32(in_base, c * hw + j);
    }
    arena[out_base + c] = bitcast<u32>(acc / f32(hw));
}
"#;

const QUANTIZE: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    let out_words = (out_elems + 3u) / 4u;
    if gid.x >= out_words || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let zp = pi(18u);
    let scale = pf(22u);
    var out_word = 0u;
    for (var lane = 0u; lane < 4u; lane = lane + 1u) {
        let idx = gid.x * 4u + lane;
        if idx >= out_elems {
            break;
        }
        let q = clamp(i32(round_half_away(arena_f32(in_base, idx) / scale)) + zp, 0, 255);
        out_word = out_word | (u32(q) << (8u * lane));
    }
    arena[out_base + gid.x] = out_word;
}
"#;

const DEQUANTIZE: &str = r#"
@compute @workgroup_size(64)
fn main(@builtin(global_invocation_id) gid: vec3<u32>) {
    let out_elems = pu(23u);
    if gid.x >= out_elems || gid.y >= pu(3u) {
        return;
    }
    let in_base = pu(0u) + gid.y * pu(2u);
    let out_base = pu(1u) + gid.y * pu(2u);
    let zp = pi(18u);
    let scale = pf(22u);
    let q = i32(arena_u8(in_base, gid.x));
    arena[out_base + gid.x] = bitcast<u32>(f32(q - zp) * scale);
}
"#;

/// The full WGSL source (prelude + entry point) for one shader kind.
pub fn source(kind: ShaderKind) -> String {
    let body = match kind {
        ShaderKind::QConv => QCONV,
        ShaderKind::FConv => FCONV,
        ShaderKind::QLinear => QLINEAR,
        ShaderKind::FLinear => FLINEAR,
        ShaderKind::QMaxPool => QMAXPOOL,
        ShaderKind::FMaxPool => FMAXPOOL,
        ShaderKind::QGap => QGAP,
        ShaderKind::FGap => FGAP,
        ShaderKind::Quantize => QUANTIZE,
        ShaderKind::Dequantize => DEQUANTIZE,
    };
    format!("{PRELUDE}{body}")
}

/// Rust mirror of the WGSL `round_half_away`: round half away from zero
/// via the exact fraction `x - trunc(x)`. Bit-identical to `f32::round`
/// for every finite input (and agreeing on ±inf/NaN propagation), unlike
/// the `floor(|x| + 0.5)` formulation, which misrounds just below
/// odd-multiple-of-0.5 binade boundaries where `|x| + 0.5` ties to even.
pub fn round_half_away(x: f32) -> f32 {
    let t = x.trunc();
    let fr = x - t;
    if fr.abs() >= 0.5 {
        // x != 0 here, so signum is ±1 exactly like WGSL sign().
        t + x.signum()
    } else {
        t
    }
}

/// Rust mirror of the WGSL `requantize_q` epilogue. Must stay value-equal
/// to [`crate::quant::requantize`] — the unit tests below pin it.
pub fn requantize_mirror(acc: i32, mult: f32, z_out: i32, relu: bool) -> u8 {
    let v = round_half_away(acc as f32 * mult) as i32 + z_out;
    let lo = if relu { z_out.clamp(0, 255) } else { 0 };
    v.clamp(lo, 255) as u8
}

/// Rust mirror of the WGSL `Quantize` boundary body (host-side division;
/// the WGSL division itself is 2.5 ULP — see the module caveat).
pub fn quantize_mirror(v: f32, scale: f32, zero_point: i32) -> u8 {
    (round_half_away(v / scale) as i32 + zero_point).clamp(0, 255) as u8
}

/// Rust mirror of the WGSL `Dequantize` boundary body (exact).
pub fn dequantize_mirror(q: u8, scale: f32, zero_point: i32) -> f32 {
    (q as i32 - zero_point) as f32 * scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{requantize, QParams};
    use crate::util::prng::Pcg32;

    #[test]
    fn round_half_away_matches_f32_round() {
        // Adversarial set: exact halves, values one ULP below a half at a
        // binade boundary (where floor(|x| + 0.5) misrounds), huge values
        // past integer precision, signed zeros.
        let mut cases = vec![
            0.0f32, -0.0, 0.25, -0.25, 0.5, -0.5, 0.75, 1.5, -1.5, 2.5, -2.5, 126.5, 127.5,
            -127.5, 8388607.5_f32, 1e10, -1e10, 3.4e38,
        ];
        for base in [0.5f32, 1.5, 127.5, 255.5, 8191.5] {
            cases.push(f32::from_bits(base.to_bits() - 1));
            cases.push(-f32::from_bits(base.to_bits() - 1));
            cases.push(f32::from_bits(base.to_bits() + 1));
        }
        let mut rng = Pcg32::seeded(0xF00D);
        for _ in 0..200_000 {
            cases.push(rng.uniform(-1e6, 1e6));
            cases.push(rng.uniform(-2.0, 2.0));
        }
        for x in cases {
            assert_eq!(
                round_half_away(x).to_bits(),
                x.round().to_bits(),
                "x = {x} ({:#010x})",
                x.to_bits()
            );
        }
    }

    #[test]
    fn wgsl_requantize_matches_scalar_formula() {
        // The shader epilogue must agree with quant::requantize (Eq. 4)
        // over accumulator sweeps, both relu modes, and multiplier signs
        // that exercise rounding, clamping, and the relu floor.
        let mults = [0.0173f32, 0.5, 1.0, 0.001, 3.7, 1.0 / 3.0];
        let zs = [0i32, 13, 128, 255];
        for &mult in &mults {
            for &z in &zs {
                for relu in [false, true] {
                    for acc in -70_000..70_000 {
                        assert_eq!(
                            requantize_mirror(acc, mult, z, relu),
                            requantize(acc, mult, z, relu),
                            "acc={acc} mult={mult} z={z} relu={relu}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn wgsl_quantize_dequantize_match_qparams() {
        let qp = QParams { scale: 0.0173, zero_point: 77 };
        let mut rng = Pcg32::seeded(9);
        for _ in 0..100_000 {
            let v = rng.uniform(-4.0, 4.0);
            assert_eq!(quantize_mirror(v, qp.scale, qp.zero_point), qp.quantize(v), "v = {v}");
        }
        for q in 0..=255u8 {
            assert_eq!(
                dequantize_mirror(q, qp.scale, qp.zero_point).to_bits(),
                qp.dequantize(q).to_bits()
            );
        }
    }

    #[test]
    fn shader_sources_are_well_formed() {
        for kind in ALL_KINDS {
            let src = source(kind);
            assert!(src.contains("@compute @workgroup_size(64)"), "{kind:?}");
            assert!(src.contains("fn main(@builtin(global_invocation_id)"), "{kind:?}");
            assert!(src.contains("var<storage, read_write> arena"), "{kind:?}");
            assert!(src.contains("var<uniform> p: Params"), "{kind:?}");
            let open = src.matches('{').count();
            let close = src.matches('}').count();
            assert_eq!(open, close, "unbalanced braces in {kind:?}");
            assert!(!kind.name().is_empty());
        }
    }
}
