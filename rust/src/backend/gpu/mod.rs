//! wgpu device plumbing for the WGSL backend (feature `gpu`).
//!
//! [`GpuContext::try_new`] acquires an adapter + device, preferring a
//! hardware adapter and falling back to a software one (Mesa lavapipe on
//! the CI runners); it returns `None` — never panics — when no adapter
//! initializes, which is what lets `tests/gpu_cross_validation.rs`
//! clean-skip on machines without any Vulkan/GL stack.
//!
//! The crate is dependency-minimal by policy, so the async plumbing wgpu
//! exposes is driven by a hand-rolled no-op-waker [`block_on`] (the
//! futures here complete via `device.poll`, not a reactor) instead of
//! pulling in an executor crate.

pub mod plan;

pub use plan::{GpuAct, GpuPlan};

use std::future::Future;
use std::pin::Pin;
use std::sync::mpsc;
use std::task::{Context, Poll, RawWaker, RawWakerVTable, Waker};

fn noop_raw_waker() -> RawWaker {
    fn clone(_: *const ()) -> RawWaker {
        noop_raw_waker()
    }
    fn noop(_: *const ()) {}
    static VTABLE: RawWakerVTable = RawWakerVTable::new(clone, noop, noop, noop);
    RawWaker::new(std::ptr::null(), &VTABLE)
}

/// Drive a wgpu future to completion on the current thread. The adapter /
/// device / map futures used here make progress from wgpu's own internals
/// (or `device.poll`), so a spin-with-yield loop with a no-op waker is
/// sufficient and keeps the build free of executor dependencies.
pub fn block_on<F: Future>(mut fut: F) -> F::Output {
    let waker = unsafe { Waker::from_raw(noop_raw_waker()) };
    let mut cx = Context::from_waker(&waker);
    // SAFETY: `fut` lives on this stack frame for the whole loop and is
    // never moved after being pinned.
    let mut fut = unsafe { Pin::new_unchecked(&mut fut) };
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(v) => return v,
            Poll::Pending => std::thread::yield_now(),
        }
    }
}

/// An acquired wgpu device + queue, shared by every [`GpuPlan`].
pub struct GpuContext {
    pub device: wgpu::Device,
    pub queue: wgpu::Queue,
    /// Human-readable adapter description for logs and perf rows.
    pub adapter_info: String,
}

impl GpuContext {
    /// Acquire an adapter and device, or `None` if no usable adapter
    /// exists (headless runner without a software driver installed).
    pub fn try_new() -> Option<GpuContext> {
        let instance = wgpu::Instance::new(wgpu::InstanceDescriptor {
            backends: wgpu::Backends::all(),
            ..Default::default()
        });
        let adapter = block_on(instance.request_adapter(&wgpu::RequestAdapterOptions {
            power_preference: wgpu::PowerPreference::HighPerformance,
            force_fallback_adapter: false,
            compatible_surface: None,
        }))
        .or_else(|| {
            // Explicitly ask for the software fallback (lavapipe).
            block_on(instance.request_adapter(&wgpu::RequestAdapterOptions {
                power_preference: wgpu::PowerPreference::LowPower,
                force_fallback_adapter: true,
                compatible_surface: None,
            }))
        })?;
        let info = adapter.get_info();
        let (device, queue) = block_on(adapter.request_device(
            &wgpu::DeviceDescriptor {
                label: Some("tinytrain-gpu"),
                required_features: wgpu::Features::empty(),
                required_limits: wgpu::Limits::downlevel_defaults(),
                memory_hints: wgpu::MemoryHints::default(),
            },
            None,
        ))
        .ok()?;
        let adapter_info = format!("{} ({:?})", info.name, info.backend);
        Some(GpuContext { device, queue, adapter_info })
    }

    /// Copy `words` u32 words out of `src` (which must carry `COPY_SRC`)
    /// through a fresh staging buffer and map them back to the host.
    pub fn read_words(&self, src: &wgpu::Buffer, words: usize) -> Vec<u32> {
        let bytes = (words.max(1) * 4) as u64;
        let staging = self.device.create_buffer(&wgpu::BufferDescriptor {
            label: Some("tt-readback"),
            size: bytes,
            usage: wgpu::BufferUsages::COPY_DST | wgpu::BufferUsages::MAP_READ,
            mapped_at_creation: false,
        });
        let mut enc = self
            .device
            .create_command_encoder(&wgpu::CommandEncoderDescriptor { label: Some("tt-read") });
        enc.copy_buffer_to_buffer(src, 0, &staging, 0, bytes);
        self.queue.submit([enc.finish()]);
        self.map_and_read(&staging, words)
    }

    /// Map an already-populated `MAP_READ` buffer and decode `words` u32
    /// words (little-endian, the WebGPU buffer byte order).
    pub fn map_and_read(&self, staging: &wgpu::Buffer, words: usize) -> Vec<u32> {
        let slice = staging.slice(..);
        let (tx, rx) = mpsc::channel();
        slice.map_async(wgpu::MapMode::Read, move |r| {
            let _ = tx.send(r);
        });
        let _ = self.device.poll(wgpu::Maintain::Wait);
        rx.recv().expect("map_async dropped its callback").expect("buffer map failed");
        let out = {
            let data = slice.get_mapped_range();
            data.chunks_exact(4)
                .take(words)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        };
        staging.unmap();
        out
    }
}
