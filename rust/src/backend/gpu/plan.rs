//! `GpuPlan`: lower a compiled [`ExecPlan`] onto WGSL compute pipelines.
//!
//! The lowering walks the plan's backend-neutral step descriptions
//! ([`StepDesc`], recorded by the same compile loop that boxes the CPU
//! ops) and emits one dispatch per step — `Flatten` lowers to nothing,
//! exactly like the CPU's zero-copy view. Scope is batched **forward
//! inference of the unfused schedule**: the unfused op sequence is the
//! repository-wide bit-parity oracle (`TT_NO_FUSE=1` CI leg), and by the
//! plan-parity contract its activations are bit-identical to the fused
//! executor's, so validating against it validates against both.
//!
//! **Device memory mirrors the plan's liveness accounting.** The same
//! [`crate::memplan::allocate_arena`] pass that gives the CPU plan its
//! `planned_peak_bytes` places the inference-mode arena items (word-
//! aligned via [`crate::memplan::align_up`], which keeps every placed
//! offset word-aligned) into one reused arena buffer region per sample:
//! the whole batch lives in a single `array<u32>` storage binding of
//! `batch × arena_bytes_per_sample` bytes, and per-step offsets ride in
//! each dispatch's uniform block. No buffer aliasing, no re-binding, and
//! the buffer-pool footprint is the liveness answer, not the sum of
//! activation sizes.
//!
//! **Numerics contract** (pinned by `tests/gpu_cross_validation.rs`):
//! uint8/i32 steps are bit-exact against the scalar oracle — integer
//! accumulation is exact in both places and the requantization epilogue
//! is provably identical to [`crate::quant::requantize`] (see
//! [`crate::backend::wgsl`]); float steps are tolerance-tiered like the
//! XLA suite because WGSL may contract multiply-adds to fma. Quantized
//! biases, requantization multipliers, and the input quantization are
//! computed host-side by the *same* `quant` functions the CPU kernels
//! call, so every scale/zero-point constant reaching the shaders is
//! bit-identical to what the CPU path uses.

use std::collections::HashMap;

use crate::backend::gpu::GpuContext;
use crate::backend::wgsl::{self, slot, ShaderKind};
use crate::graph::act::LayerParams;
use crate::graph::exec::NativeModel;
use crate::graph::ops::QpSlot;
use crate::graph::plan::{arena_items_with, ExecPlan, StepDesc};
use crate::graph::Precision;
use crate::memplan::{align_up, allocate_arena};
use crate::quant::{quantize_bias, requant_multiplier, QParams, QTensor};
use crate::tensor::TensorF32;

/// One lowered plan step: which pipeline to run, its pre-composed uniform
/// block, and how many x-invocations it needs per sample.
struct Dispatch {
    kind: ShaderKind,
    params: [u32; wgsl::PARAM_WORDS],
    /// Invocations along x per sample: output *words* for uint8-writing
    /// shaders (four lanes per invocation), output elements for float.
    x_threads: u32,
    /// Layers whose activations live in the arena right after this
    /// dispatch (the producing layer, plus any `Flatten` aliasing it) —
    /// the capture points of [`GpuPlan::forward_batch_captured`].
    capture_layers: Vec<usize>,
}

/// Where one layer's output activation lives within a sample's region.
#[derive(Clone, Copy)]
struct LayerSlot {
    word_off: usize,
    elems: usize,
    prec: Precision,
    qp: QParams,
}

/// One activation read back from the device.
#[derive(Clone, Debug)]
pub enum GpuAct {
    /// Quantized bytes plus their quantization parameters.
    Q(Vec<u8>, QParams),
    /// Float values.
    F(Vec<f32>),
}

impl GpuAct {
    /// Dequantized copy, mirroring `Act::to_float` (same
    /// [`QParams::dequantize`] per value — bit-identical).
    pub fn to_float(&self) -> Vec<f32> {
        match self {
            GpuAct::Q(v, qp) => v.iter().map(|&q| qp.dequantize(q)).collect(),
            GpuAct::F(v) => v.clone(),
        }
    }
}

/// A compiled model lowered onto GPU compute pipelines (see the module
/// docs for scope and contracts).
pub struct GpuPlan {
    pipelines: HashMap<ShaderKind, wgpu::ComputePipeline>,
    dispatches: Vec<Dispatch>,
    bind_groups: Vec<wgpu::BindGroup>,
    arena: wgpu::Buffer,
    layer_slots: Vec<LayerSlot>,
    /// Copy-point index per layer for captured forwards.
    layer_copy: Vec<usize>,
    n_copies: usize,
    input: LayerSlot,
    stride_words: usize,
    max_batch: usize,
    slot_bytes_total: usize,
}

fn push_u8(consts: &mut Vec<u32>, bytes: &[u8]) -> u32 {
    let off = consts.len() as u32;
    for c in bytes.chunks(4) {
        let mut w = [0u8; 4];
        w[..c.len()].copy_from_slice(c);
        consts.push(u32::from_le_bytes(w));
    }
    off
}

fn push_f32(consts: &mut Vec<u32>, vals: &[f32]) -> u32 {
    let off = consts.len() as u32;
    consts.extend(vals.iter().map(|v| v.to_bits()));
    off
}

fn push_i32(consts: &mut Vec<u32>, vals: &[i32]) -> u32 {
    let off = consts.len() as u32;
    consts.extend(vals.iter().map(|v| *v as u32));
    off
}

/// Quantized weights + float bias of a layer, unpacking sub-byte storage
/// host-side (bit-identical lanes, see `quant::subbyte`).
fn q_params_of(lp: &LayerParams) -> (QTensor, Vec<f32>) {
    match lp {
        LayerParams::Q { w, bias } => (w.clone(), bias.clone()),
        LayerParams::Qp { w, bias } => (w.to_qtensor(), bias.clone()),
        other => panic!("quantized step over non-quantized params {other:?}"),
    }
}

fn f_params_of(lp: &LayerParams) -> (&TensorF32, &[f32]) {
    match lp {
        LayerParams::F { w, bias } => (w, bias),
        other => panic!("float step over non-float params {other:?}"),
    }
}

fn upload_words(
    device: &wgpu::Device,
    label: &str,
    words: &[u32],
    usage: wgpu::BufferUsages,
) -> wgpu::Buffer {
    let buf = device.create_buffer(&wgpu::BufferDescriptor {
        label: Some(label),
        size: (words.len().max(1) * 4) as u64,
        usage,
        mapped_at_creation: true,
    });
    {
        let mut view = buf.slice(..).get_mapped_range_mut();
        for (i, w) in words.iter().enumerate() {
            view[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
        }
    }
    buf.unmap();
    buf
}

fn read_slot(region: &[u32], sample: usize, stride_words: usize, s: &LayerSlot) -> GpuAct {
    let base = sample * stride_words + s.word_off;
    match s.prec {
        Precision::Uint8 => {
            let mut v = Vec::with_capacity(s.elems);
            for i in 0..s.elems {
                v.push(((region[base + i / 4] >> (8 * (i % 4))) & 0xFF) as u8);
            }
            GpuAct::Q(v, s.qp)
        }
        Precision::Float32 => {
            GpuAct::F(region[base..base + s.elems].iter().map(|w| f32::from_bits(*w)).collect())
        }
    }
}

impl GpuPlan {
    /// Lower `model`'s compiled plan for batches of up to `max_batch`
    /// samples. The model must be built **unfused** (see the module docs);
    /// weights and quantization parameters are snapshotted at build.
    pub fn new(ctx: &GpuContext, model: &NativeModel, max_batch: usize) -> GpuPlan {
        assert!(max_batch >= 1, "max_batch must be at least 1");
        let plan: &ExecPlan = model.plan();
        assert!(
            !plan.fused(),
            "GpuPlan lowers the unfused oracle schedule; build with fusion off"
        );
        let def = &model.shared.def;
        let prec = &model.shared.prec;
        let act_qp = &model.state.act_qp;
        let shapes = def.shapes();
        let n = def.layers.len();

        // Liveness-planned per-sample arena: same placement pass as the
        // CPU plan, over the inference-mode items, word-aligned so every
        // offset stays word-aligned. `fused: true` drops the i32 strips
        // the unfused *CPU* path stages through registers here.
        let mut items = arena_items_with(def, model.shared.cfg, false, true);
        for it in &mut items {
            it.bytes = align_up(it.bytes, 4);
        }
        let slot_bytes_total: usize = items.iter().map(|it| it.bytes).sum();
        let placement = allocate_arena(items);
        let stride_words = placement.total_bytes / 4;
        let word_off: HashMap<String, usize> =
            placement.items.iter().map(|(it, off)| (it.name.clone(), off / 4)).collect();
        let off = |name: &str| -> usize {
            *word_off.get(name).unwrap_or_else(|| panic!("missing arena slot {name}"))
        };

        let resolve = |s: QpSlot| -> QParams {
            match s {
                QpSlot::Input => model.shared.input_qp,
                QpSlot::Layer(j) => act_qp[j],
            }
        };
        let base = |in_off: usize, out_off: usize| -> [u32; wgsl::PARAM_WORDS] {
            let mut p = [0u32; wgsl::PARAM_WORDS];
            p[slot::IN_OFF] = in_off as u32;
            p[slot::OUT_OFF] = out_off as u32;
            p[slot::STRIDE_WORDS] = stride_words as u32;
            p[slot::BATCH] = max_batch as u32;
            p
        };

        let input_elems: usize = def.input_shape.iter().product();
        let input = LayerSlot {
            word_off: off("input"),
            elems: input_elems,
            prec: prec[0],
            qp: model.shared.input_qp,
        };
        let mut cur = input;

        let mut consts: Vec<u32> = Vec::new();
        let mut dispatches: Vec<Dispatch> = Vec::new();
        let mut layer_slots: Vec<Option<LayerSlot>> = vec![None; n];

        for step in plan.steps() {
            match step {
                StepDesc::Quantize { layer, qp } => {
                    let q = resolve(*qp);
                    let out_off = off(&format!("stage{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::ZX] = q.zero_point as u32;
                    p[slot::MULT] = q.scale.to_bits();
                    p[slot::OUT_ELEMS] = cur.elems as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::Quantize,
                        params: p,
                        x_threads: cur.elems.div_ceil(4) as u32,
                        capture_layers: Vec::new(),
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: cur.elems,
                        prec: Precision::Uint8,
                        qp: q,
                    };
                }
                StepDesc::Dequantize { layer } => {
                    let out_off = off(&format!("stage{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::ZX] = cur.qp.zero_point as u32;
                    p[slot::MULT] = cur.qp.scale.to_bits();
                    p[slot::OUT_ELEMS] = cur.elems as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::Dequantize,
                        params: p,
                        x_threads: cur.elems as u32,
                        capture_layers: Vec::new(),
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: cur.elems,
                        prec: Precision::Float32,
                        qp: cur.qp,
                    };
                }
                StepDesc::QConv { layer, geom, relu, in_qp, in_h, in_w, .. } => {
                    let in_q = resolve(*in_qp);
                    let out_q = act_qp[*layer];
                    let (wq, bias) = q_params_of(&model.state.params[*layer]);
                    let w_off = push_u8(&mut consts, wq.values.data());
                    let b_off =
                        push_i32(&mut consts, &quantize_bias(&bias, in_q.scale, wq.qp.scale));
                    let cin_pf = if geom.depthwise { 1 } else { geom.cin };
                    let (oh, ow) = (shapes[*layer][1], shapes[*layer][2]);
                    let out_elems: usize = shapes[*layer].iter().product();
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::W_OFF] = w_off;
                    p[slot::B_OFF] = b_off;
                    p[slot::CIN_PF] = cin_pf as u32;
                    p[slot::KH] = geom.kh as u32;
                    p[slot::KW] = geom.kw as u32;
                    p[slot::CONV_STRIDE] = geom.stride as u32;
                    p[slot::PAD_H] = geom.pad_h as u32;
                    p[slot::PAD_W] = geom.pad_w as u32;
                    p[slot::DEPTHWISE] = geom.depthwise as u32;
                    p[slot::IH] = *in_h as u32;
                    p[slot::IW] = *in_w as u32;
                    p[slot::OH] = oh as u32;
                    p[slot::OW] = ow as u32;
                    p[slot::ZX] = in_q.zero_point as u32;
                    p[slot::ZW] = wq.qp.zero_point as u32;
                    p[slot::Z_OUT] = out_q.zero_point as u32;
                    p[slot::RELU] = *relu as u32;
                    p[slot::MULT] =
                        requant_multiplier(in_q.scale, wq.qp.scale, out_q.scale).to_bits();
                    p[slot::OUT_ELEMS] = out_elems as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::QConv,
                        params: p,
                        x_threads: out_elems.div_ceil(4) as u32,
                        capture_layers: vec![*layer],
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: out_elems,
                        prec: Precision::Uint8,
                        qp: out_q,
                    };
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::FConv { layer, geom, relu, in_h, in_w } => {
                    let (w, bias) = f_params_of(&model.state.params[*layer]);
                    let w_off = push_f32(&mut consts, w.data());
                    let b_off = push_f32(&mut consts, bias);
                    let cin_pf = if geom.depthwise { 1 } else { geom.cin };
                    let (oh, ow) = (shapes[*layer][1], shapes[*layer][2]);
                    let out_elems: usize = shapes[*layer].iter().product();
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::W_OFF] = w_off;
                    p[slot::B_OFF] = b_off;
                    p[slot::CIN_PF] = cin_pf as u32;
                    p[slot::KH] = geom.kh as u32;
                    p[slot::KW] = geom.kw as u32;
                    p[slot::CONV_STRIDE] = geom.stride as u32;
                    p[slot::PAD_H] = geom.pad_h as u32;
                    p[slot::PAD_W] = geom.pad_w as u32;
                    p[slot::DEPTHWISE] = geom.depthwise as u32;
                    p[slot::IH] = *in_h as u32;
                    p[slot::IW] = *in_w as u32;
                    p[slot::OH] = oh as u32;
                    p[slot::OW] = ow as u32;
                    p[slot::RELU] = *relu as u32;
                    p[slot::OUT_ELEMS] = out_elems as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::FConv,
                        params: p,
                        x_threads: out_elems as u32,
                        capture_layers: vec![*layer],
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: out_elems,
                        prec: Precision::Float32,
                        qp: cur.qp,
                    };
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::QLinear { layer, n_in, n_out, relu, in_qp, .. } => {
                    let in_q = resolve(*in_qp);
                    let out_q = act_qp[*layer];
                    let (wq, bias) = q_params_of(&model.state.params[*layer]);
                    let w_off = push_u8(&mut consts, wq.values.data());
                    let b_off =
                        push_i32(&mut consts, &quantize_bias(&bias, in_q.scale, wq.qp.scale));
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::W_OFF] = w_off;
                    p[slot::B_OFF] = b_off;
                    p[slot::N_IN] = *n_in as u32;
                    p[slot::ZX] = in_q.zero_point as u32;
                    p[slot::ZW] = wq.qp.zero_point as u32;
                    p[slot::Z_OUT] = out_q.zero_point as u32;
                    p[slot::RELU] = *relu as u32;
                    p[slot::MULT] =
                        requant_multiplier(in_q.scale, wq.qp.scale, out_q.scale).to_bits();
                    p[slot::OUT_ELEMS] = *n_out as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::QLinear,
                        params: p,
                        x_threads: n_out.div_ceil(4) as u32,
                        capture_layers: vec![*layer],
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: *n_out,
                        prec: Precision::Uint8,
                        qp: out_q,
                    };
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::FLinear { layer, n_in, n_out, relu } => {
                    let (w, bias) = f_params_of(&model.state.params[*layer]);
                    let w_off = push_f32(&mut consts, w.data());
                    let b_off = push_f32(&mut consts, bias);
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::W_OFF] = w_off;
                    p[slot::B_OFF] = b_off;
                    p[slot::N_IN] = *n_in as u32;
                    p[slot::RELU] = *relu as u32;
                    p[slot::OUT_ELEMS] = *n_out as u32;
                    dispatches.push(Dispatch {
                        kind: ShaderKind::FLinear,
                        params: p,
                        x_threads: *n_out as u32,
                        capture_layers: vec![*layer],
                    });
                    cur = LayerSlot {
                        word_off: out_off,
                        elems: *n_out,
                        prec: Precision::Float32,
                        qp: cur.qp,
                    };
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::MaxPool { layer, k, in_shape } => {
                    let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
                    let (kh, kw) = ((*k).min(h), (*k).min(w));
                    let (oh, ow) = (h / kh, w / kw);
                    let out_elems = c * oh * ow;
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::KH] = kh as u32;
                    p[slot::KW] = kw as u32;
                    p[slot::IH] = h as u32;
                    p[slot::IW] = w as u32;
                    p[slot::OH] = oh as u32;
                    p[slot::OW] = ow as u32;
                    p[slot::OUT_ELEMS] = out_elems as u32;
                    let quantized = cur.prec == Precision::Uint8;
                    dispatches.push(Dispatch {
                        kind: if quantized { ShaderKind::QMaxPool } else { ShaderKind::FMaxPool },
                        params: p,
                        x_threads: if quantized {
                            out_elems.div_ceil(4) as u32
                        } else {
                            out_elems as u32
                        },
                        capture_layers: vec![*layer],
                    });
                    // Pooling preserves precision and quantization params.
                    cur = LayerSlot { word_off: out_off, elems: out_elems, ..cur };
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::GlobalAvgPool { layer, in_shape } => {
                    let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
                    let out_off = off(&format!("act{layer}"));
                    let mut p = base(cur.word_off, out_off);
                    p[slot::IH] = h as u32;
                    p[slot::IW] = w as u32;
                    p[slot::OUT_ELEMS] = c as u32;
                    if cur.prec == Precision::Uint8 {
                        let out_q = act_qp[*layer];
                        // Exactly the multiplier expression of kernels::
                        // pool::qgap2d_fwd — host f32 ops, bit-identical.
                        let nf = (h * w) as f32;
                        let mult = cur.qp.scale / (nf * out_q.scale);
                        p[slot::ZX] = cur.qp.zero_point as u32;
                        p[slot::Z_OUT] = out_q.zero_point as u32;
                        p[slot::MULT] = mult.to_bits();
                        dispatches.push(Dispatch {
                            kind: ShaderKind::QGap,
                            params: p,
                            x_threads: c.div_ceil(4) as u32,
                            capture_layers: vec![*layer],
                        });
                        cur = LayerSlot {
                            word_off: out_off,
                            elems: c,
                            prec: Precision::Uint8,
                            qp: out_q,
                        };
                    } else {
                        dispatches.push(Dispatch {
                            kind: ShaderKind::FGap,
                            params: p,
                            x_threads: c as u32,
                            capture_layers: vec![*layer],
                        });
                        cur = LayerSlot { word_off: out_off, elems: c, ..cur };
                    }
                    layer_slots[*layer] = Some(cur);
                }
                StepDesc::Flatten { layer, out_len } => {
                    // Zero-copy on the GPU too: the layer's activation is
                    // the producer's buffer; capture it after the last
                    // dispatch (its content is already live).
                    assert_eq!(*out_len, cur.elems, "flatten must preserve element count");
                    layer_slots[*layer] = Some(cur);
                    dispatches
                        .last_mut()
                        .expect("flatten cannot be the first plan step")
                        .capture_layers
                        .push(*layer);
                }
            }
        }

        let layer_slots: Vec<LayerSlot> = layer_slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.unwrap_or_else(|| panic!("layer {i} produced no slot")))
            .collect();
        let mut layer_copy = vec![0usize; n];
        let mut n_copies = 0usize;
        for d in &dispatches {
            if !d.capture_layers.is_empty() {
                for &l in &d.capture_layers {
                    layer_copy[l] = n_copies;
                }
                n_copies += 1;
            }
        }

        // Device resources: one pipeline per shader kind in use, one
        // uniform + bind group per dispatch, the shared constants buffer,
        // and the single liveness-planned arena buffer.
        let device = &ctx.device;
        let bgl = device.create_bind_group_layout(&wgpu::BindGroupLayoutDescriptor {
            label: Some("tt-gpu-bgl"),
            entries: &[
                wgpu::BindGroupLayoutEntry {
                    binding: 0,
                    visibility: wgpu::ShaderStages::COMPUTE,
                    ty: wgpu::BindingType::Buffer {
                        ty: wgpu::BufferBindingType::Storage { read_only: false },
                        has_dynamic_offset: false,
                        min_binding_size: None,
                    },
                    count: None,
                },
                wgpu::BindGroupLayoutEntry {
                    binding: 1,
                    visibility: wgpu::ShaderStages::COMPUTE,
                    ty: wgpu::BindingType::Buffer {
                        ty: wgpu::BufferBindingType::Storage { read_only: true },
                        has_dynamic_offset: false,
                        min_binding_size: None,
                    },
                    count: None,
                },
                wgpu::BindGroupLayoutEntry {
                    binding: 2,
                    visibility: wgpu::ShaderStages::COMPUTE,
                    ty: wgpu::BindingType::Buffer {
                        ty: wgpu::BufferBindingType::Uniform,
                        has_dynamic_offset: false,
                        min_binding_size: None,
                    },
                    count: None,
                },
            ],
        });
        let pl = device.create_pipeline_layout(&wgpu::PipelineLayoutDescriptor {
            label: Some("tt-gpu-pl"),
            bind_group_layouts: &[&bgl],
            push_constant_ranges: &[],
        });
        let mut pipelines = HashMap::new();
        for d in &dispatches {
            if pipelines.contains_key(&d.kind) {
                continue;
            }
            let module = device.create_shader_module(wgpu::ShaderModuleDescriptor {
                label: Some(d.kind.name()),
                source: wgpu::ShaderSource::Wgsl(wgsl::source(d.kind).into()),
            });
            let pipe = device.create_compute_pipeline(&wgpu::ComputePipelineDescriptor {
                label: Some(d.kind.name()),
                layout: Some(&pl),
                module: &module,
                entry_point: "main",
                compilation_options: wgpu::PipelineCompilationOptions::default(),
                cache: None,
            });
            pipelines.insert(d.kind, pipe);
        }
        let consts_buf = upload_words(device, "tt-consts", &consts, wgpu::BufferUsages::STORAGE);
        let arena = device.create_buffer(&wgpu::BufferDescriptor {
            label: Some("tt-arena"),
            size: (max_batch * stride_words.max(1) * 4) as u64,
            usage: wgpu::BufferUsages::STORAGE
                | wgpu::BufferUsages::COPY_SRC
                | wgpu::BufferUsages::COPY_DST,
            mapped_at_creation: false,
        });
        let bind_groups = dispatches
            .iter()
            .map(|d| {
                let uniform =
                    upload_words(device, "tt-uniform", &d.params, wgpu::BufferUsages::UNIFORM);
                device.create_bind_group(&wgpu::BindGroupDescriptor {
                    label: Some(d.kind.name()),
                    layout: &bgl,
                    entries: &[
                        wgpu::BindGroupEntry { binding: 0, resource: arena.as_entire_binding() },
                        wgpu::BindGroupEntry {
                            binding: 1,
                            resource: consts_buf.as_entire_binding(),
                        },
                        wgpu::BindGroupEntry { binding: 2, resource: uniform.as_entire_binding() },
                    ],
                })
            })
            .collect();

        GpuPlan {
            pipelines,
            dispatches,
            bind_groups,
            arena,
            layer_slots,
            layer_copy,
            n_copies,
            input,
            stride_words,
            max_batch,
            slot_bytes_total,
        }
    }

    /// Per-sample device arena footprint in bytes — the liveness-planned
    /// total, mirroring the CPU plan's `planned_peak_bytes` accounting.
    pub fn arena_bytes_per_sample(&self) -> usize {
        self.stride_words * 4
    }

    /// Sum of all (word-aligned) activation slot sizes — what the arena
    /// would cost *without* liveness reuse.
    pub fn slot_bytes_total(&self) -> usize {
        self.slot_bytes_total
    }

    /// Number of compute dispatches per sample batch (`Flatten` is free).
    pub fn num_dispatches(&self) -> usize {
        self.dispatches.len()
    }

    /// The batch capacity the arena buffer was sized for.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn upload_inputs(&self, ctx: &GpuContext, xs: &[TensorF32]) {
        assert!(!xs.is_empty() && xs.len() <= self.max_batch, "batch must fit the arena");
        for (s, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.input.elems, "input shape mismatch");
            let bytes: Vec<u8> = match self.input.prec {
                Precision::Uint8 => {
                    // Host-side input coercion, bit-identical to
                    // run_forward's `QTensor::quantize_with`.
                    let q = QTensor::quantize_with(x, self.input.qp);
                    let mut b = q.values.data().to_vec();
                    while b.len() % 4 != 0 {
                        b.push(0);
                    }
                    b
                }
                Precision::Float32 => x.data().iter().flat_map(|f| f.to_le_bytes()).collect(),
            };
            let off = ((s * self.stride_words + self.input.word_off) * 4) as u64;
            ctx.queue.write_buffer(&self.arena, off, &bytes);
        }
    }

    fn encode_dispatch(&self, pass: &mut wgpu::ComputePass<'_>, i: usize, batch: u32) {
        let d = &self.dispatches[i];
        pass.set_pipeline(&self.pipelines[&d.kind]);
        pass.set_bind_group(0, &self.bind_groups[i], &[]);
        pass.dispatch_workgroups(d.x_threads.div_ceil(wgsl::WORKGROUP_SIZE), batch, 1);
    }

    /// Batched forward pass returning per-sample logits (the last layer's
    /// activation, dequantized exactly like `Act::to_float`).
    pub fn forward_batch(&self, ctx: &GpuContext, xs: &[TensorF32]) -> Vec<Vec<f32>> {
        self.upload_inputs(ctx, xs);
        let mut enc = ctx
            .device
            .create_command_encoder(&wgpu::CommandEncoderDescriptor { label: Some("tt-fwd") });
        {
            let mut pass = enc.begin_compute_pass(&wgpu::ComputePassDescriptor {
                label: Some("tt-fwd"),
                timestamp_writes: None,
            });
            for i in 0..self.dispatches.len() {
                self.encode_dispatch(&mut pass, i, xs.len() as u32);
            }
        }
        ctx.queue.submit([enc.finish()]);
        let words = ctx.read_words(&self.arena, self.max_batch * self.stride_words);
        let last = self.layer_slots.last().expect("model has at least one layer");
        (0..xs.len()).map(|s| read_slot(&words, s, self.stride_words, last).to_float()).collect()
    }

    /// Batched forward pass that snapshots the arena after every layer's
    /// producing dispatch (before liveness reuse can overwrite it) and
    /// returns each sample's per-layer activations — the cross-validation
    /// hook mirroring the CPU `FwdTrace::acts`.
    pub fn forward_batch_captured(&self, ctx: &GpuContext, xs: &[TensorF32]) -> Vec<Vec<GpuAct>> {
        self.upload_inputs(ctx, xs);
        let total_words = self.max_batch * self.stride_words;
        let capture = ctx.device.create_buffer(&wgpu::BufferDescriptor {
            label: Some("tt-capture"),
            size: (self.n_copies.max(1) * total_words * 4) as u64,
            usage: wgpu::BufferUsages::COPY_DST | wgpu::BufferUsages::MAP_READ,
            mapped_at_creation: false,
        });
        let mut enc = ctx
            .device
            .create_command_encoder(&wgpu::CommandEncoderDescriptor { label: Some("tt-fwd-cap") });
        let mut copy_idx = 0usize;
        for i in 0..self.dispatches.len() {
            {
                let mut pass = enc.begin_compute_pass(&wgpu::ComputePassDescriptor {
                    label: None,
                    timestamp_writes: None,
                });
                self.encode_dispatch(&mut pass, i, xs.len() as u32);
            }
            if !self.dispatches[i].capture_layers.is_empty() {
                enc.copy_buffer_to_buffer(
                    &self.arena,
                    0,
                    &capture,
                    (copy_idx * total_words * 4) as u64,
                    (total_words * 4) as u64,
                );
                copy_idx += 1;
            }
        }
        ctx.queue.submit([enc.finish()]);
        let words = ctx.map_and_read(&capture, self.n_copies * total_words);
        (0..xs.len())
            .map(|s| {
                self.layer_slots
                    .iter()
                    .enumerate()
                    .map(|(l, slot)| {
                        let c = self.layer_copy[l];
                        let region = &words[c * total_words..(c + 1) * total_words];
                        read_slot(region, s, self.stride_words, slot)
                    })
                    .collect()
            })
            .collect()
    }
}
