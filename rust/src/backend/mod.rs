//! Alternate execution backends for compiled plans.
//!
//! The native engine executes an [`crate::graph::plan::ExecPlan`] on the
//! CPU; this module hosts lowerings of the *same* compiled schedule onto
//! other compute substrates — the server-side half of the paper's
//! deployment story (pre-training and fleet scoring happen off-device,
//! only adaptation runs on the MCU):
//!
//!  * [`wgsl`] — the WGSL compute-shader sources for every plan step,
//!    plus Rust scalar mirrors of their quantized arithmetic. Always
//!    compiled (plain string templates, no GPU dependency), so the
//!    shader-side numerics are unit-tested against
//!    [`crate::quant`]'s formulas in the default dependency-free build.
//!  * `gpu` (feature `gpu`) — the wgpu device plumbing: `GpuContext`
//!    adapter/device acquisition and `GpuPlan`, which lowers an
//!    `ExecPlan`'s step descriptions ([`crate::graph::plan::StepDesc`])
//!    onto compute pipelines with a liveness-reused arena buffer
//!    mirroring the plan's `planned_peak_bytes` accounting. See
//!    DESIGN.md §12.

pub mod wgsl;

#[cfg(feature = "gpu")]
pub mod gpu;
