//! Packed sub-byte weight tensors (INT4 / INT2 lanes in a byte).
//!
//! The memory-driven mixed-precision direction of Rusci et al. applied to
//! this repo's FQT loop: weights may be held at 8, 4 or 2 bits per lane,
//! packed little-endian within each byte, and unpacked to plain `u8`
//! lanes immediately before the micro-kernel A-pack. An unpacked lane is
//! an ordinary affine-quantized value in `[0, qmax]` ⊂ `[0, 255]`, so
//! every existing u8 kernel consumes it unchanged (the kernels only ever
//! subtract the zero point) — which is what makes the packed-8 path
//! bit-identical to the retained [`QTensor`] oracle.
//!
//! Byte layout (LSB-first): lane `i` lives in byte `i / L` at bit offset
//! `(i % L) * bits`, where `L = 8 / bits` is the lanes-per-byte count.
//! For INT4, byte `b = lane1 << 4 | lane0`; for INT2,
//! `b = lane3 << 6 | lane2 << 4 | lane1 << 2 | lane0`. The final byte of
//! an odd-length tensor is zero-padded in its high lanes. The same layout
//! is consumed lane-parallel by the SWAR word unpacker in
//! [`kernels::simd`](crate::kernels::simd).
//!
//! Quantization at reduced width reuses the affine scheme verbatim with
//! `qmax = 2^bits - 1` in place of 255 (see
//! [`QParams::from_min_max_bits`]); at 8 bits the arithmetic is
//! *identical* to [`QParams::from_min_max`], which the tests pin down.

use crate::quant::{QParams, QTensor};
use crate::tensor::{TensorF32, TensorU8};

/// Per-tensor weight storage width. `W8` is the compatibility width: a
/// packed-8 tensor holds exactly the bytes its [`QTensor`] twin would.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WBits {
    /// One lane per byte — bit-identical to the u8 oracle path.
    W8,
    /// Two lanes per byte (`qmax = 15`), halving weight memory.
    W4,
    /// Four lanes per byte (`qmax = 3`), quartering weight memory.
    W2,
}

impl WBits {
    /// Bits per lane (8 / 4 / 2).
    #[inline(always)]
    pub fn bits(self) -> u32 {
        match self {
            WBits::W8 => 8,
            WBits::W4 => 4,
            WBits::W2 => 2,
        }
    }

    /// Lanes stored per byte (1 / 2 / 4).
    #[inline(always)]
    pub fn lanes_per_byte(self) -> usize {
        (8 / self.bits()) as usize
    }

    /// Largest representable lane value (`2^bits - 1`).
    #[inline(always)]
    pub fn qmax(self) -> i32 {
        (1i32 << self.bits()) - 1
    }

    /// Packed byte count for `len` logical lanes (final byte zero-padded).
    #[inline(always)]
    pub fn packed_len(self, len: usize) -> usize {
        len.div_ceil(self.lanes_per_byte())
    }

    /// One demotion step on the 8 → 4 → 2 ladder (`None` below 2).
    pub fn demote(self) -> Option<WBits> {
        match self {
            WBits::W8 => Some(WBits::W4),
            WBits::W4 => Some(WBits::W2),
            WBits::W2 => None,
        }
    }

    /// Parse a `TT_WBITS`-style value ("8" / "4" / "2").
    pub fn parse(s: &str) -> Option<WBits> {
        match s.trim() {
            "8" => Some(WBits::W8),
            "4" => Some(WBits::W4),
            "2" => Some(WBits::W2),
            _ => None,
        }
    }
}

/// Extract logical lane `i` from a packed byte slice.
#[inline(always)]
pub fn extract_lane(packed: &[u8], i: usize, bits: WBits) -> u8 {
    let lanes = bits.lanes_per_byte();
    let shift = (i % lanes) as u32 * bits.bits();
    let mask = bits.qmax() as u8;
    (packed[i / lanes] >> shift) & mask
}

/// Pack `lanes` (each must already be ≤ `qmax`) into bytes, LSB-first.
pub fn pack_lanes(lanes: &[u8], bits: WBits) -> Vec<u8> {
    let per = bits.lanes_per_byte();
    let mask = bits.qmax() as u8;
    let mut out = vec![0u8; bits.packed_len(lanes.len())];
    for (i, &v) in lanes.iter().enumerate() {
        debug_assert!(v <= mask, "lane {i} value {v} exceeds {bits:?} qmax {mask}");
        out[i / per] |= (v & mask) << ((i % per) as u32 * bits.bits());
    }
    out
}

/// Scalar unpack of `len` lanes into `dst` (the bit-exactness oracle for
/// the SWAR word unpacker in `kernels::simd`).
pub fn unpack_lanes(packed: &[u8], len: usize, bits: WBits, dst: &mut [u8]) {
    assert!(dst.len() >= len, "unpack dst {} too small for {len} lanes", dst.len());
    if bits == WBits::W8 {
        dst[..len].copy_from_slice(&packed[..len]);
        return;
    }
    let per = bits.lanes_per_byte();
    let shift = bits.bits();
    let mask = bits.qmax() as u8;
    for (b, chunk) in dst[..len].chunks_mut(per).enumerate() {
        let mut byte = packed[b];
        for d in chunk.iter_mut() {
            *d = byte & mask;
            byte >>= shift;
        }
    }
}

impl QParams {
    /// [`QParams::from_min_max`] generalized to a reduced lane width:
    /// `qmax = 2^bits - 1` replaces 255 in both the scale and the
    /// zero-point clamp. At [`WBits::W8`] the arithmetic is identical to
    /// `from_min_max` (pinned by test), so packed-8 deployments derive
    /// bit-identical parameters to the u8 oracle.
    pub fn from_min_max_bits(fmin: f32, fmax: f32, bits: WBits) -> QParams {
        let qmax = bits.qmax();
        let fmin = fmin.min(0.0);
        let fmax = fmax.max(0.0);
        let span = (fmax - fmin).max(1e-8);
        let scale = span / qmax as f32;
        let zero_point = (-fmin / scale).round().clamp(0.0, qmax as f32) as i32;
        QParams { scale, zero_point }
    }

    /// Quantize one value at a reduced lane width (clamp to `[0, qmax]`
    /// instead of `[0, 255]`). At [`WBits::W8`] this equals
    /// [`QParams::quantize`].
    #[inline(always)]
    pub fn quantize_bits(&self, f: f32, bits: WBits) -> u8 {
        ((f / self.scale).round() as i32 + self.zero_point).clamp(0, bits.qmax()) as u8
    }
}

/// A quantized tensor stored packed at a sub-byte lane width: the
/// [`QTensor`] twin for demoted layers. `shape`/`len` describe the
/// *logical* lane grid; `data` holds `bits.packed_len(len)` bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQTensor {
    shape: Vec<usize>,
    len: usize,
    pub bits: WBits,
    /// Packed payload (Arc-backed copy-on-write, like every tensor).
    pub data: TensorU8,
    pub qp: QParams,
}

impl PackedQTensor {
    /// Quantize a float tensor at `bits` using the provided parameters
    /// (the optimizer's quantize-on-write entry point).
    pub fn quantize_with_bits(t: &TensorF32, qp: QParams, bits: WBits) -> PackedQTensor {
        let lanes: Vec<u8> = t.data().iter().map(|&f| qp.quantize_bits(f, bits)).collect();
        PackedQTensor::from_lanes(t.shape(), &lanes, qp, bits)
    }

    /// Quantize a float tensor at `bits` with freshly observed parameters.
    pub fn quantize_bits(t: &TensorF32, bits: WBits) -> PackedQTensor {
        let (lo, hi) = crate::util::stats::min_max(t.data());
        PackedQTensor::quantize_with_bits(t, QParams::from_min_max_bits(lo, hi, bits), bits)
    }

    /// Pack already-quantized lanes (each ≤ `qmax`).
    pub fn from_lanes(shape: &[usize], lanes: &[u8], qp: QParams, bits: WBits) -> PackedQTensor {
        assert_eq!(shape.iter().product::<usize>(), lanes.len());
        let packed = pack_lanes(lanes, bits);
        PackedQTensor {
            shape: shape.to_vec(),
            len: lanes.len(),
            bits,
            data: TensorU8::from_vec(&[packed.len()], packed),
            qp,
        }
    }

    /// Zero-filled (at the zero point) packed tensor.
    pub fn zeros(shape: &[usize], qp: QParams, bits: WBits) -> PackedQTensor {
        let n: usize = shape.iter().product();
        let z = qp.zero_point.clamp(0, bits.qmax()) as u8;
        PackedQTensor::from_lanes(shape, &vec![z; n], qp, bits)
    }

    /// Logical lane grid shape (what the kernels see after unpack).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Logical lane count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Stored byte count — the number that weight-memory accounting
    /// reports (`len / lanes_per_byte`, rounded up).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }

    /// Scalar-unpack all lanes into `dst[..len]`.
    pub fn unpack_into(&self, dst: &mut [u8]) {
        unpack_lanes(self.data.data(), self.len, self.bits, dst);
    }

    /// Allocating unpack to the u8 twin (the cold oracle path: the
    /// reference executor unpacks once, then runs the unchanged u8
    /// kernels).
    pub fn to_qtensor(&self) -> QTensor {
        let mut lanes = vec![0u8; self.len];
        self.unpack_into(&mut lanes);
        QTensor { values: TensorU8::from_vec(&self.shape, lanes), qp: self.qp }
    }

    /// Dequantize to float (via the lane values; the qp applies
    /// unchanged because lanes are ordinary affine-quantized values).
    pub fn dequantize(&self) -> TensorF32 {
        let packed = self.data.data();
        let out: Vec<f32> =
            (0..self.len).map(|i| self.qp.dequantize(extract_lane(packed, i, self.bits))).collect();
        TensorF32::from_vec(&self.shape, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    const ALL: [WBits; 3] = [WBits::W8, WBits::W4, WBits::W2];

    #[test]
    fn widths_and_capacities() {
        assert_eq!(WBits::W8.lanes_per_byte(), 1);
        assert_eq!(WBits::W4.lanes_per_byte(), 2);
        assert_eq!(WBits::W2.lanes_per_byte(), 4);
        assert_eq!(WBits::W8.qmax(), 255);
        assert_eq!(WBits::W4.qmax(), 15);
        assert_eq!(WBits::W2.qmax(), 3);
        assert_eq!(WBits::W4.packed_len(7), 4);
        assert_eq!(WBits::W2.packed_len(7), 2);
        assert_eq!(WBits::W8.packed_len(7), 7);
        assert_eq!(WBits::W2.packed_len(0), 0);
        assert_eq!(WBits::W8.demote(), Some(WBits::W4));
        assert_eq!(WBits::W4.demote(), Some(WBits::W2));
        assert_eq!(WBits::W2.demote(), None);
    }

    #[test]
    fn parse_accepts_only_supported_widths() {
        assert_eq!(WBits::parse("8"), Some(WBits::W8));
        assert_eq!(WBits::parse(" 4 "), Some(WBits::W4));
        assert_eq!(WBits::parse("2"), Some(WBits::W2));
        for junk in ["1", "3", "16", "0", "", "four", "w4"] {
            assert_eq!(WBits::parse(junk), None, "{junk:?} must not parse");
        }
    }

    #[test]
    fn int4_byte_layout_is_lsb_first() {
        // lanes [a, b] -> byte b<<4 | a
        let p = pack_lanes(&[0x3, 0xA], WBits::W4);
        assert_eq!(p, vec![0xA3]);
        // INT2 lanes [a,b,c,d] -> d<<6 | c<<4 | b<<2 | a
        let p2 = pack_lanes(&[1, 2, 3, 0], WBits::W2);
        assert_eq!(p2, vec![0b00_11_10_01]);
        // odd tail zero-padded in the high lanes
        let p3 = pack_lanes(&[0xF, 0x1, 0x7], WBits::W4);
        assert_eq!(p3, vec![0x1F, 0x07]);
    }

    /// Pack → unpack round-trips at every width, including odd lengths
    /// and the MR/NR±1 edge-tile counts the micro-kernels produce.
    #[test]
    fn prop_pack_unpack_roundtrip() {
        Prop::new(128).check(
            |r: &mut Pcg32| {
                let bits = ALL[r.below(3) as usize];
                // bias toward lane-boundary lengths: MR=4, NR=16 tiles ±1
                let n = match r.below(4) {
                    0 => [3usize, 5, 15, 17, 63, 65][r.below(6) as usize],
                    _ => 1 + r.below(97) as usize,
                };
                let lanes: Vec<u8> =
                    (0..n).map(|_| (r.below(bits.qmax() as u32 + 1)) as u8).collect();
                (bits, lanes)
            },
            |&(bits, ref lanes)| {
                shrink_dim(lanes.len(), 1)
                    .into_iter()
                    .map(|m| (bits, lanes[..m].to_vec()))
                    .collect()
            },
            |&(bits, ref lanes)| {
                let packed = pack_lanes(lanes, bits);
                if packed.len() != bits.packed_len(lanes.len()) {
                    return Err(format!("packed {} bytes", packed.len()));
                }
                let mut back = vec![0u8; lanes.len()];
                unpack_lanes(&packed, lanes.len(), bits, &mut back);
                if &back != lanes {
                    return Err(format!("{bits:?} roundtrip diverged at n={}", lanes.len()));
                }
                for (i, &v) in lanes.iter().enumerate() {
                    if extract_lane(&packed, i, bits) != v {
                        return Err(format!("extract_lane({i}) diverged"));
                    }
                }
                Ok(())
            },
        );
    }

    /// At 8 bits the generalized parameter derivation and quantizer are
    /// arithmetic-identical to the proven u8 path — the foundation of the
    /// packed-8 bit-exactness oracle contract.
    #[test]
    fn prop_w8_matches_u8_oracle() {
        Prop::new(96).check(
            |r: &mut Pcg32| {
                let a = r.uniform(-8.0, 8.0);
                let b = r.uniform(-8.0, 8.0);
                let x = r.uniform(-10.0, 10.0);
                (a.min(b), a.max(b), x)
            },
            |_| vec![],
            |&(lo, hi, x)| {
                let qp8 = QParams::from_min_max_bits(lo, hi, WBits::W8);
                let qp = QParams::from_min_max(lo, hi);
                if qp8.scale.to_bits() != qp.scale.to_bits() || qp8.zero_point != qp.zero_point {
                    return Err(format!("params diverged: {qp8:?} vs {qp:?}"));
                }
                if qp8.quantize_bits(x, WBits::W8) != qp.quantize(x) {
                    return Err(format!("quantizer diverged at {x}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn packed8_tensor_matches_qtensor_bytes() {
        let mut rng = Pcg32::seeded(21);
        let mut t = TensorF32::zeros(&[3, 5]);
        rng.fill_normal(t.data_mut(), 1.0);
        let qp = QParams::observe(t.data());
        let q = QTensor::quantize_with(&t, qp);
        let p = PackedQTensor::quantize_with_bits(&t, qp, WBits::W8);
        assert_eq!(p.data.data(), q.values.data(), "packed-8 payload must equal the u8 oracle");
        assert_eq!(p.to_qtensor(), q);
        assert_eq!(p.packed_bytes(), q.len());
    }

    /// Sub-byte round-trip error is bounded by half a (coarser) step, and
    /// the packed byte count shrinks by exactly the lane factor.
    #[test]
    fn subbyte_quantize_roundtrip_and_size() {
        let mut rng = Pcg32::seeded(33);
        let mut t = TensorF32::zeros(&[4, 9]);
        rng.fill_normal(t.data_mut(), 1.0);
        for bits in [WBits::W4, WBits::W2] {
            let p = PackedQTensor::quantize_bits(&t, bits);
            assert_eq!(p.packed_bytes(), bits.packed_len(t.len()));
            assert_eq!(p.len(), t.len());
            let back = p.dequantize();
            for (a, b) in back.data().iter().zip(t.data()) {
                assert!(
                    (a - b).abs() <= 0.5 * p.qp.scale + 1e-6,
                    "{bits:?}: roundtrip error {} above half-step {}",
                    (a - b).abs(),
                    0.5 * p.qp.scale
                );
            }
            // dequantize must agree with the allocating unpack's dequantize
            let via_q = p.to_qtensor().dequantize();
            assert_eq!(via_q.data(), back.data());
        }
    }

    #[test]
    fn zeros_is_at_the_zero_point() {
        for bits in ALL {
            let qp = QParams::from_min_max_bits(-1.0, 1.0, bits);
            let z = PackedQTensor::zeros(&[2, 3], qp, bits);
            assert_eq!(z.len(), 6);
            for v in z.dequantize().data() {
                assert!(v.abs() < 1e-6, "{bits:?}: zeros must dequantize to ~0");
            }
        }
    }
}
