//! Linear (affine) uint8 quantization — the substrate shared by inference
//! and training (§III-A of the paper).
//!
//! Per-tensor scheme: `q = clamp(round(f / s) + z, 0, 255)`, with scale `s`
//! and zero point `z` derived from the observed float range (Eqs. 6–7). The
//! *same* scheme is used for weights, activations, and backpropagated error
//! tensors; weight gradients are the single exception — they stay in float
//! because the descent step (Eq. 5) runs in float space.
//!
//! Rounding is *half away from zero* everywhere (`f32::round`). The Pallas
//! kernels implement the identical rule (`sign(x) * floor(|x| + 0.5)`) so the
//! native backend and the AOT HLO artifacts agree bit-exactly on integer
//! paths (verified by `rust/tests/xla_cross_validation.rs`).

pub mod observer;
pub mod subbyte;

use crate::tensor::{TensorF32, TensorU8};

/// Scale / zero-point pair of one quantized tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QParams {
    /// Identity-ish params used before any observation: maps [0,255] to
    /// [-1, 1) roughly symmetrically.
    pub fn unit() -> QParams {
        QParams { scale: 2.0 / 255.0, zero_point: 128 }
    }

    /// Derive parameters from an observed float range (paper Eqs. 6–7).
    /// The range is widened to include zero so the zero point is exactly
    /// representable (required for zero-padding in conv and ReLU clamping).
    pub fn from_min_max(fmin: f32, fmax: f32) -> QParams {
        let fmin = fmin.min(0.0);
        let fmax = fmax.max(0.0);
        let span = (fmax - fmin).max(1e-8);
        let scale = span / 255.0;
        let zero_point = (-fmin / scale).round().clamp(0.0, 255.0) as i32;
        QParams { scale, zero_point }
    }

    /// Derive parameters from the contents of a float tensor.
    pub fn observe(data: &[f32]) -> QParams {
        let (lo, hi) = crate::util::stats::min_max(data);
        QParams::from_min_max(lo, hi)
    }

    /// Quantize one value.
    #[inline(always)]
    pub fn quantize(&self, f: f32) -> u8 {
        ((f / self.scale).round() as i32 + self.zero_point).clamp(0, 255) as u8
    }

    /// Dequantize one value.
    #[inline(always)]
    pub fn dequantize(&self, q: u8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }

    /// The quantized representation of float 0 (= the zero point).
    #[inline(always)]
    pub fn qzero(&self) -> u8 {
        self.zero_point.clamp(0, 255) as u8
    }
}

/// A quantized tensor: uint8 payload plus its per-tensor parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct QTensor {
    pub values: TensorU8,
    pub qp: QParams,
}

impl QTensor {
    /// Quantize a float tensor with freshly derived parameters.
    pub fn quantize(t: &TensorF32) -> QTensor {
        let qp = QParams::observe(t.data());
        QTensor::quantize_with(t, qp)
    }

    /// Quantize a float tensor using the provided parameters.
    pub fn quantize_with(t: &TensorF32, qp: QParams) -> QTensor {
        let values = TensorU8::from_vec(
            t.shape(),
            t.data().iter().map(|&f| qp.quantize(f)).collect(),
        );
        QTensor { values, qp }
    }

    /// Dequantize to float.
    pub fn dequantize(&self) -> TensorF32 {
        TensorF32::from_vec(
            self.values.shape(),
            self.values.data().iter().map(|&q| self.qp.dequantize(q)).collect(),
        )
    }

    pub fn shape(&self) -> &[usize] {
        self.values.shape()
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Zero-filled (at the zero point) quantized tensor.
    pub fn zeros(shape: &[usize], qp: QParams) -> QTensor {
        QTensor { values: TensorU8::full(shape, qp.qzero()), qp }
    }
}

/// Quantize a bias vector to i32 at scale `s_x * s_w` (zero point 0), the
/// standard convention that lets the bias be added directly to the i32
/// accumulator of a quantized conv / linear op.
pub fn quantize_bias(bias: &[f32], s_x: f32, s_w: f32) -> Vec<i32> {
    let s = s_x * s_w;
    bias.iter().map(|&b| (b / s).round() as i32).collect()
}

/// The fixed-point requantization multiplier `s_a * s_b / s_out` used when
/// the i32 accumulator of a quantized op is mapped back to uint8 (Eq. 4).
#[inline(always)]
pub fn requant_multiplier(s_a: f32, s_b: f32, s_out: f32) -> f32 {
    s_a * s_b / s_out
}

/// Requantize one i32 accumulator value to uint8 (Eq. 4 inner expression).
/// `relu` additionally clamps at the output zero point, implementing the
/// folded ReLU of the paper's monolithic QConv block (Fig. 2b).
#[inline(always)]
pub fn requantize(acc: i32, mult: f32, z_out: i32, relu: bool) -> u8 {
    let v = (acc as f32 * mult).round() as i32 + z_out;
    let lo = if relu { z_out.clamp(0, 255) } else { 0 };
    v.clamp(lo, 255) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    #[test]
    fn qparams_cover_range() {
        let qp = QParams::from_min_max(-2.0, 6.0);
        assert!((qp.scale - 8.0 / 255.0).abs() < 1e-7);
        assert_eq!(qp.quantize(-2.0), 0);
        assert_eq!(qp.quantize(6.0), 255);
        // zero must be exactly representable
        assert!((qp.dequantize(qp.qzero())).abs() < 1e-6);
    }

    #[test]
    fn range_widened_to_include_zero() {
        let qp = QParams::from_min_max(2.0, 6.0);
        assert_eq!(qp.zero_point, 0);
        let qp2 = QParams::from_min_max(-6.0, -2.0);
        assert_eq!(qp2.zero_point, 255);
    }

    #[test]
    fn degenerate_range_is_safe() {
        let qp = QParams::from_min_max(0.0, 0.0);
        assert!(qp.scale > 0.0);
        let q = qp.quantize(0.0);
        assert!((qp.dequantize(q)).abs() < 1e-6);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        Prop::new(128).check(
            |r: &mut Pcg32| {
                let lo = r.uniform(-10.0, 0.0);
                let hi = r.uniform(0.0, 10.0);
                let x = r.uniform(lo, hi);
                (lo, hi, x)
            },
            |_| vec![],
            |&(lo, hi, x)| {
                let qp = QParams::from_min_max(lo, hi);
                let err = (qp.dequantize(qp.quantize(x)) - x).abs();
                if err <= 0.5 * qp.scale + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("roundtrip error {err} > s/2 = {}", qp.scale * 0.5))
                }
            },
        );
    }

    #[test]
    fn qtensor_roundtrip_shape_preserved() {
        let mut rng = Pcg32::seeded(11);
        let mut t = TensorF32::zeros(&[3, 4, 4]);
        rng.fill_normal(t.data_mut(), 1.0);
        let q = QTensor::quantize(&t);
        let back = q.dequantize();
        assert_eq!(back.shape(), t.shape());
        for (a, b) in back.data().iter().zip(t.data()) {
            assert!((a - b).abs() <= 0.5 * q.qp.scale + 1e-6);
        }
    }

    #[test]
    fn requantize_matches_scalar_math() {
        let (sa, sb, so) = (0.02f32, 0.015f32, 0.11f32);
        let m = requant_multiplier(sa, sb, so);
        let acc = 1234i32;
        let expect = ((acc as f32 * m).round() as i32 + 7).clamp(0, 255) as u8;
        assert_eq!(requantize(acc, m, 7, false), expect);
    }

    #[test]
    fn requantize_relu_clamps_at_zero_point() {
        let m = 0.01;
        // Negative accumulator maps below the zero point -> clamped to z.
        assert_eq!(requantize(-5000, m, 100, true), 100);
        assert_eq!(requantize(-5000, m, 100, false), 50);
    }

    #[test]
    fn bias_quantization_roundtrips() {
        let bias = [0.5f32, -0.25, 0.0];
        let (sx, sw) = (0.05, 0.01);
        let qb = quantize_bias(&bias, sx, sw);
        for (q, b) in qb.iter().zip(bias.iter()) {
            let back = *q as f32 * sx * sw;
            assert!((back - b).abs() <= 0.5 * sx * sw + 1e-7);
        }
    }

    #[test]
    fn quantize_saturates_out_of_range() {
        let qp = QParams::from_min_max(-1.0, 1.0);
        assert_eq!(qp.quantize(100.0), 255);
        assert_eq!(qp.quantize(-100.0), 0);
    }

    #[test]
    fn prop_qparams_monotone() {
        // Quantization must be monotone: f1 <= f2 -> q(f1) <= q(f2).
        Prop::new(96).check(
            |r: &mut Pcg32| {
                let a = r.uniform(-5.0, 5.0);
                let b = r.uniform(-5.0, 5.0);
                let n = 2 + r.below(30) as usize;
                (a.min(b), a.max(b), n)
            },
            |&(a, b, n)| shrink_dim(n, 2).into_iter().map(|m| (a, b, m)).collect(),
            |&(lo, hi, n)| {
                let qp = QParams::from_min_max(lo, hi);
                let mut prev = qp.quantize(lo - 1.0);
                for i in 0..n {
                    let f = lo - 1.0 + (hi - lo + 2.0) * i as f32 / n as f32;
                    let q = qp.quantize(f);
                    if q < prev {
                        return Err(format!("non-monotone at {f}"));
                    }
                    prev = q;
                }
                Ok(())
            },
        );
    }
}
