//! Range observers: track the float range of a tensor stream and derive the
//! quantization parameters for it.
//!
//! Two uses on device:
//!  * **PTQ calibration** (`MinMaxObserver` in `absolute` mode) — run a few
//!    calibration samples through the float model before deployment and fix
//!    activation ranges.
//!  * **Online error-tensor observers** (`ema` mode) — backpropagated error
//!    tensors (Eq. 4) need scale/zero-point too. Their distribution drifts as
//!    training converges (Fig. 3: magnitudes shrink), so we follow it with an
//!    exponential moving average of the per-sample min/max. This is our
//!    implementation choice for a detail the paper leaves open; it mirrors
//!    the dynamic weight-range adaptation of Eqs. 6–7.

use crate::quant::QParams;
use crate::util::stats::Ema;

/// How the observer aggregates successive ranges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ObserverMode {
    /// Running absolute min/max over everything ever seen (calibration).
    Absolute,
    /// EMA-smoothed min/max (online training observers).
    Ema { alpha: f32 },
}

/// Tracks a float value range and yields quantization parameters.
#[derive(Clone, Debug)]
pub struct MinMaxObserver {
    mode: ObserverMode,
    abs_min: f32,
    abs_max: f32,
    ema_min: Ema,
    ema_max: Ema,
    observed: bool,
}

impl MinMaxObserver {
    pub fn new(mode: ObserverMode) -> Self {
        let alpha = match mode {
            ObserverMode::Ema { alpha } => alpha,
            ObserverMode::Absolute => 1.0,
        };
        MinMaxObserver {
            mode,
            abs_min: f32::INFINITY,
            abs_max: f32::NEG_INFINITY,
            ema_min: Ema::new(alpha),
            ema_max: Ema::new(alpha),
            observed: false,
        }
    }

    /// Default observer for online error tensors.
    pub fn online() -> Self {
        MinMaxObserver::new(ObserverMode::Ema { alpha: 0.1 })
    }

    /// Default observer for PTQ calibration.
    pub fn calibration() -> Self {
        MinMaxObserver::new(ObserverMode::Absolute)
    }

    /// Feed one tensor's worth of float data.
    pub fn observe(&mut self, data: &[f32]) {
        if data.is_empty() {
            return;
        }
        let (lo, hi) = crate::util::stats::min_max(data);
        self.observe_range(lo, hi);
    }

    /// Feed a precomputed (min, max) range.
    pub fn observe_range(&mut self, lo: f32, hi: f32) {
        self.observed = true;
        match self.mode {
            ObserverMode::Absolute => {
                self.abs_min = self.abs_min.min(lo);
                self.abs_max = self.abs_max.max(hi);
            }
            ObserverMode::Ema { .. } => {
                self.ema_min.push(lo);
                self.ema_max.push(hi);
            }
        }
    }

    pub fn has_observed(&self) -> bool {
        self.observed
    }

    /// Current range estimate (None before any observation).
    pub fn range(&self) -> Option<(f32, f32)> {
        if !self.observed {
            return None;
        }
        Some(match self.mode {
            ObserverMode::Absolute => (self.abs_min, self.abs_max),
            ObserverMode::Ema { .. } => (self.ema_min.get(), self.ema_max.get()),
        })
    }

    /// Quantization parameters for the current range; `QParams::unit()`
    /// before any observation (a safe, wide default).
    pub fn qparams(&self) -> QParams {
        match self.range() {
            Some((lo, hi)) => QParams::from_min_max(lo, hi),
            None => QParams::unit(),
        }
    }

    /// Seed the observer from known parameters (restoring deployed state).
    pub fn seed_from(&mut self, qp: QParams) {
        let lo = (0 - qp.zero_point) as f32 * qp.scale;
        let hi = (255 - qp.zero_point) as f32 * qp.scale;
        self.observe_range(lo, hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absolute_tracks_extremes() {
        let mut o = MinMaxObserver::calibration();
        o.observe(&[0.0, 1.0]);
        o.observe(&[-3.0, 0.5]);
        o.observe(&[2.0]);
        assert_eq!(o.range(), Some((-3.0, 2.0)));
    }

    #[test]
    fn ema_follows_shrinking_ranges() {
        let mut o = MinMaxObserver::new(ObserverMode::Ema { alpha: 0.5 });
        o.observe(&[-8.0, 8.0]);
        for _ in 0..20 {
            o.observe(&[-1.0, 1.0]);
        }
        let (lo, hi) = o.range().unwrap();
        assert!(lo > -1.1 && lo < -0.9, "lo={lo}");
        assert!(hi < 1.1 && hi > 0.9, "hi={hi}");
    }

    #[test]
    fn unprimed_returns_unit_params() {
        let o = MinMaxObserver::online();
        assert_eq!(o.qparams(), QParams::unit());
        assert!(o.range().is_none());
    }

    #[test]
    fn seed_from_roundtrips_range() {
        let qp = QParams::from_min_max(-2.0, 2.0);
        let mut o = MinMaxObserver::online();
        o.seed_from(qp);
        let qp2 = o.qparams();
        assert!((qp.scale - qp2.scale).abs() < 1e-6);
        assert!((qp.zero_point - qp2.zero_point).abs() <= 1);
    }

    #[test]
    fn empty_observation_is_noop() {
        let mut o = MinMaxObserver::calibration();
        o.observe(&[]);
        assert!(!o.has_observed());
    }
}
