//! Quantized fully connected layer: forward (Eq. 3 with `·` = matvec),
//! error backprop `E_{n-1} = Wᵀ·E_n` (Eq. 1/4) and weight gradient
//! `∇W = E_n · X_nᵀ` (Eq. 2).
//!
//! Layouts: input `[In]`, weights `[Out, In]`, output `[Out]` — per-sample
//! vectors (the paper's minibatching accumulates gradients over successive
//! samples instead of adding a batch dimension, §III-A).
//!
//! The sparse-update "structures" of a linear layer are its output rows
//! (paper §III-B: rows/columns); `keep` masks whole rows.

use crate::kernels::simd::{self, KernelSel};
use crate::kernels::{gemm, kept_count, OpCounter};
use crate::memplan::Scratch;
use crate::quant::subbyte::PackedQTensor;
use crate::quant::{requant_multiplier, requantize, QParams, QTensor};
use crate::tensor::TensorF32;

/// Forward: `y = relu?(W·x + b)` fully quantized.
pub fn qlinear_fwd(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> QTensor {
    qlinear_fwd_sel(KernelSel::Auto, x, w, bias, out_qp, relu, ops)
}

/// [`qlinear_fwd`] with an explicit kernel selection (the layer ops pass
/// the plan-compile autotuned choice). Bit-exact for every selection.
pub fn qlinear_fwd_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> QTensor {
    let n_in = x.len();
    let n_out = w.shape()[0];
    assert_eq!(w.shape()[1], n_in, "weight/input dims mismatch");
    assert_eq!(bias.len(), n_out);

    let zx = x.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(x.qp.scale, w.qp.scale, out_qp.scale);
    let xd = x.values.data();
    let wd = w.values.data();

    // Routed through the shared integer GEMM core with N = 1: the
    // per-sample matvec is a degenerate GEMM (weights are the `[Out, In]`
    // A-matrix, the input vector a single column). Bit-exact with the
    // previous hand-rolled loop — i32 sums are order-independent.
    let mut acc = vec![0i32; n_out];
    gemm::gemm_u8_i32_sel(sel, wd, zw, xd, zx, bias, n_out, n_in, 1, &mut acc);
    let mut out = QTensor::zeros(&[n_out], out_qp);
    for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
        *o = requantize(a, mult, out_qp.zero_point, relu);
    }

    ops.int_macs += (n_out * n_in) as u64;
    ops.int_ops += n_out as u64;
    ops.bytes += (n_in + n_out * n_in + n_out) as u64;
    out
}

/// [`qlinear_fwd`] with the quantized epilogue fused into the GEMM
/// micro-kernel ([`gemm::gemm_u8_i32_fused`]): requantization, bias add and
/// the folded ReLU run on the accumulator tile in registers, so the unfused
/// path's heap-allocated `[Out]` i32 accumulator disappears entirely.
///
/// `dequant`: when `Some`, the float dequantization of the output is
/// emitted alongside it (a plan-folded `DequantizeOp`'s staging buffer).
/// Returns the output plus the saturated-value count (see
/// [`gemm::gemm_u8_i32_fused`]). Bit-identical to [`qlinear_fwd`] with
/// identical op accounting — the unfused kernel is the `TT_NO_FUSE=1`
/// parity oracle.
pub fn qlinear_fwd_fused(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    qlinear_fwd_fused_sel(KernelSel::Auto, x, w, bias, out_qp, relu, dequant, ops)
}

/// [`qlinear_fwd_fused`] with an explicit kernel selection. Bit-exact for
/// every selection (the fused GEMM's epilogue is selection-invariant).
#[allow(clippy::too_many_arguments)]
pub fn qlinear_fwd_fused_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    let n_in = x.len();
    let n_out = w.shape()[0];
    assert_eq!(w.shape()[1], n_in, "weight/input dims mismatch");
    assert_eq!(bias.len(), n_out);

    let zx = x.qp.zero_point;
    let zw = w.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(x.qp.scale, w.qp.scale, out_qp.scale),
        qp: out_qp,
        relu,
    };
    let xd = x.values.data();
    let wd = w.values.data();

    let mut out = QTensor::zeros(&[n_out], out_qp);
    let sat = gemm::gemm_u8_i32_fused_sel(
        sel,
        wd,
        zw,
        xd,
        zx,
        bias,
        n_out,
        n_in,
        1,
        &epi,
        out.values.data_mut(),
        dequant,
    );

    ops.int_macs += (n_out * n_in) as u64;
    ops.int_ops += n_out as u64;
    ops.bytes += (n_in + n_out * n_in + n_out) as u64;
    (out, sat)
}

/// Error backprop: `e_in = Wᵀ · e_out`, quantized (Eq. 4). `keep` masks
/// output rows (sparse updates).
pub fn qlinear_bwd_input(
    e: &QTensor,
    w: &QTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> QTensor {
    let n_out = e.len();
    let n_in = w.shape()[1];
    assert_eq!(w.shape()[0], n_out);

    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale);
    let ed = e.values.data();
    let wd = w.values.data();

    let mut acc = vec![0i32; n_in];
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        let ev = ed[o] as i32 - ze;
        if ev == 0 {
            continue;
        }
        let row = &wd[o * n_in..(o + 1) * n_in];
        for (a, wv) in acc.iter_mut().zip(row.iter()) {
            *a += ev * (*wv as i32 - zw);
        }
    }

    let mut out = QTensor::zeros(&[n_in], out_qp);
    for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
        *o = requantize(a, mult, out_qp.zero_point, false);
    }

    ops.int_macs += kept * n_in as u64;
    ops.int_ops += n_in as u64;
    ops.bytes += (n_out + n_out * n_in + n_in) as u64;
    out
}

/// GEMM-routed error backprop, **bit-exact** with [`qlinear_bwd_input`]:
/// `e_in = eᵀ·W` expressed as a 1×`n_out`×`n_in` GEMM over the row-major
/// weight matrix. Masked rows are written to the scratch copy of `e` at the
/// error zero point, which the integer GEMM core skips as whole AXPY rows
/// (`av == 0`), so the kept ratio is a proportional FLOP reduction.
pub fn qlinear_bwd_input_gemm(
    e: &QTensor,
    w: &QTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qlinear_bwd_input_gemm_sel(KernelSel::Auto, e, w, out_qp, keep, scratch, ops)
}

/// [`qlinear_bwd_input_gemm`] with an explicit kernel selection.
pub fn qlinear_bwd_input_gemm_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let n_out = e.len();
    let n_in = w.shape()[1];
    assert_eq!(w.shape()[0], n_out);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale);
    let kept = kept_count(keep, n_out) as u64;

    let mut out = QTensor::zeros(&[n_in], out_qp);
    {
        let (_, ecopy, acc, init) = scratch.qconv_bwd_bufs(0, n_out, n_in, 1);
        let zq = e.qp.qzero();
        for (dst, (i, &src)) in ecopy.iter_mut().zip(e.values.data().iter().enumerate()) {
            *dst = match keep {
                Some(k) if !k[i] => zq,
                _ => src,
            };
        }
        gemm::gemm_u8_i32_sel(sel, ecopy, ze, w.values.data(), zw, init, 1, n_out, n_in, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += kept * n_in as u64;
    ops.int_ops += n_in as u64;
    ops.bytes += (n_out + n_out * n_in + n_in) as u64;
    out
}

/// [`qlinear_bwd_input_gemm`] with the requantize epilogue fused into the
/// GEMM micro-kernel: the `[In]` i32 accumulator strip never materializes
/// (only the masked `e` scratch copy remains). Bit-exact with both unfused
/// backward kernels, with identical op accounting.
pub fn qlinear_bwd_input_gemm_fused(
    e: &QTensor,
    w: &QTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qlinear_bwd_input_gemm_fused_sel(KernelSel::Auto, e, w, out_qp, keep, scratch, ops)
}

/// [`qlinear_bwd_input_gemm_fused`] with an explicit kernel selection.
pub fn qlinear_bwd_input_gemm_fused_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let n_out = e.len();
    let n_in = w.shape()[1];
    assert_eq!(w.shape()[0], n_out);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let kept = kept_count(keep, n_out) as u64;

    let mut out = QTensor::zeros(&[n_in], out_qp);
    {
        let (_, ecopy, _, init) = scratch.qconv_bwd_bufs(0, n_out, 0, 1);
        let zq = e.qp.qzero();
        for (dst, (i, &src)) in ecopy.iter_mut().zip(e.values.data().iter().enumerate()) {
            *dst = match keep {
                Some(k) if !k[i] => zq,
                _ => src,
            };
        }
        gemm::gemm_u8_i32_fused_sel(
            sel,
            ecopy,
            ze,
            w.values.data(),
            zw,
            init,
            1,
            n_out,
            n_in,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += kept * n_in as u64;
    ops.int_ops += n_in as u64;
    ops.bytes += (n_out + n_out * n_in + n_in) as u64;
    out
}

// ---- packed sub-byte weight twins (`quant::subbyte`) ----------------------
//
// Same contract as the conv twins (`kernels::qconv`): weights arrive as a
// [`PackedQTensor`], lanes are unpacked into scratch in one panel pass and
// the existing GEMM core runs unchanged — bit-identical to the u8 kernel on
// `pw.to_qtensor()`, op accounting on the logical lane count. The forward
// uses the A-side panel unpack of [`gemm::gemm_u8_i32_pa_sel`]; the
// backward-input GEMM consumes `w` as its **B operand** (`e_in = eᵀ·W`), so
// the whole weight matrix is unpacked into the `wq_u8` scratch span before
// the call. Unlike the u8 forwards, the packed forwards take a `Scratch` —
// the lane buffer has to live somewhere, and the plan-owned arena is where
// every other transient of the engine lives.

/// Packed-weight twin of [`qlinear_fwd_sel`].
#[allow(clippy::too_many_arguments)]
pub fn qlinear_fwd_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let n_in = x.len();
    let n_out = pw.shape()[0];
    assert_eq!(pw.shape()[1], n_in, "weight/input dims mismatch");
    assert_eq!(bias.len(), n_out);

    let zx = x.qp.zero_point;
    let zw = pw.qp.zero_point;
    let mult = requant_multiplier(x.qp.scale, pw.qp.scale, out_qp.scale);

    let mut out = QTensor::zeros(&[n_out], out_qp);
    {
        let (wq, _, acc) = scratch.qconv_pa_bufs(n_out * n_in, 0, n_out);
        gemm::gemm_u8_i32_pa_sel(
            sel,
            pw.data.data(),
            pw.bits,
            wq,
            zw,
            x.values.data(),
            zx,
            bias,
            n_out,
            n_in,
            1,
            acc,
        );
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, relu);
        }
    }

    ops.int_macs += (n_out * n_in) as u64;
    ops.int_ops += n_out as u64;
    ops.bytes += (n_in + n_out * n_in + n_out) as u64;
    out
}

/// Packed-weight twin of [`qlinear_fwd_fused_sel`].
#[allow(clippy::too_many_arguments)]
pub fn qlinear_fwd_fused_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    let n_in = x.len();
    let n_out = pw.shape()[0];
    assert_eq!(pw.shape()[1], n_in, "weight/input dims mismatch");
    assert_eq!(bias.len(), n_out);

    let zx = x.qp.zero_point;
    let zw = pw.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(x.qp.scale, pw.qp.scale, out_qp.scale),
        qp: out_qp,
        relu,
    };

    let mut out = QTensor::zeros(&[n_out], out_qp);
    let sat;
    {
        let (wq, _, _) = scratch.qconv_pa_bufs(n_out * n_in, 0, 0);
        sat = gemm::gemm_u8_i32_fused_pa_sel(
            sel,
            pw.data.data(),
            pw.bits,
            wq,
            zw,
            x.values.data(),
            zx,
            bias,
            n_out,
            n_in,
            1,
            &epi,
            out.values.data_mut(),
            dequant,
        );
    }

    ops.int_macs += (n_out * n_in) as u64;
    ops.int_ops += n_out as u64;
    ops.bytes += (n_in + n_out * n_in + n_out) as u64;
    (out, sat)
}

/// Packed-weight twin of [`qlinear_bwd_input_gemm_sel`]: `w` is the GEMM's
/// B operand here, so the whole matrix is unpacked into the `wq_u8` span
/// (the masked `e` copy still lives in the backward column buffer).
pub fn qlinear_bwd_input_gemm_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let n_out = e.len();
    let n_in = pw.shape()[1];
    assert_eq!(pw.shape()[0], n_out);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale);
    let kept = kept_count(keep, n_out) as u64;

    let mut out = QTensor::zeros(&[n_in], out_qp);
    {
        let (wq, ecopy, acc, init) = scratch.qconv_bwd_pa_bufs(n_out * n_in, n_out, n_in, 1);
        simd::unpack_lanes_sel(sel, pw.data.data(), n_out * n_in, pw.bits, wq);
        let zq = e.qp.qzero();
        for (dst, (i, &src)) in ecopy.iter_mut().zip(e.values.data().iter().enumerate()) {
            *dst = match keep {
                Some(k) if !k[i] => zq,
                _ => src,
            };
        }
        gemm::gemm_u8_i32_sel(sel, ecopy, ze, wq, zw, init, 1, n_out, n_in, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += kept * n_in as u64;
    ops.int_ops += n_in as u64;
    ops.bytes += (n_out + n_out * n_in + n_in) as u64;
    out
}

/// Packed-weight twin of [`qlinear_bwd_input_gemm_fused_sel`].
pub fn qlinear_bwd_input_gemm_fused_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let n_out = e.len();
    let n_in = pw.shape()[1];
    assert_eq!(pw.shape()[0], n_out);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let kept = kept_count(keep, n_out) as u64;

    let mut out = QTensor::zeros(&[n_in], out_qp);
    {
        let (wq, ecopy, _, init) = scratch.qconv_bwd_pa_bufs(n_out * n_in, n_out, 0, 1);
        simd::unpack_lanes_sel(sel, pw.data.data(), n_out * n_in, pw.bits, wq);
        let zq = e.qp.qzero();
        for (dst, (i, &src)) in ecopy.iter_mut().zip(e.values.data().iter().enumerate()) {
            *dst = match keep {
                Some(k) if !k[i] => zq,
                _ => src,
            };
        }
        gemm::gemm_u8_i32_fused_sel(
            sel,
            ecopy,
            ze,
            wq,
            zw,
            init,
            1,
            n_out,
            n_in,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += kept * n_in as u64;
    ops.int_ops += n_in as u64;
    ops.bytes += (n_out + n_out * n_in + n_in) as u64;
    out
}

/// Weight gradient in float: `∇W[o][i] = s_e·s_x · (e[o]−z_e)(x[i]−z_x)`,
/// bias gradient `∇b[o] = s_e · (e[o]−z_e)`. Not requantized (Eq. 5 runs in
/// float). `keep` masks output rows.
pub fn qlinear_bwd_weight(
    e: &QTensor,
    x: &QTensor,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let n_out = e.len();
    let n_in = x.len();
    let ze = e.qp.zero_point;
    let zx = x.qp.zero_point;
    let s = e.qp.scale * x.qp.scale;
    let ed = e.values.data();
    let xd = x.values.data();

    let mut gw = TensorF32::zeros(&[n_out, n_in]);
    let mut gb = TensorF32::zeros(&[n_out]);
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        let ev = ed[o] as i32 - ze;
        gb.data_mut()[o] = ev as f32 * e.qp.scale;
        if ev == 0 {
            continue;
        }
        let row = gw.outer_mut(o);
        for (gv, xv) in row.iter_mut().zip(xd.iter()) {
            *gv = (ev * (*xv as i32 - zx)) as f32 * s;
        }
    }

    ops.int_macs += kept * n_in as u64;
    ops.float_ops += kept * n_in as u64;
    ops.bytes += (n_out + n_in + n_out * n_in * 4) as u64;
    (gw, gb)
}

/// GEMM-routed weight gradient, **bit-exact** with [`qlinear_bwd_weight`]:
/// the outer product `∇W = e·xᵀ` is a rank-1 A·Bᵀ GEMM
/// ([`gemm::gemm_abt_u8_i32`] with reduction depth 1); `keep` skips masked
/// rows as whole GEMM rows. Each element is the same single i32 product the
/// scalar kernel computes, scaled to float once.
pub fn qlinear_bwd_weight_gemm(
    e: &QTensor,
    x: &QTensor,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    qlinear_bwd_weight_gemm_sel(KernelSel::Auto, e, x, keep, scratch, ops)
}

/// [`qlinear_bwd_weight_gemm`] with an explicit kernel selection.
pub fn qlinear_bwd_weight_gemm_sel(
    sel: KernelSel,
    e: &QTensor,
    x: &QTensor,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let n_out = e.len();
    let n_in = x.len();
    let ze = e.qp.zero_point;
    let zx = x.qp.zero_point;
    let s = e.qp.scale * x.qp.scale;

    let mut gw = TensorF32::zeros(&[n_out, n_in]);
    let mut gb = TensorF32::zeros(&[n_out]);
    {
        let (_, _, acc, _) = scratch.qconv_bwd_bufs(0, 0, n_out * n_in, 0);
        gemm::gemm_abt_u8_i32_sel(
            sel,
            e.values.data(),
            ze,
            x.values.data(),
            zx,
            n_out,
            n_in,
            1,
            keep,
            acc,
        );
        for (g, &a) in gw.data_mut().iter_mut().zip(acc.iter()) {
            *g = a as f32 * s;
        }
    }

    let ed = e.values.data();
    let gbd = gb.data_mut();
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        gbd[o] = (ed[o] as i32 - ze) as f32 * e.qp.scale;
    }

    ops.int_macs += kept * n_in as u64;
    ops.float_ops += kept * n_in as u64;
    ops.bytes += (n_out + n_in + n_out * n_in * 4) as u64;
    (gw, gb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    fn rand_case(rng: &mut Pcg32, n_in: usize, n_out: usize) -> (TensorF32, TensorF32, Vec<f32>) {
        let mut x = TensorF32::zeros(&[n_in]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut w = TensorF32::zeros(&[n_out, n_in]);
        rng.fill_normal(w.data_mut(), 0.3);
        let b: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();
        (x, w, b)
    }

    #[test]
    fn fwd_tracks_float_matvec() {
        let mut rng = Pcg32::seeded(21);
        let (n_in, n_out) = (32, 10);
        let (x, w, b) = rand_case(&mut rng, n_in, n_out);
        let mut yref = vec![0f32; n_out];
        for o in 0..n_out {
            yref[o] = b[o] + (0..n_in).map(|i| w.data()[o * n_in + i] * x.data()[i]).sum::<f32>();
        }
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&w);
        let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        let oqp = QParams::observe(&yref);
        let mut ops = OpCounter::new();
        let y = qlinear_fwd(&xq, &wq, &bq, oqp, false, &mut ops).dequantize();
        for (a, r) in y.data().iter().zip(yref.iter()) {
            assert!((a - r).abs() < 3.0 * oqp.scale + 0.05, "{a} vs {r}");
        }
        assert_eq!(ops.int_macs, (n_in * n_out) as u64);
    }

    #[test]
    fn bwd_input_tracks_float_wt_e() {
        let mut rng = Pcg32::seeded(22);
        let (n_in, n_out) = (24, 12);
        let (_, w, _) = rand_case(&mut rng, n_in, n_out);
        let mut e = TensorF32::zeros(&[n_out]);
        rng.fill_normal(e.data_mut(), 1.0);
        let mut eref = vec![0f32; n_in];
        for i in 0..n_in {
            eref[i] = (0..n_out).map(|o| w.data()[o * n_in + i] * e.data()[o]).sum();
        }
        let eq = QTensor::quantize(&e);
        let wq = QTensor::quantize(&w);
        let oqp = QParams::observe(&eref);
        let mut ops = OpCounter::new();
        let got = qlinear_bwd_input(&eq, &wq, oqp, None, &mut ops).dequantize();
        for (a, r) in got.data().iter().zip(eref.iter()) {
            assert!((a - r).abs() < 4.0 * oqp.scale + 0.1, "{a} vs {r}");
        }
    }

    #[test]
    fn bwd_weight_is_outer_product() {
        let mut rng = Pcg32::seeded(23);
        let (n_in, n_out) = (16, 8);
        let mut x = TensorF32::zeros(&[n_in]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut e = TensorF32::zeros(&[n_out]);
        rng.fill_normal(e.data_mut(), 1.0);
        let eq = QTensor::quantize(&e);
        let xq = QTensor::quantize(&x);
        let mut ops = OpCounter::new();
        let (gw, gb) = qlinear_bwd_weight(&eq, &xq, None, &mut ops);
        for o in 0..n_out {
            for i in 0..n_in {
                let want = e.data()[o] * x.data()[i];
                let got = gw.data()[o * n_in + i];
                assert!((got - want).abs() < 0.1, "{got} vs {want}");
            }
            assert!((gb.data()[o] - e.data()[o]).abs() < eq.qp.scale);
        }
    }

    #[test]
    fn sparse_mask_rows_skipped_exactly() {
        let mut rng = Pcg32::seeded(24);
        let (n_in, n_out) = (10, 6);
        let mut x = TensorF32::zeros(&[n_in]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut e = TensorF32::zeros(&[n_out]);
        rng.fill_normal(e.data_mut(), 1.0);
        let eq = QTensor::quantize(&e);
        let xq = QTensor::quantize(&x);
        let keep = vec![true, false, false, true, false, true];
        let mut ops = OpCounter::new();
        let (gw, gb) = qlinear_bwd_weight(&eq, &xq, Some(&keep), &mut ops);
        for o in 0..n_out {
            let all_zero = gw.outer(o).iter().all(|&v| v == 0.0) && gb.data()[o] == 0.0;
            assert_eq!(all_zero, !keep[o]);
        }
        assert_eq!(ops.int_macs, 3 * n_in as u64);
    }

    /// Property: both GEMM-routed backward kernels are bit-exact with the
    /// scalar references across random sizes and masks, with identical op
    /// accounting.
    #[test]
    fn prop_gemm_bwd_bit_exact_with_scalar() {
        Prop::new(48).check(
            |r: &mut Pcg32| (1 + r.below(48) as usize, 1 + r.below(24) as usize, r.next_u64()),
            |&(i, o, s)| {
                let mut v = Vec::new();
                for i2 in shrink_dim(i, 1) {
                    v.push((i2, o, s));
                }
                for o2 in shrink_dim(o, 1) {
                    v.push((i, o2, s));
                }
                v
            },
            |&(n_in, n_out, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let (x, w, _) = rand_case(&mut rng, n_in, n_out);
                let mut e = TensorF32::zeros(&[n_out]);
                rng.fill_normal(e.data_mut(), 1.0);
                let eq = QTensor::quantize(&e);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&w);
                let keep: Option<Vec<bool>> = match seed % 3 {
                    0 => None,
                    1 => Some((0..n_out).map(|_| rng.below(2) == 1).collect()),
                    _ => Some(vec![false; n_out]),
                };
                let keep = keep.as_deref();
                let mut scratch = crate::memplan::Scratch::new();

                let mut ops_s = OpCounter::new();
                let mut ops_g = OpCounter::new();
                let (gws, gbs) = qlinear_bwd_weight(&eq, &xq, keep, &mut ops_s);
                let (gwg, gbg) = qlinear_bwd_weight_gemm(&eq, &xq, keep, &mut scratch, &mut ops_g);
                if gws.data() != gwg.data() || gbs.data() != gbg.data() {
                    return Err("GEMM weight gradient differs from scalar".into());
                }
                if ops_s != ops_g {
                    return Err("bwd_weight op accounting differs".into());
                }

                let oqp = QParams::from_min_max(-2.0, 2.0);
                let mut ops_s2 = OpCounter::new();
                let mut ops_g2 = OpCounter::new();
                let es = qlinear_bwd_input(&eq, &wq, oqp, keep, &mut ops_s2);
                let eg = qlinear_bwd_input_gemm(&eq, &wq, oqp, keep, &mut scratch, &mut ops_g2);
                if es.values.data() != eg.values.data() {
                    return Err("GEMM input gradient differs from scalar".into());
                }
                if ops_s2 != ops_g2 {
                    return Err("bwd_input op accounting differs".into());
                }
                Ok(())
            },
        );
    }

    /// The fused kernels are bit-exact with the unfused oracles: output
    /// bytes, op accounting, the emitted dequantization and the saturation
    /// count all match a post-hoc sweep over the unfused result.
    #[test]
    fn fused_kernels_bit_exact_with_unfused() {
        let mut rng = Pcg32::seeded(77);
        for &(n_in, n_out, relu) in &[(32usize, 10usize, true), (17, 23, false), (1, 1, true)] {
            let (x, w, b) = rand_case(&mut rng, n_in, n_out);
            let xq = QTensor::quantize(&x);
            let wq = QTensor::quantize(&w);
            let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
            let oqp = QParams::from_min_max(-2.0, 2.0);

            let mut ops_u = OpCounter::new();
            let mut ops_f = OpCounter::new();
            let yu = qlinear_fwd(&xq, &wq, &bq, oqp, relu, &mut ops_u);
            let mut deq = vec![0f32; n_out];
            let (yf, sat) =
                qlinear_fwd_fused(&xq, &wq, &bq, oqp, relu, Some(&mut deq), &mut ops_f);
            assert_eq!(yu.values.data(), yf.values.data());
            assert_eq!(ops_u, ops_f);
            assert_eq!(deq, yu.dequantize().data());
            let want_sat = yu
                .values
                .data()
                .iter()
                .filter(|&&v| v == 255 || (!relu && v == 0))
                .count() as u64;
            assert_eq!(sat, want_sat);

            let mut e = TensorF32::zeros(&[n_out]);
            rng.fill_normal(e.data_mut(), 1.0);
            let eq = QTensor::quantize(&e);
            let mut scratch = crate::memplan::Scratch::new();
            for keep in [None, Some((0..n_out).map(|i| i % 2 == 0).collect::<Vec<_>>())] {
                let keep = keep.as_deref();
                let mut ops_u = OpCounter::new();
                let mut ops_f = OpCounter::new();
                let eu = qlinear_bwd_input_gemm(&eq, &wq, oqp, keep, &mut scratch, &mut ops_u);
                let ef =
                    qlinear_bwd_input_gemm_fused(&eq, &wq, oqp, keep, &mut scratch, &mut ops_f);
                assert_eq!(eu.values.data(), ef.values.data());
                assert_eq!(ops_u, ops_f);
            }
        }
    }

    /// Every `_pa_sel` kernel must be bit-identical to its u8 twin running
    /// on `PackedQTensor::to_qtensor` of the same packed weights, at every
    /// width and mask, with identical op accounting.
    #[test]
    fn packed_linear_paths_bit_exact_with_u8_twin() {
        use crate::quant::subbyte::WBits;
        let mut rng = Pcg32::seeded(91);
        let mut scratch = crate::memplan::Scratch::new();
        let oqp = QParams::from_min_max(-2.0, 2.0);
        for &(n_in, n_out, relu) in &[(32usize, 10usize, true), (17, 23, false), (1, 1, true)] {
            let (x, w, b) = rand_case(&mut rng, n_in, n_out);
            let xq = QTensor::quantize(&x);
            let mut e = TensorF32::zeros(&[n_out]);
            rng.fill_normal(e.data_mut(), 1.0);
            let eq = QTensor::quantize(&e);

            for bits in [WBits::W8, WBits::W4, WBits::W2] {
                let pw = PackedQTensor::quantize_bits(&w, bits);
                let wq = pw.to_qtensor();
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);

                let mut ops_a = OpCounter::new();
                let mut ops_b = OpCounter::new();
                let ya = qlinear_fwd(&xq, &wq, &bq, oqp, relu, &mut ops_a);
                let yb = qlinear_fwd_pa_sel(
                    KernelSel::Auto,
                    &xq,
                    &pw,
                    &bq,
                    oqp,
                    relu,
                    &mut scratch,
                    &mut ops_b,
                );
                assert_eq!(ya.values.data(), yb.values.data(), "fwd {bits:?}");
                assert_eq!(ops_a, ops_b, "fwd ops {bits:?}");

                let mut deq_a = vec![0f32; n_out];
                let mut deq_b = vec![0f32; n_out];
                let mut ops_fa = OpCounter::new();
                let mut ops_fb = OpCounter::new();
                let (yfa, sat_a) =
                    qlinear_fwd_fused(&xq, &wq, &bq, oqp, relu, Some(&mut deq_a), &mut ops_fa);
                let (yfb, sat_b) = qlinear_fwd_fused_pa_sel(
                    KernelSel::Auto,
                    &xq,
                    &pw,
                    &bq,
                    oqp,
                    relu,
                    Some(&mut deq_b),
                    &mut scratch,
                    &mut ops_fb,
                );
                assert_eq!(yfa.values.data(), yfb.values.data(), "fused fwd {bits:?}");
                assert_eq!(sat_a, sat_b, "fused sat {bits:?}");
                assert_eq!(ops_fa, ops_fb, "fused fwd ops {bits:?}");
                assert_eq!(deq_a, deq_b, "dequant emit {bits:?}");

                for keep in [None, Some((0..n_out).map(|i| i % 2 == 0).collect::<Vec<_>>())] {
                    let keep = keep.as_deref();
                    let mut ops_ba = OpCounter::new();
                    let mut ops_bb = OpCounter::new();
                    let ea =
                        qlinear_bwd_input_gemm(&eq, &wq, oqp, keep, &mut scratch, &mut ops_ba);
                    let eb = qlinear_bwd_input_gemm_pa_sel(
                        KernelSel::Auto,
                        &eq,
                        &pw,
                        oqp,
                        keep,
                        &mut scratch,
                        &mut ops_bb,
                    );
                    assert_eq!(ea.values.data(), eb.values.data(), "bwd {bits:?}");
                    assert_eq!(ops_ba, ops_bb, "bwd ops {bits:?}");

                    let mut ops_ga = OpCounter::new();
                    let mut ops_gb = OpCounter::new();
                    let fa = qlinear_bwd_input_gemm_fused(
                        &eq, &wq, oqp, keep, &mut scratch, &mut ops_ga,
                    );
                    let fb = qlinear_bwd_input_gemm_fused_pa_sel(
                        KernelSel::Auto,
                        &eq,
                        &pw,
                        oqp,
                        keep,
                        &mut scratch,
                        &mut ops_gb,
                    );
                    assert_eq!(fa.values.data(), fb.values.data(), "fused bwd {bits:?}");
                    assert_eq!(ops_ga, ops_gb, "fused bwd ops {bits:?}");
                }
            }
        }
    }

    #[test]
    fn prop_fwd_output_in_quant_range() {
        Prop::new(48).check(
            |r: &mut Pcg32| (1 + r.below(64) as usize, 1 + r.below(32) as usize, r.next_u64()),
            |&(i, o, s)| {
                let mut v = Vec::new();
                for i2 in shrink_dim(i, 1) {
                    v.push((i2, o, s));
                }
                for o2 in shrink_dim(o, 1) {
                    v.push((i, o2, s));
                }
                v
            },
            |&(n_in, n_out, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let (x, w, b) = rand_case(&mut rng, n_in, n_out);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&w);
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
                let oqp = QParams::from_min_max(-2.0, 2.0);
                let mut ops = OpCounter::new();
                let y = qlinear_fwd(&xq, &wq, &bq, oqp, true, &mut ops);
                if y.len() != n_out {
                    return Err("bad output length".into());
                }
                for &v in y.values.data() {
                    if (v as i32) < oqp.zero_point {
                        return Err(format!("relu floor violated: {v}"));
                    }
                }
                Ok(())
            },
        );
    }
}
