//! Softmax cross-entropy head.
//!
//! The loss and the initial error `E_N = softmax(logits) − onehot(label)`
//! are always computed in float (a K-element vector — negligible cost even
//! on the Cortex-M0+). For the fully quantized configuration the logits
//! arrive as a dequantized uint8 tensor and the initial error is immediately
//! requantized with the head error observer's parameters; for the mixed /
//! float configurations it stays in float.

use crate::kernels::OpCounter;
use crate::quant::{QParams, QTensor};
use crate::tensor::TensorF32;

/// Numerically stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

/// Cross-entropy loss and the initial backward error, in float.
/// Returns `(loss, probs, error)` with `error = probs − onehot(label)`.
pub fn softmax_ce(logits: &[f32], label: usize, ops: &mut OpCounter) -> (f32, Vec<f32>, TensorF32) {
    assert!(label < logits.len(), "label out of range");
    let probs = softmax(logits);
    let loss = -(probs[label].max(1e-12)).ln();
    let mut err = probs.clone();
    err[label] -= 1.0;
    ops.float_ops += 4 * logits.len() as u64;
    (loss, probs, TensorF32::from_vec(&[logits.len()], err))
}

/// Quantized head entry point: dequantize logits, compute loss/error in
/// float, requantize the error at `err_qp` (the head error observer's
/// current parameters). Returns `(loss, probs, quantized error, float
/// error)` — the float error is what the observer should be fed.
pub fn softmax_ce_q(
    logits: &QTensor,
    label: usize,
    err_qp: QParams,
    ops: &mut OpCounter,
) -> (f32, Vec<f32>, QTensor, TensorF32) {
    let lf = logits.dequantize();
    let (loss, probs, err_f) = softmax_ce(lf.data(), label, ops);
    let err_q = QTensor::quantize_with(&err_f, err_qp);
    ops.int_ops += err_f.len() as u64;
    (loss, probs, err_q, err_f)
}

/// Top-1 prediction from logits.
pub fn predict(logits: &[f32]) -> usize {
    crate::util::stats::argmax(logits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let p = softmax(&[1000.0, 1000.0, 1000.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-6));
    }

    #[test]
    fn ce_loss_decreases_with_confidence() {
        let mut ops = OpCounter::new();
        let (l_bad, _, _) = softmax_ce(&[0.0, 0.0], 0, &mut ops);
        let (l_good, _, _) = softmax_ce(&[5.0, 0.0], 0, &mut ops);
        assert!(l_good < l_bad);
        assert!((l_bad - (2f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn error_is_probs_minus_onehot() {
        let mut ops = OpCounter::new();
        let (_, probs, err) = softmax_ce(&[1.0, 2.0, 3.0], 1, &mut ops);
        assert!((err.data()[0] - probs[0]).abs() < 1e-6);
        assert!((err.data()[1] - (probs[1] - 1.0)).abs() < 1e-6);
        // errors sum to zero
        assert!(err.data().iter().sum::<f32>().abs() < 1e-6);
    }

    #[test]
    fn error_matches_finite_difference_of_loss() {
        let logits = [0.3f32, -0.7, 1.1, 0.2];
        let label = 2;
        let mut ops = OpCounter::new();
        let (_, _, err) = softmax_ce(&logits, label, &mut ops);
        let eps = 1e-3;
        for i in 0..logits.len() {
            let mut lp = logits;
            lp[i] += eps;
            let mut lm = logits;
            lm[i] -= eps;
            let (l1, _, _) = softmax_ce(&lp, label, &mut ops);
            let (l2, _, _) = softmax_ce(&lm, label, &mut ops);
            let num = (l1 - l2) / (2.0 * eps);
            assert!((num - err.data()[i]).abs() < 1e-3, "{num} vs {}", err.data()[i]);
        }
    }

    #[test]
    fn quantized_head_roundtrip() {
        let logits_f = TensorF32::from_vec(&[3], vec![0.5, -0.2, 1.5]);
        let lq = QTensor::quantize(&logits_f);
        let err_qp = QParams::from_min_max(-1.0, 1.0);
        let mut ops = OpCounter::new();
        let (loss, probs, err_q, err_f) = softmax_ce_q(&lq, 2, err_qp, &mut ops);
        assert!(loss > 0.0);
        assert_eq!(predict(&probs.iter().map(|&p| p).collect::<Vec<_>>()), 2);
        // quantized error tracks the float error
        for (q, f) in err_q.dequantize().data().iter().zip(err_f.data()) {
            assert!((q - f).abs() <= 0.5 * err_qp.scale + 1e-6);
        }
    }
}
