//! NEON lane kernels (aarch64 only). Same safety and numerics contract
//! as `simd::x86`: callers verify ISA support and bounds; u8/i32 paths
//! are exact (integer `vmlaq` is a true i32 multiply-accumulate), f32
//! paths use a separate `vmulq`/`vaddq` pair per `k` step so no FMA
//! contraction can change the scalar rounding.

#![allow(clippy::missing_safety_doc)]

use core::arch::aarch64::*;

use crate::kernels::gemm::{MR, NR};

/// Widen 16 bytes at `p` into four 4×i32 lanes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn load16_u8_s32(p: *const u8) -> [int32x4_t; 4] {
    let bytes = vld1q_u8(p);
    let lo = vmovl_u8(vget_low_u8(bytes));
    let hi = vmovl_u8(vget_high_u8(bytes));
    [
        vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(lo))),
        vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(lo))),
        vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(hi))),
        vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(hi))),
    ]
}

/// Widen 4 bytes at `p` into one 4×i32 lane.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn load4_u8_s32(p: *const u8) -> int32x4_t {
    let bytes = vreinterpret_u8_u32(vdup_n_u32(core::ptr::read_unaligned(p as *const u32)));
    vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(vmovl_u8(bytes))))
}

#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_u8_neon(
    acc: &mut [[i32; NR]; MR],
    mrr: usize,
    a: &[u8],
    arow0: usize,
    astride: usize,
    za: i32,
    b: &[u8],
    bcol0: usize,
    bstride: usize,
    zb: i32,
    k: usize,
) {
    let zbv = vdupq_n_s32(zb);
    let mut accv = [[vdupq_n_s32(0); 4]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = vld1q_s32(acc[ii].as_ptr().add(h * 4));
        }
    }
    for kk in 0..k {
        let mut bv = load16_u8_s32(b.as_ptr().add(bcol0 + kk * bstride));
        for lane in bv.iter_mut() {
            *lane = vsubq_s32(*lane, zbv);
        }
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = *a.get_unchecked(arow0 + ii * astride + kk) as i32 - za;
            for (lane, bl) in lanes.iter_mut().zip(bv.iter()) {
                // integer multiply-accumulate: exact i32 arithmetic
                *lane = vmlaq_n_s32(*lane, *bl, av);
            }
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            vst1q_s32(acc[ii].as_mut_ptr().add(h * 4), *lane);
        }
    }
}

#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_f32_neon(
    acc: &mut [[f32; NR]; MR],
    mrr: usize,
    a: &[f32],
    arow0: usize,
    astride: usize,
    b: &[f32],
    bcol0: usize,
    bstride: usize,
    k: usize,
) {
    let mut accv = [[vdupq_n_f32(0.0); 4]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = vld1q_f32(acc[ii].as_ptr().add(h * 4));
        }
    }
    for kk in 0..k {
        let bp = b.as_ptr().add(bcol0 + kk * bstride);
        let mut bv = [vdupq_n_f32(0.0); 4];
        for (h, lane) in bv.iter_mut().enumerate() {
            *lane = vld1q_f32(bp.add(h * 4));
        }
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = vdupq_n_f32(*a.get_unchecked(arow0 + ii * astride + kk));
            for (lane, bl) in lanes.iter_mut().zip(bv.iter()) {
                // separate mul + add (not vfmaq): keeps the scalar rounding
                *lane = vaddq_f32(*lane, vmulq_f32(av, *bl));
            }
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            vst1q_f32(acc[ii].as_mut_ptr().add(h * 4), *lane);
        }
    }
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn dot_u8_neon(a: &[u8], za: i32, b: &[u8], zb: i32) -> i32 {
    let k = a.len();
    let zav = vdupq_n_s32(za);
    let zbv = vdupq_n_s32(zb);
    let mut accv = vdupq_n_s32(0);
    let mut kk = 0;
    while kk + 4 <= k {
        let av = vsubq_s32(load4_u8_s32(a.as_ptr().add(kk)), zav);
        let bv = vsubq_s32(load4_u8_s32(b.as_ptr().add(kk)), zbv);
        accv = vmlaq_s32(accv, av, bv);
        kk += 4;
    }
    let mut sum = vaddvq_s32(accv);
    while kk < k {
        sum = sum
            .wrapping_add((*a.get_unchecked(kk) as i32 - za) * (*b.get_unchecked(kk) as i32 - zb));
        kk += 1;
    }
    sum
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy_u8_i32_neon(acc: &mut [i32], xs: &[u8], zx: i32, wv: i32) {
    let n = acc.len();
    let zxv = vdupq_n_s32(zx);
    let mut i = 0;
    while i + 4 <= n {
        let xv = vsubq_s32(load4_u8_s32(xs.as_ptr().add(i)), zxv);
        let av = vld1q_s32(acc.as_ptr().add(i));
        vst1q_s32(acc.as_mut_ptr().add(i), vmlaq_n_s32(av, xv, wv));
        i += 4;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * (*xs.get_unchecked(i) as i32 - zx);
        i += 1;
    }
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy_f32_neon(acc: &mut [f32], xs: &[f32], wv: f32) {
    let n = acc.len();
    let wvv = vdupq_n_f32(wv);
    let mut i = 0;
    while i + 4 <= n {
        let xv = vld1q_f32(xs.as_ptr().add(i));
        let av = vld1q_f32(acc.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(av, vmulq_f32(wvv, xv)));
        i += 4;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * *xs.get_unchecked(i);
        i += 1;
    }
}
