//! The plan-compile autotuner: a statically tabulated cost model that
//! maps a layer geometry to a [`TilePref`] per kernel direction.
//!
//! Contract (DESIGN.md §10): the model is *conservative and
//! machine-independent* — it only answers "is this shape wide enough
//! that the vector path can amortize its lane setup", never "how fast is
//! this host". Shapes it declares [`TilePref::Scalar`] are the
//! edge-dominated ones where the SIMD driver would spend most of its
//! time in the scalar edge-column branch anyway; that *is* the
//! edge-tile strategy — resolve the whole layer to the scalar
//! micro-kernel rather than pay dispatch for no vector work. The
//! thresholds are lane-width facts (8 i32 lanes on AVX2, 4 on
//! SSE4.1/NEON, NR = 16 columns per full tile), not measurements, so a
//! plan compiled on one host stays valid on another; `TT_KERNEL` exists
//! to override the table wholesale when a host disagrees.

use super::TilePref;
use crate::kernels::gemm::NR;

/// Preference for an `m × k × n` GEMM (C = A·B + init, row-major).
///
/// * `n >= NR`: at least one full 4×16 register tile per row block — the
///   vector tile kernel carries the inner loop.
/// * `n == 1`: the matvec path reduces each row with the lane dot
///   kernel; worthwhile once the reduction is at least two 8-lane
///   chunks.
/// * Everything else (`1 < n < NR`) runs entirely in the scalar edge
///   branch — keep the scalar micro-kernel.
pub fn prefer_gemm(m: usize, k: usize, n: usize) -> TilePref {
    let _ = m; // blocking is over n/k; m only changes how often tiles run
    if n == 1 {
        if k >= 16 {
            TilePref::Simd
        } else {
            TilePref::Scalar
        }
    } else if n >= NR {
        TilePref::Simd
    } else {
        TilePref::Scalar
    }
}

/// Preference for a length-`kd` zero-pointed dot reduction (A·Bᵀ weight
/// gradients, depthwise dW): two 8-lane chunks or one full SSE/NEON
/// stripe plus tail.
pub fn prefer_dot(kd: usize) -> TilePref {
    if kd >= 16 {
        TilePref::Simd
    } else {
        TilePref::Scalar
    }
}

/// Preference for stride-1 AXPY spans of width `span` (the depthwise
/// engine's inner loop): one full 8-lane chunk.
pub fn prefer_axpy(span: usize) -> TilePref {
    if span >= 8 {
        TilePref::Simd
    } else {
        TilePref::Scalar
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_table_matches_tile_geometry() {
        // MCUNet-style hot shapes all take the vector path…
        assert_eq!(prefer_gemm(16, 27, 1024), TilePref::Simd);
        assert_eq!(prefer_gemm(32, 144, 256), TilePref::Simd);
        assert_eq!(prefer_gemm(128, 64, 64), TilePref::Simd);
        // …the classifier matvec uses the dot kernel…
        assert_eq!(prefer_gemm(256, 512, 1), TilePref::Simd);
        assert_eq!(prefer_gemm(10, 8, 1), TilePref::Scalar);
        // …and edge-dominated shapes stay scalar.
        assert_eq!(prefer_gemm(64, 64, NR - 1), TilePref::Scalar);
        assert_eq!(prefer_gemm(64, 64, NR), TilePref::Simd);
    }

    #[test]
    fn dot_and_axpy_thresholds() {
        assert_eq!(prefer_dot(15), TilePref::Scalar);
        assert_eq!(prefer_dot(16), TilePref::Simd);
        assert_eq!(prefer_axpy(7), TilePref::Scalar);
        assert_eq!(prefer_axpy(8), TilePref::Simd);
    }
}
