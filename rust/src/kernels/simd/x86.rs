//! AVX2 and SSE4.1 lane kernels (x86_64 only). Safety contract for every
//! function here: the caller (the dispatch wrappers in `simd::mod`) has
//! verified the host supports the ISA and that all offsets stay in
//! bounds; the `debug_assert!`s there are the single source of truth.
//!
//! Numerics: u8/i32 kernels are exact (i32 lane arithmetic wraps exactly
//! like the scalar loop's two's-complement sums). f32 kernels use one
//! separate multiply and one separate add per `k` step — never an FMA —
//! so every output lane reproduces the scalar reduction bit-for-bit.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use crate::kernels::gemm::{MR, NR};

// -------------------------------- AVX2 ------------------------------------

#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_u8_avx2(
    acc: &mut [[i32; NR]; MR],
    mrr: usize,
    a: &[u8],
    arow0: usize,
    astride: usize,
    za: i32,
    b: &[u8],
    bcol0: usize,
    bstride: usize,
    zb: i32,
    k: usize,
) {
    let zbv = _mm256_set1_epi32(zb);
    let mut accv = [[_mm256_setzero_si256(); 2]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = _mm256_loadu_si256(acc[ii].as_ptr().add(h * 8) as *const __m256i);
        }
    }
    for kk in 0..k {
        let bp = b.as_ptr().add(bcol0 + kk * bstride);
        let b0 = _mm256_sub_epi32(
            _mm256_cvtepu8_epi32(_mm_loadl_epi64(bp as *const __m128i)),
            zbv,
        );
        let b1 = _mm256_sub_epi32(
            _mm256_cvtepu8_epi32(_mm_loadl_epi64(bp.add(8) as *const __m128i)),
            zbv,
        );
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = _mm256_set1_epi32(*a.get_unchecked(arow0 + ii * astride + kk) as i32 - za);
            lanes[0] = _mm256_add_epi32(lanes[0], _mm256_mullo_epi32(av, b0));
            lanes[1] = _mm256_add_epi32(lanes[1], _mm256_mullo_epi32(av, b1));
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            _mm256_storeu_si256(acc[ii].as_mut_ptr().add(h * 8) as *mut __m256i, *lane);
        }
    }
}

#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_f32_avx2(
    acc: &mut [[f32; NR]; MR],
    mrr: usize,
    a: &[f32],
    arow0: usize,
    astride: usize,
    b: &[f32],
    bcol0: usize,
    bstride: usize,
    k: usize,
) {
    let mut accv = [[_mm256_setzero_ps(); 2]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = _mm256_loadu_ps(acc[ii].as_ptr().add(h * 8));
        }
    }
    for kk in 0..k {
        let bp = b.as_ptr().add(bcol0 + kk * bstride);
        let b0 = _mm256_loadu_ps(bp);
        let b1 = _mm256_loadu_ps(bp.add(8));
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = _mm256_set1_ps(*a.get_unchecked(arow0 + ii * astride + kk));
            // separate mul + add: keeps the scalar rounding (no FMA)
            lanes[0] = _mm256_add_ps(lanes[0], _mm256_mul_ps(av, b0));
            lanes[1] = _mm256_add_ps(lanes[1], _mm256_mul_ps(av, b1));
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            _mm256_storeu_ps(acc[ii].as_mut_ptr().add(h * 8), *lane);
        }
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_u8_avx2(a: &[u8], za: i32, b: &[u8], zb: i32) -> i32 {
    let k = a.len();
    let zav = _mm256_set1_epi32(za);
    let zbv = _mm256_set1_epi32(zb);
    let mut accv = _mm256_setzero_si256();
    let mut kk = 0;
    while kk + 8 <= k {
        let av = _mm256_sub_epi32(
            _mm256_cvtepu8_epi32(_mm_loadl_epi64(a.as_ptr().add(kk) as *const __m128i)),
            zav,
        );
        let bv = _mm256_sub_epi32(
            _mm256_cvtepu8_epi32(_mm_loadl_epi64(b.as_ptr().add(kk) as *const __m128i)),
            zbv,
        );
        accv = _mm256_add_epi32(accv, _mm256_mullo_epi32(av, bv));
        kk += 8;
    }
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, accv);
    let mut sum = lanes.iter().fold(0i32, |s, &v| s.wrapping_add(v));
    while kk < k {
        sum = sum
            .wrapping_add((*a.get_unchecked(kk) as i32 - za) * (*b.get_unchecked(kk) as i32 - zb));
        kk += 1;
    }
    sum
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_u8_i32_avx2(acc: &mut [i32], xs: &[u8], zx: i32, wv: i32) {
    let n = acc.len();
    let wvv = _mm256_set1_epi32(wv);
    let zxv = _mm256_set1_epi32(zx);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm256_sub_epi32(
            _mm256_cvtepu8_epi32(_mm_loadl_epi64(xs.as_ptr().add(i) as *const __m128i)),
            zxv,
        );
        let av = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi32(av, _mm256_mullo_epi32(wvv, xv)),
        );
        i += 8;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * (*xs.get_unchecked(i) as i32 - zx);
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_f32_avx2(acc: &mut [f32], xs: &[f32], wv: f32) {
    let n = acc.len();
    let wvv = _mm256_set1_ps(wv);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(xs.as_ptr().add(i));
        let av = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(av, _mm256_mul_ps(wvv, xv)));
        i += 8;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * *xs.get_unchecked(i);
        i += 1;
    }
}

// ------------------------------- SSE4.1 ------------------------------------

/// Widen 4 bytes at `p` to 4×i32 lanes (SSE4.1 `pmovzxbd`).
#[inline]
#[target_feature(enable = "sse4.1")]
unsafe fn load4_u8_epi32(p: *const u8) -> __m128i {
    _mm_cvtepu8_epi32(_mm_cvtsi32_si128(core::ptr::read_unaligned(p as *const i32)))
}

#[target_feature(enable = "sse4.1")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_u8_sse41(
    acc: &mut [[i32; NR]; MR],
    mrr: usize,
    a: &[u8],
    arow0: usize,
    astride: usize,
    za: i32,
    b: &[u8],
    bcol0: usize,
    bstride: usize,
    zb: i32,
    k: usize,
) {
    let zbv = _mm_set1_epi32(zb);
    let mut accv = [[_mm_setzero_si128(); 4]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = _mm_loadu_si128(acc[ii].as_ptr().add(h * 4) as *const __m128i);
        }
    }
    for kk in 0..k {
        let bp = b.as_ptr().add(bcol0 + kk * bstride);
        let mut bv = [_mm_setzero_si128(); 4];
        for (h, lane) in bv.iter_mut().enumerate() {
            *lane = _mm_sub_epi32(load4_u8_epi32(bp.add(h * 4)), zbv);
        }
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = _mm_set1_epi32(*a.get_unchecked(arow0 + ii * astride + kk) as i32 - za);
            for (lane, bl) in lanes.iter_mut().zip(bv.iter()) {
                *lane = _mm_add_epi32(*lane, _mm_mullo_epi32(av, *bl));
            }
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            _mm_storeu_si128(acc[ii].as_mut_ptr().add(h * 4) as *mut __m128i, *lane);
        }
    }
}

#[target_feature(enable = "sse4.1")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn tile_f32_sse41(
    acc: &mut [[f32; NR]; MR],
    mrr: usize,
    a: &[f32],
    arow0: usize,
    astride: usize,
    b: &[f32],
    bcol0: usize,
    bstride: usize,
    k: usize,
) {
    let mut accv = [[_mm_setzero_ps(); 4]; MR];
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter_mut().enumerate() {
            *lane = _mm_loadu_ps(acc[ii].as_ptr().add(h * 4));
        }
    }
    for kk in 0..k {
        let bp = b.as_ptr().add(bcol0 + kk * bstride);
        let mut bv = [_mm_setzero_ps(); 4];
        for (h, lane) in bv.iter_mut().enumerate() {
            *lane = _mm_loadu_ps(bp.add(h * 4));
        }
        for (ii, lanes) in accv[..mrr].iter_mut().enumerate() {
            let av = _mm_set1_ps(*a.get_unchecked(arow0 + ii * astride + kk));
            for (lane, bl) in lanes.iter_mut().zip(bv.iter()) {
                *lane = _mm_add_ps(*lane, _mm_mul_ps(av, *bl));
            }
        }
    }
    for ii in 0..mrr {
        for (h, lane) in accv[ii].iter().enumerate() {
            _mm_storeu_ps(acc[ii].as_mut_ptr().add(h * 4), *lane);
        }
    }
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn dot_u8_sse41(a: &[u8], za: i32, b: &[u8], zb: i32) -> i32 {
    let k = a.len();
    let zav = _mm_set1_epi32(za);
    let zbv = _mm_set1_epi32(zb);
    let mut accv = _mm_setzero_si128();
    let mut kk = 0;
    while kk + 4 <= k {
        let av = _mm_sub_epi32(load4_u8_epi32(a.as_ptr().add(kk)), zav);
        let bv = _mm_sub_epi32(load4_u8_epi32(b.as_ptr().add(kk)), zbv);
        accv = _mm_add_epi32(accv, _mm_mullo_epi32(av, bv));
        kk += 4;
    }
    let mut lanes = [0i32; 4];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, accv);
    let mut sum = lanes.iter().fold(0i32, |s, &v| s.wrapping_add(v));
    while kk < k {
        sum = sum
            .wrapping_add((*a.get_unchecked(kk) as i32 - za) * (*b.get_unchecked(kk) as i32 - zb));
        kk += 1;
    }
    sum
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn axpy_u8_i32_sse41(acc: &mut [i32], xs: &[u8], zx: i32, wv: i32) {
    let n = acc.len();
    let wvv = _mm_set1_epi32(wv);
    let zxv = _mm_set1_epi32(zx);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm_sub_epi32(load4_u8_epi32(xs.as_ptr().add(i)), zxv);
        let av = _mm_loadu_si128(acc.as_ptr().add(i) as *const __m128i);
        _mm_storeu_si128(
            acc.as_mut_ptr().add(i) as *mut __m128i,
            _mm_add_epi32(av, _mm_mullo_epi32(wvv, xv)),
        );
        i += 4;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * (*xs.get_unchecked(i) as i32 - zx);
        i += 1;
    }
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn axpy_f32_sse41(acc: &mut [f32], xs: &[f32], wv: f32) {
    let n = acc.len();
    let wvv = _mm_set1_ps(wv);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm_loadu_ps(xs.as_ptr().add(i));
        let av = _mm_loadu_ps(acc.as_ptr().add(i));
        _mm_storeu_ps(acc.as_mut_ptr().add(i), _mm_add_ps(av, _mm_mul_ps(wvv, xv)));
        i += 4;
    }
    while i < n {
        *acc.get_unchecked_mut(i) += wv * *xs.get_unchecked(i);
        i += 1;
    }
}
