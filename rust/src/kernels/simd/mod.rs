//! Runtime-dispatched SIMD micro-kernel back ends for the GEMM and
//! depthwise engines (`kernels::gemm`, `kernels::dwconv`).
//!
//! The scalar MR×NR micro-kernels (PRs 4–6) were shaped so their inner
//! loops vectorize; this module adds the explicit `core::arch` lanes —
//! AVX2 and SSE4.1 on x86_64, NEON on aarch64 — behind one-time runtime
//! feature detection. The design contract, in order of precedence:
//!
//! 1. **The scalar micro-kernel stays the oracle.** Every SIMD path is
//!    bit-identical on the u8/i32 kernels (i32 accumulation is
//!    order-independent, including the fused [`QEpilogue`] writeout —
//!    the epilogue is a pure per-element map over exact sums), and
//!    bit-identical on the f32 GEMM/AXPY paths too, because each output
//!    lane keeps the scalar kernel's ascending-`k` accumulation order
//!    with a separate multiply and add per step (never FMA — fusing
//!    would change the rounding). f32 *reductions* that a SIMD schedule
//!    would have to reassociate (`gemm_abt_f32`, the float depthwise
//!    weight-gradient dots) have **no** SIMD path at all.
//! 2. **Detection is one-time.** [`isa`] probes the host once and caches
//!    the result in a `OnceLock`; every kernel call is a table lookup,
//!    never a CPUID.
//! 3. **Dispatch is layered.** [`KernelMode`] (the `TT_KERNEL` override,
//!    also settable through the typed `RunConfig`) is the *global*
//!    policy; [`TilePref`] is the *per-shape* autotuned preference the
//!    plan compiler caches next to a layer's weight packs
//!    (`graph::packs::KernelChoice`); [`resolve`] combines the two into
//!    the [`KernelSel`] a kernel call actually executes. `TilePref` is
//!    deliberately mode-independent so a cached plan stays valid when
//!    `TT_KERNEL` is flipped in-process (the parity tests do exactly
//!    that).
//!
//! On ISAs with no SIMD path (or when detection fails) everything
//! resolves to the scalar micro-kernels — the default build compiles
//! unchanged everywhere and stays zero-dependency.
//!
//! [`QEpilogue`]: crate::kernels::gemm::QEpilogue

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::gemm::{MR, NR};
use crate::quant::subbyte::{self, WBits};

pub mod tune;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

// The lane splits below hard-code the 4×16 register block (NR = 2×8 AVX2
// lanes = 4×4 SSE/NEON lanes); a tile-size change must revisit them.
const _: () = assert!(MR == 4 && NR == 16, "SIMD tiles are written for the 4x16 block");

/// The instruction sets a host can dispatch to. All variants exist on
/// every build target (so `KernelSel` has one shape everywhere); [`isa`]
/// only ever returns the ones the current architecture can run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// x86_64 AVX2 (8×i32 / 8×f32 lanes).
    Avx2,
    /// x86_64 SSE4.1 (4×i32 / 4×f32 lanes).
    Sse41,
    /// aarch64 NEON (4×i32 / 4×f32 lanes).
    Neon,
}

static ISA: OnceLock<Option<Isa>> = OnceLock::new();

/// The best SIMD instruction set the host supports, probed once and
/// cached (`None` on architectures without a SIMD path here).
pub fn isa() -> Option<Isa> {
    *ISA.get_or_init(detect)
}

fn detect() -> Option<Isa> {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Some(Isa::Avx2);
        }
        if is_x86_feature_detected!("sse4.1") {
            return Some(Isa::Sse41);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(Isa::Neon);
        }
    }
    None
}

/// The global dispatch policy — the `TT_KERNEL=scalar|simd|auto` knob,
/// exposed through the typed `RunConfig` as well.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// Per-shape choice: a layer runs SIMD only where the plan-compile
    /// autotuner ([`tune`]) tabulated a win (the default).
    #[default]
    Auto,
    /// Force the scalar micro-kernels everywhere (the oracle path).
    Scalar,
    /// Force SIMD wherever a vector path exists, ignoring the autotuner
    /// (falls back to scalar only where no SIMD kernel exists at all).
    Simd,
}

impl KernelMode {
    /// Parse a `TT_KERNEL` value. Unknown strings are `None` (callers
    /// default to [`KernelMode::Auto`]).
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelMode::Auto),
            "scalar" => Some(KernelMode::Scalar),
            "simd" => Some(KernelMode::Simd),
            _ => None,
        }
    }

    fn from_env() -> KernelMode {
        std::env::var("TT_KERNEL").ok().and_then(|v| KernelMode::parse(&v)).unwrap_or_default()
    }
}

// 0 = unset (read TT_KERNEL on first use), then 1/2/3 = Auto/Scalar/Simd.
static MODE: AtomicU8 = AtomicU8::new(0);

/// The current global kernel mode. Initialized lazily from `TT_KERNEL`
/// on first use; [`set_mode`] overrides it in-process (the typed
/// `RunConfig` path, and the forced-dispatch parity tests).
pub fn mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        0 => {
            let m = KernelMode::from_env();
            set_mode(m);
            m
        }
        2 => KernelMode::Scalar,
        3 => KernelMode::Simd,
        _ => KernelMode::Auto,
    }
}

/// Set the global kernel mode, overriding `TT_KERNEL`.
pub fn set_mode(m: KernelMode) {
    let v = match m {
        KernelMode::Auto => 1,
        KernelMode::Scalar => 2,
        KernelMode::Simd => 3,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// The per-shape kernel preference the plan-compile autotuner tabulates
/// ([`tune`]) and the pack cache stores per layer. Mode-independent on
/// purpose: under `TT_KERNEL=scalar|simd` the global mode wins, so a
/// cached plan never needs recompiling when the mode flips.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TilePref {
    /// Edge-dominated or tiny shape: the scalar micro-kernel wins.
    #[default]
    Scalar,
    /// Vector-friendly shape: take the SIMD path when the host has one.
    Simd,
}

/// What one kernel call actually executes — the parameter of the `_sel`
/// kernel twins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSel {
    /// Resolve inside the kernel from the shape at hand (the old-name
    /// wrappers; call sites without a plan-cached choice).
    Auto,
    /// The scalar micro-kernel (oracle path).
    Scalar,
    /// The SIMD path on the given instruction set.
    Simd(Isa),
}

/// Combine the global [`mode`] with a per-shape [`TilePref`] into the
/// selection a kernel call executes.
pub fn resolve(pref: TilePref) -> KernelSel {
    match mode() {
        KernelMode::Scalar => KernelSel::Scalar,
        KernelMode::Simd => match isa() {
            Some(i) => KernelSel::Simd(i),
            None => KernelSel::Scalar,
        },
        KernelMode::Auto => match (pref, isa()) {
            (TilePref::Simd, Some(i)) => KernelSel::Simd(i),
            _ => KernelSel::Scalar,
        },
    }
}

/// Resolve a `_sel` parameter to a concrete ISA (or scalar = `None`),
/// using `pref` only when the caller passed [`KernelSel::Auto`].
pub fn resolve_isa(sel: KernelSel, pref: TilePref) -> Option<Isa> {
    let sel = match sel {
        KernelSel::Auto => resolve(pref),
        s => s,
    };
    match sel {
        KernelSel::Simd(i) => Some(i),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Dispatch wrappers: safe entry points over the per-ISA unsafe kernels.
// Each carries the bounds contract as debug asserts; the `_` arms (ISAs
// the current architecture cannot return) fall back to the scalar loop so
// the match stays exhaustive on every build target.
// ---------------------------------------------------------------------------

/// Full-width u8/i32 accumulator tile:
/// `acc[ii][jj] += Σ_kk (a[arow0 + ii·astride + kk] − za) ·
/// (b[bcol0 + kk·bstride + jj] − zb)` for `ii < mrr`, `jj < NR`.
/// Exact i32 sums — bit-identical to the scalar tile for any lane
/// schedule.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile_u8(
    isa: Isa,
    acc: &mut [[i32; NR]; MR],
    mrr: usize,
    a: &[u8],
    arow0: usize,
    astride: usize,
    za: i32,
    b: &[u8],
    bcol0: usize,
    bstride: usize,
    zb: i32,
    k: usize,
) {
    debug_assert!(mrr >= 1 && mrr <= MR);
    debug_assert!(k == 0 || arow0 + (mrr - 1) * astride + k <= a.len());
    debug_assert!(k == 0 || bcol0 + (k - 1) * bstride + NR <= b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            x86::tile_u8_avx2(acc, mrr, a, arow0, astride, za, b, bcol0, bstride, zb, k)
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => unsafe {
            x86::tile_u8_sse41(acc, mrr, a, arow0, astride, za, b, bcol0, bstride, zb, k)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::tile_u8_neon(acc, mrr, a, arow0, astride, za, b, bcol0, bstride, zb, k)
        },
        _ => tile_u8_scalar(acc, mrr, a, arow0, astride, za, b, bcol0, bstride, zb, k),
    }
}

/// Full-width f32 tile: `acc[ii][jj] += a[arow0 + ii·astride + kk] ·
/// b[bcol0 + kk·bstride + jj]`, ascending `kk`, one separate multiply and
/// add per step — every output lane keeps the scalar kernel's reduction
/// order, so results are bit-identical (no FMA anywhere).
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile_f32(
    isa: Isa,
    acc: &mut [[f32; NR]; MR],
    mrr: usize,
    a: &[f32],
    arow0: usize,
    astride: usize,
    b: &[f32],
    bcol0: usize,
    bstride: usize,
    k: usize,
) {
    debug_assert!(mrr >= 1 && mrr <= MR);
    debug_assert!(k == 0 || arow0 + (mrr - 1) * astride + k <= a.len());
    debug_assert!(k == 0 || bcol0 + (k - 1) * bstride + NR <= b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            x86::tile_f32_avx2(acc, mrr, a, arow0, astride, b, bcol0, bstride, k)
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => unsafe {
            x86::tile_f32_sse41(acc, mrr, a, arow0, astride, b, bcol0, bstride, k)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            neon::tile_f32_neon(acc, mrr, a, arow0, astride, b, bcol0, bstride, k)
        },
        _ => tile_f32_scalar(acc, mrr, a, arow0, astride, b, bcol0, bstride, k),
    }
}

/// Zero-pointed u8 dot product `Σ (a[i] − za)(b[i] − zb)` — the matvec
/// row kernel (`n == 1` GEMMs) and the A·Bᵀ / depthwise-dW reduction.
/// i32 partial-lane sums are exact under any reordering.
pub(crate) fn dot_u8(isa: Option<Isa>, a: &[u8], za: i32, b: &[u8], zb: i32) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Avx2) => unsafe { x86::dot_u8_avx2(a, za, b, zb) },
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Sse41) => unsafe { x86::dot_u8_sse41(a, za, b, zb) },
        #[cfg(target_arch = "aarch64")]
        Some(Isa::Neon) => unsafe { neon::dot_u8_neon(a, za, b, zb) },
        _ => dot_u8_scalar(a, za, b, zb),
    }
}

/// Quantized AXPY span `acc[i] += wv · (xs[i] − zx)` — the depthwise
/// engine's stride-1 inner loop. Element-wise (no cross-lane reduction),
/// so exact for any lane width.
pub(crate) fn axpy_u8_i32(isa: Option<Isa>, acc: &mut [i32], xs: &[u8], zx: i32, wv: i32) {
    debug_assert_eq!(acc.len(), xs.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Avx2) => unsafe { x86::axpy_u8_i32_avx2(acc, xs, zx, wv) },
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Sse41) => unsafe { x86::axpy_u8_i32_sse41(acc, xs, zx, wv) },
        #[cfg(target_arch = "aarch64")]
        Some(Isa::Neon) => unsafe { neon::axpy_u8_i32_neon(acc, xs, zx, wv) },
        _ => {
            for (a, &xv) in acc.iter_mut().zip(xs.iter()) {
                *a += wv * (xv as i32 - zx);
            }
        }
    }
}

/// Float AXPY span `acc[i] += wv · xs[i]` — the float depthwise engine's
/// stride-1 inner loop. Per element it is the same single multiply and
/// add the scalar loop performs (element-wise, never reassociated), so
/// results are bit-identical.
pub(crate) fn axpy_f32(isa: Option<Isa>, acc: &mut [f32], xs: &[f32], wv: f32) {
    debug_assert_eq!(acc.len(), xs.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Avx2) => unsafe { x86::axpy_f32_avx2(acc, xs, wv) },
        #[cfg(target_arch = "x86_64")]
        Some(Isa::Sse41) => unsafe { x86::axpy_f32_sse41(acc, xs, wv) },
        #[cfg(target_arch = "aarch64")]
        Some(Isa::Neon) => unsafe { neon::axpy_f32_neon(acc, xs, wv) },
        _ => {
            for (a, &xv) in acc.iter_mut().zip(xs.iter()) {
                *a += wv * xv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sub-byte lane unpacking (packed INT4/INT2 weights -> plain u8 lanes).
// The vector twin is SWAR — plain u64 word parallelism, no intrinsics —
// so it compiles on every target; it still sits behind the KernelSel
// dispatch so TT_KERNEL=scalar pins the per-lane oracle loop exactly
// like every other kernel pair.
// ---------------------------------------------------------------------------

/// Spread 4 packed INT4 bytes (8 lanes, LSB-first) into 8 output bytes.
#[inline(always)]
fn spread_nibbles(x: u32) -> u64 {
    let mut t = x as u64;
    t = (t | (t << 16)) & 0x0000_FFFF_0000_FFFF;
    t = (t | (t << 8)) & 0x00FF_00FF_00FF_00FF;
    (t | (t << 4)) & 0x0F0F_0F0F_0F0F_0F0F
}

/// Spread 2 packed INT2 bytes (8 lanes, LSB-first) into 8 output bytes.
#[inline(always)]
fn spread_crumbs(x: u16) -> u64 {
    let mut t = x as u64;
    t = (t | (t << 24)) & 0x0000_00FF_0000_00FF;
    t = (t | (t << 12)) & 0x000F_000F_000F_000F;
    (t | (t << 6)) & 0x0303_0303_0303_0303
}

/// Word-parallel (SWAR) unpack of `len` packed sub-byte lanes into
/// `dst[..len]` — the vector twin of
/// [`subbyte::unpack_lanes`](crate::quant::subbyte::unpack_lanes),
/// bit-identical to it by the property suite. Eight lanes are produced
/// per u64 store; the sub-word tail falls back to per-lane extraction.
pub fn unpack_lanes_swar(packed: &[u8], len: usize, bits: WBits, dst: &mut [u8]) {
    assert!(dst.len() >= len, "unpack dst {} too small for {len} lanes", dst.len());
    let full = len / 8;
    match bits {
        WBits::W8 => {
            dst[..len].copy_from_slice(&packed[..len]);
            return;
        }
        WBits::W4 => {
            let srcs = packed[..full * 4].chunks_exact(4);
            for (src, out) in srcs.zip(dst[..full * 8].chunks_exact_mut(8)) {
                let x = u32::from_le_bytes([src[0], src[1], src[2], src[3]]);
                out.copy_from_slice(&spread_nibbles(x).to_le_bytes());
            }
        }
        WBits::W2 => {
            let srcs = packed[..full * 2].chunks_exact(2);
            for (src, out) in srcs.zip(dst[..full * 8].chunks_exact_mut(8)) {
                let x = u16::from_le_bytes([src[0], src[1]]);
                out.copy_from_slice(&spread_crumbs(x).to_le_bytes());
            }
        }
    }
    for (i, d) in dst[..len].iter_mut().enumerate().skip(full * 8) {
        *d = subbyte::extract_lane(packed, i, bits);
    }
}

/// Dispatching unpack: the entry point the packed-weight (`_pa`) kernel
/// twins use to materialize u8 lanes ahead of the A-pack. Same layering
/// as every `_sel` kernel: [`KernelSel::Scalar`] pins the per-lane
/// oracle, [`KernelSel::Simd`] (or an [`KernelSel::Auto`] resolution to
/// it) takes the SWAR word path. Both are bit-identical; W8 is a straight
/// copy on either path.
pub fn unpack_lanes_sel(sel: KernelSel, packed: &[u8], len: usize, bits: WBits, dst: &mut [u8]) {
    match resolve_isa(sel, TilePref::Simd) {
        Some(_) => unpack_lanes_swar(packed, len, bits, dst),
        None => subbyte::unpack_lanes(packed, len, bits, dst),
    }
}

// ---------------------------------------------------------------------------
// Scalar fallbacks for the unreachable-ISA match arms (and non-SIMD
// architectures). Same loops as the micro-kernels' full-tile branches.
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn tile_u8_scalar(
    acc: &mut [[i32; NR]; MR],
    mrr: usize,
    a: &[u8],
    arow0: usize,
    astride: usize,
    za: i32,
    b: &[u8],
    bcol0: usize,
    bstride: usize,
    zb: i32,
    k: usize,
) {
    for kk in 0..k {
        let boff = bcol0 + kk * bstride;
        let brow = &b[boff..boff + NR];
        for ii in 0..mrr {
            let av = a[arow0 + ii * astride + kk] as i32 - za;
            let ai = &mut acc[ii];
            for jj in 0..NR {
                ai[jj] += av * (brow[jj] as i32 - zb);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn tile_f32_scalar(
    acc: &mut [[f32; NR]; MR],
    mrr: usize,
    a: &[f32],
    arow0: usize,
    astride: usize,
    b: &[f32],
    bcol0: usize,
    bstride: usize,
    k: usize,
) {
    for kk in 0..k {
        let boff = bcol0 + kk * bstride;
        let brow = &b[boff..boff + NR];
        for ii in 0..mrr {
            let av = a[arow0 + ii * astride + kk];
            let ai = &mut acc[ii];
            for jj in 0..NR {
                ai[jj] += av * brow[jj];
            }
        }
    }
}

fn dot_u8_scalar(a: &[u8], za: i32, b: &[u8], zb: i32) -> i32 {
    let mut sum = 0i32;
    for (&av, &bv) in a.iter().zip(b.iter()) {
        sum = sum.wrapping_add((av as i32 - za).wrapping_mul(bv as i32 - zb));
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn mode_parse_round_trip() {
        assert_eq!(KernelMode::parse("auto"), Some(KernelMode::Auto));
        assert_eq!(KernelMode::parse("SCALAR"), Some(KernelMode::Scalar));
        assert_eq!(KernelMode::parse(" simd "), Some(KernelMode::Simd));
        assert_eq!(KernelMode::parse("avx512"), None);
    }

    #[test]
    fn resolve_honors_forced_modes() {
        let prev = mode();
        set_mode(KernelMode::Scalar);
        assert_eq!(resolve(TilePref::Simd), KernelSel::Scalar);
        set_mode(KernelMode::Simd);
        match isa() {
            Some(i) => assert_eq!(resolve(TilePref::Scalar), KernelSel::Simd(i)),
            None => assert_eq!(resolve(TilePref::Scalar), KernelSel::Scalar),
        }
        set_mode(KernelMode::Auto);
        assert_eq!(resolve(TilePref::Scalar), KernelSel::Scalar);
        set_mode(prev);
    }

    #[test]
    fn detection_is_stable() {
        assert_eq!(isa(), isa());
    }

    /// Every SIMD span/dot/tile helper must be bit-identical to its
    /// scalar fallback on the host's detected ISA (vacuous on non-SIMD
    /// hosts).
    #[test]
    fn span_helpers_match_scalar_on_host_isa() {
        let Some(i) = isa() else { return };
        let mut rng = Pcg32::seeded(9);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 64] {
            let xs: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let ys: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            assert_eq!(
                dot_u8(Some(i), &xs, 3, &ys, 7),
                dot_u8_scalar(&xs, 3, &ys, 7),
                "dot_u8 len {len}"
            );

            let base: Vec<i32> = (0..len).map(|_| rng.below(1000) as i32 - 500).collect();
            let mut simd_acc = base.clone();
            let mut ref_acc = base.clone();
            axpy_u8_i32(Some(i), &mut simd_acc, &xs, 3, -5);
            axpy_u8_i32(None, &mut ref_acc, &xs, 3, -5);
            assert_eq!(simd_acc, ref_acc, "axpy_u8_i32 len {len}");

            let xf: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let basef: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let mut sf = basef.clone();
            let mut rf = basef.clone();
            axpy_f32(Some(i), &mut sf, &xf, 0.37);
            axpy_f32(None, &mut rf, &xf, 0.37);
            let sb: Vec<u32> = sf.iter().map(|v| v.to_bits()).collect();
            let rb: Vec<u32> = rf.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sb, rb, "axpy_f32 len {len}");
        }
    }

    /// The SWAR word unpacker must be bit-identical to the scalar
    /// per-lane oracle at every width, for lengths straddling every word
    /// and byte boundary (including the MR/NR±1 edge-tile counts).
    #[test]
    fn swar_unpack_matches_scalar_oracle() {
        let mut rng = Pcg32::seeded(17);
        for bits in [WBits::W8, WBits::W4, WBits::W2] {
            for len in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 63, 65, 100] {
                let packed: Vec<u8> =
                    (0..bits.packed_len(len)).map(|_| rng.below(256) as u8).collect();
                let mut swar = vec![0xAAu8; len];
                let mut scalar = vec![0x55u8; len];
                unpack_lanes_swar(&packed, len, bits, &mut swar);
                subbyte::unpack_lanes(&packed, len, bits, &mut scalar);
                assert_eq!(swar, scalar, "{bits:?} len {len}");
            }
        }
    }

    /// `unpack_lanes_sel` produces identical lanes under every forced
    /// mode (the dispatch seam itself cannot change values).
    #[test]
    fn unpack_sel_is_mode_invariant() {
        let mut rng = Pcg32::seeded(23);
        let prev = mode();
        for bits in [WBits::W4, WBits::W2] {
            let len = 37;
            let packed: Vec<u8> = (0..bits.packed_len(len)).map(|_| rng.below(256) as u8).collect();
            let mut want = vec![0u8; len];
            subbyte::unpack_lanes(&packed, len, bits, &mut want);
            for m in [KernelMode::Auto, KernelMode::Scalar, KernelMode::Simd] {
                set_mode(m);
                for sel in [KernelSel::Auto, KernelSel::Scalar] {
                    let mut got = vec![0u8; len];
                    unpack_lanes_sel(sel, &packed, len, bits, &mut got);
                    assert_eq!(got, want, "{bits:?} mode {m:?} sel {sel:?}");
                }
                if let Some(i) = isa() {
                    let mut got = vec![0u8; len];
                    unpack_lanes_sel(KernelSel::Simd(i), &packed, len, bits, &mut got);
                    assert_eq!(got, want, "{bits:?} mode {m:?} forced simd");
                }
            }
        }
        set_mode(prev);
    }

    #[test]
    fn tiles_match_scalar_on_host_isa() {
        let Some(i) = isa() else { return };
        let mut rng = Pcg32::seeded(11);
        for k in [1usize, 2, 5, 8, 31] {
            for mrr in 1..=MR {
                let a: Vec<u8> = (0..MR * k).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..k * NR).map(|_| rng.below(256) as u8).collect();
                let mut t_simd = [[7i32; NR]; MR];
                let mut t_ref = [[7i32; NR]; MR];
                tile_u8(i, &mut t_simd, mrr, &a, 0, k, 3, &b, 0, NR, 5, k);
                tile_u8_scalar(&mut t_ref, mrr, &a, 0, k, 3, &b, 0, NR, 5, k);
                assert_eq!(t_simd, t_ref, "tile_u8 k={k} mrr={mrr}");

                let af: Vec<f32> = (0..MR * k).map(|_| rng.normal()).collect();
                let bf: Vec<f32> = (0..k * NR).map(|_| rng.normal()).collect();
                let mut f_simd = [[0.25f32; NR]; MR];
                let mut f_ref = [[0.25f32; NR]; MR];
                tile_f32(i, &mut f_simd, mrr, &af, 0, k, &bf, 0, NR, k);
                tile_f32_scalar(&mut f_ref, mrr, &af, 0, k, &bf, 0, NR, k);
                let sb: Vec<u32> =
                    f_simd.iter().flat_map(|r| r.iter().map(|v| v.to_bits())).collect();
                let rb: Vec<u32> =
                    f_ref.iter().flat_map(|r| r.iter().map(|v| v.to_bits())).collect();
                assert_eq!(sb, rb, "tile_f32 k={k} mrr={mrr}");
            }
        }
    }
}
