//! im2col + cache-blocked GEMM: the batched execution engine's compute
//! core.
//!
//! Non-depthwise convolutions are lowered to one matrix multiply per
//! sample: the input feature map `[Cin, H, W]` is packed into a column
//! matrix `col[Cin·Kh·Kw, Oh·Ow]` (padding positions filled with the input
//! zero point, so they contribute `(z_x − z_x)(w − z_w) = 0`, exactly like
//! the scalar kernels' skip), and the weight tensor is viewed as
//! `[Cout, Cin·Kh·Kw]` — already its storage layout. The product is
//! accumulated in i32 (exact, order-independent), so the GEMM path is
//! **bit-exact** with the scalar reference kernels in `qconv`; the float
//! twin accumulates in ascending-k order, matching the scalar float
//! kernel's `(ci, ky, kx)` nesting so results are value-identical.
//!
//! Blocking: the inner loop is an AXPY over a contiguous row of `col`
//! (vectorizable u8→i32 widening multiply-add); the `k` and `n` loops are
//! tiled so one output tile and the `col` rows feeding it stay cache
//! resident. The scalar kernels remain in `qconv`/`fconv` as the
//! MCU-faithful reference — this module is the host-side fast path.
//!
//! Scratch buffers come from [`crate::memplan::Scratch`]: the sequential
//! training loop allocates one arena per run, batch workers one per
//! spawned worker (i.e. per minibatch × worker) — in both cases the
//! buffers are reused across every layer and sample they serve.

/// Columns per output tile (i32 accumulator row bytes ≈ 4·NC per m-row).
const NC: usize = 256;
/// Rows of `col` (reduction depth) per tile.
const KC: usize = 128;

/// Pack a `[Cin, H, W]` feature map into `col[Cin·Kh·Kw, Oh·Ow]`.
///
/// Row `(ci·Kh + ky)·Kw + kx`, column `oy·Ow + ox` holds the input value at
/// `(ci, oy·stride + ky − pad_h, ox·stride + kx − pad_w)`, or `pad` when
/// that position falls outside the map. One generic body serves both
/// element types so the index math cannot drift between the integer and
/// float engines (their bit-exactness contracts share this packing).
fn im2col<T: Copy>(
    xd: &[T],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    pad: T,
    col: &mut [T],
) {
    let n = oh * ow;
    assert_eq!(col.len(), geom.cin * geom.kh * geom.kw * n, "im2col buffer size");
    assert_eq!(xd.len(), geom.cin * h * w, "input size");
    let mut r = 0usize;
    for ci in 0..geom.cin {
        let plane = &xd[ci * h * w..(ci + 1) * h * w];
        for ky in 0..geom.kh {
            for kx in 0..geom.kw {
                let dst = &mut col[r * n..(r + 1) * n];
                let mut p = 0usize;
                for oy in 0..oh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[p..p + ow].fill(pad);
                        p += ow;
                        continue;
                    }
                    let rowbase = iy as usize * w;
                    for ox in 0..ow {
                        let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                        dst[p] = if ix < 0 || ix >= w as isize {
                            pad
                        } else {
                            plane[rowbase + ix as usize]
                        };
                        p += 1;
                    }
                }
                r += 1;
            }
        }
    }
}

/// u8 im2col. With `pad` = the input zero point, padded entries contribute
/// exactly zero to the integer GEMM (matching the scalar kernels' skip).
pub fn im2col_u8(
    xd: &[u8],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    pad: u8,
    col: &mut [u8],
) {
    im2col(xd, h, w, geom, oh, ow, pad, col);
}

/// Float twin of [`im2col_u8`]; padding positions are 0.0.
pub fn im2col_f32(
    xd: &[f32],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    col: &mut [f32],
) {
    im2col(xd, h, w, geom, oh, ow, 0.0, col);
}

/// Tiled integer GEMM with per-operand zero points:
/// `out[m·n] = row_init[m] + Σ_k (a[m·k] − za)·(b[k·n] − zb)`.
///
/// Accumulation is i32 and exact, so the result is independent of the tile
/// schedule — bit-identical to any naive triple loop over the same
/// operands. The inner loop is an AXPY over a contiguous `b` row segment
/// (the im2col layout makes the spatial dimension innermost), which the
/// compiler vectorizes; rows of `a` equal to the zero point are skipped.
pub fn gemm_u8_i32(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    for (mr, orow) in out.chunks_exact_mut(n).enumerate() {
        orow.fill(row_init[mr]);
    }
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NC).min(n);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KC).min(k);
            for mr in 0..m {
                let arow = &a[mr * k..(mr + 1) * k];
                let orow = &mut out[mr * n + nb..mr * n + ne];
                for kk in kb..ke {
                    let av = arow[kk] as i32 - za;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b[kk * n + nb..kk * n + ne];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * (bv as i32 - zb);
                    }
                }
            }
            kb = ke;
        }
        nb = ne;
    }
}

/// Tiled f32 GEMM: `out[m·n] = row_init[m] + Σ_k a[m·k]·b[k·n]`.
///
/// Per output element the products are added in ascending-`k` order
/// (tiles ascend, `k` ascends within a tile), which matches the scalar
/// float conv's `(ci, ky, kx)` loop nesting — results are value-identical
/// to the reference kernel (padded entries add an exact `a·0.0`).
pub fn gemm_f32(
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    for (mr, orow) in out.chunks_exact_mut(n).enumerate() {
        orow.fill(row_init[mr]);
    }
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NC).min(n);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KC).min(k);
            for mr in 0..m {
                let arow = &a[mr * k..(mr + 1) * k];
                let orow = &mut out[mr * n + nb..mr * n + ne];
                for kk in kb..ke {
                    let av = arow[kk];
                    let brow = &b[kk * n + nb..kk * n + ne];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
            kb = ke;
        }
        nb = ne;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvGeom;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    fn naive_gemm_i32(
        a: &[u8],
        za: i32,
        b: &[u8],
        zb: i32,
        init: &[i32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mr in 0..m {
            for nc in 0..n {
                let mut acc = init[mr];
                for kk in 0..k {
                    acc += (a[mr * k + kk] as i32 - za) * (b[kk * n + nc] as i32 - zb);
                }
                out[mr * n + nc] = acc;
            }
        }
        out
    }

    #[test]
    fn prop_tiled_gemm_matches_naive_triple_loop() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                // spans the tile boundaries: k and n around KC/NC
                let m = 1 + r.below(9) as usize;
                let k = 1 + r.below(300) as usize;
                let n = 1 + r.below(600) as usize;
                (m, k, n, r.next_u64())
            },
            |&(m, k, n, s)| {
                let mut v = Vec::new();
                for m2 in shrink_dim(m, 1) {
                    v.push((m2, k, n, s));
                }
                for k2 in shrink_dim(k, 1) {
                    v.push((m, k2, n, s));
                }
                for n2 in shrink_dim(n, 1) {
                    v.push((m, k, n2, s));
                }
                v
            },
            |&(m, k, n, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
                let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
                let mut out = vec![0i32; m * n];
                gemm_u8_i32(&a, za, &b, zb, &init, m, k, n, &mut out);
                let want = naive_gemm_i32(&a, za, &b, zb, &init, m, k, n);
                if out != want {
                    return Err("tiled result differs from naive triple loop".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gemm_f32_matches_naive_order() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, n) = (4, 150, 300); // crosses both tile boundaries
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut b, 0.5);
        let init: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; m * n];
        gemm_f32(&a, &b, &init, m, k, n, &mut out);
        for mr in 0..m {
            for nc in 0..n {
                let mut acc = init[mr];
                for kk in 0..k {
                    acc += a[mr * k + kk] * b[kk * n + nc];
                }
                // ascending-k accumulation on both sides -> exactly equal
                assert_eq!(out[mr * n + nc], acc, "({mr},{nc})");
            }
        }
    }

    #[test]
    fn im2col_identity_for_pointwise() {
        // 1x1/stride-1/no-pad im2col is the identity layout [Cin, H·W]
        let g = ConvGeom {
            cin: 3,
            cout: 1,
            kh: 1,
            kw: 1,
            stride: 1,
            pad_h: 0,
            pad_w: 0,
            depthwise: false,
        };
        let xd: Vec<u8> = (0..3 * 4 * 4).map(|v| v as u8).collect();
        let mut col = vec![0u8; 3 * 16];
        im2col_u8(&xd, 4, 4, &g, 4, 4, 99, &mut col);
        assert_eq!(col, xd);
    }

    #[test]
    fn im2col_pads_with_zero_point() {
        let g = ConvGeom {
            cin: 1,
            cout: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let xd = vec![10u8; 4]; // 2x2 map
        let mut col = vec![0u8; 9 * 4];
        im2col_u8(&xd, 2, 2, &g, 2, 2, 7, &mut col);
        // row (ky=0,kx=0), output (0,0) reads input (-1,-1) -> pad
        assert_eq!(col[0], 7);
        // center tap (ky=1,kx=1) reads the map itself
        let center = &col[4 * 4..5 * 4];
        assert_eq!(center, &[10, 10, 10, 10]);
        // 2x2 map, 3x3 kernel, pad 1: each of the 4 output positions sees
        // 4 in-bounds taps -> 16 of the 36 col entries are real values
        let in_bounds = col.iter().filter(|&&v| v == 10).count();
        assert_eq!(in_bounds, 16);
    }

    #[test]
    fn empty_dims_are_safe() {
        let mut out: Vec<i32> = Vec::new();
        gemm_u8_i32(&[], 0, &[], 0, &[], 0, 0, 3, &mut out);
        let mut out2 = vec![1i32; 2];
        // k == 0: output is just row_init
        gemm_u8_i32(&[], 3, &[], 4, &[7, -7], 2, 0, 1, &mut out2);
        assert_eq!(out2, vec![7, -7]);
    }
}
