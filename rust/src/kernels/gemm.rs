//! im2col + register-blocked GEMM: the batched execution engine's compute
//! core.
//!
//! Non-depthwise convolutions are lowered to one matrix multiply per
//! sample: the input feature map `[Cin, H, W]` is packed into a column
//! matrix `col[Cin·Kh·Kw, Oh·Ow]` (padding positions filled with the input
//! zero point, so they contribute `(z_x − z_x)(w − z_w) = 0`, exactly like
//! the scalar kernels' skip), and the weight tensor is viewed as
//! `[Cout, Cin·Kh·Kw]` — already its storage layout. The product is
//! accumulated in i32 (exact, order-independent), so the GEMM path is
//! **bit-exact** with the scalar reference kernels in `qconv`; the float
//! twin accumulates in ascending-k order, matching the scalar float
//! kernel's `(ci, ky, kx)` nesting so results are value-identical.
//!
//! Blocking: the compute core is an **MR×NR register-blocked
//! micro-kernel** ([`MR`]×[`NR`]): an MR×NR accumulator tile lives in a
//! fixed-size local array (registers after unrolling) and the k-loop
//! streams one A-column slice and one B-row slice per step — CMSIS-NN-
//! style register tiling, host-sized. The integer tile is exact i32 (any
//! accumulation order gives the bit-identical result); the float tile
//! accumulates each output element in ascending-`k` order, preserving the
//! value-identity contract with the scalar kernels' `(ci, ky, kx)`
//! nesting. Edge tiles (M/N remainders) run the same loops with clamped
//! bounds. The pre-micro-kernel cache-blocked path is retained as
//! [`gemm_u8_i32_tiled`]/[`gemm_f32_tiled`] — the property-test oracle and
//! the bench baseline the micro-kernels are measured against. The scalar
//! kernels remain in `qconv`/`fconv` as the MCU-faithful reference — this
//! module is the host-side fast path.
//!
//! Dispatch: every public GEMM has a `_sel` twin taking a
//! [`KernelSel`] — `Auto` (resolve from the global `TT_KERNEL` mode and
//! the [`tune`] shape table), `Scalar` (the `*_scalar` oracles below), or
//! `Simd(isa)` (the `kernels::simd` lane drivers). The old names forward
//! `Auto`, so existing call sites transparently pick up runtime dispatch;
//! the layer ops pass the plan-compile autotuned choice instead. See
//! DESIGN.md §10.
//!
//! Scratch buffers come from [`crate::memplan::Scratch`]: the sequential
//! training loop allocates one arena per run, batch workers one per
//! spawned worker (i.e. per minibatch × worker) — in both cases the
//! buffers are reused across every layer and sample they serve.

use super::simd::{self, tune, Isa, KernelSel};
use crate::quant::subbyte::{self, WBits};
use crate::quant::{requantize, QParams};

/// Columns per output tile of the retained cache-blocked reference path
/// (i32 accumulator row bytes ≈ 4·NC per m-row).
const NC: usize = 256;
/// Rows of `col` (reduction depth) per tile of the cache-blocked path.
const KC: usize = 128;

/// Micro-kernel tile rows: output rows whose accumulators are held
/// simultaneously. 4 rows × 16 columns = 64 i32 accumulators — two
/// AVX2 register files' worth, small enough that LLVM keeps the tile in
/// registers after unrolling, large enough to amortize every B-element
/// load across MR rows.
pub const MR: usize = 4;
/// Micro-kernel tile columns (contiguous along the B/output rows, so the
/// inner loop is a unit-stride widening multiply-add).
pub const NR: usize = 16;

/// Pack a `[Cin, H, W]` feature map into `col[Cin·Kh·Kw, Oh·Ow]`.
///
/// Row `(ci·Kh + ky)·Kw + kx`, column `oy·Ow + ox` holds the input value at
/// `(ci, oy·stride + ky − pad_h, ox·stride + kx − pad_w)`, or `pad` when
/// that position falls outside the map. One generic body serves both
/// element types so the index math cannot drift between the integer and
/// float engines (their bit-exactness contracts share this packing).
fn im2col<T: Copy>(
    xd: &[T],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    pad: T,
    col: &mut [T],
) {
    let n = oh * ow;
    assert_eq!(col.len(), geom.cin * geom.kh * geom.kw * n, "im2col buffer size");
    assert_eq!(xd.len(), geom.cin * h * w, "input size");
    let mut r = 0usize;
    for ci in 0..geom.cin {
        let plane = &xd[ci * h * w..(ci + 1) * h * w];
        for ky in 0..geom.kh {
            for kx in 0..geom.kw {
                let dst = &mut col[r * n..(r + 1) * n];
                // At stride 1 the in-bounds ox range maps to a contiguous
                // input span (ix = ox + kx − pad_w), so interior row
                // segments are one memcpy; only the padded borders fall
                // back to fills. Byte-identical to the per-element loop.
                let (lo, hi) = if geom.stride == 1 {
                    let lo = geom.pad_w.saturating_sub(kx).min(ow);
                    let hi = (w + geom.pad_w).saturating_sub(kx).min(ow).max(lo);
                    (lo, hi)
                } else {
                    (0, 0)
                };
                let mut p = 0usize;
                for oy in 0..oh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        dst[p..p + ow].fill(pad);
                        p += ow;
                        continue;
                    }
                    let rowbase = iy as usize * w;
                    if geom.stride == 1 {
                        dst[p..p + lo].fill(pad);
                        if hi > lo {
                            let src = rowbase + lo + kx - geom.pad_w;
                            dst[p + lo..p + hi].copy_from_slice(&plane[src..src + (hi - lo)]);
                        }
                        dst[p + hi..p + ow].fill(pad);
                        p += ow;
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                        dst[p] = if ix < 0 || ix >= w as isize {
                            pad
                        } else {
                            plane[rowbase + ix as usize]
                        };
                        p += 1;
                    }
                }
                r += 1;
            }
        }
    }
}

/// u8 im2col. With `pad` = the input zero point, padded entries contribute
/// exactly zero to the integer GEMM (matching the scalar kernels' skip).
pub fn im2col_u8(
    xd: &[u8],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    pad: u8,
    col: &mut [u8],
) {
    im2col(xd, h, w, geom, oh, ow, pad, col);
}

/// Float twin of [`im2col_u8`]; padding positions are 0.0.
pub fn im2col_f32(
    xd: &[f32],
    h: usize,
    w: usize,
    geom: &super::ConvGeom,
    oh: usize,
    ow: usize,
    col: &mut [f32],
) {
    im2col(xd, h, w, geom, oh, ow, 0.0, col);
}

/// Pack weights `[Cout, Cin, Kh, Kw]` into the flipped-transposed matrix
/// `wt[Cin, kept·Kh·Kw]` consumed by the backward-input GEMM: column
/// `(j·Kh + kyf)·Kw + kxf` of row `ci` holds `w[co_j, ci, Kh−1−kyf,
/// Kw−1−kxf]`, where `co_j` enumerates the **kept** output channels in
/// ascending order (all of them when `keep` is `None`).
///
/// Masked channels are dropped from the packing entirely, so they occupy no
/// GEMM rows at all — the Eq. 9 controller's `kept/total` ratio maps
/// one-to-one onto reduction-dimension length (proportional FLOP savings).
/// The kernel flip makes the GEMM's ascending-k accumulation visit
/// contributions in the scalar backward kernel's `(co, oy, ox)` order (see
/// [`im2col_bwd_f32`]), which is what keeps the float path value-identical.
///
/// Returns the number of kept channels.
fn pack_wt_flip<T: Copy>(
    wdat: &[T],
    geom: &super::ConvGeom,
    keep: Option<&[bool]>,
    dst: &mut [T],
) -> usize {
    assert!(!geom.depthwise, "flipped packing is defined for dense convs only");
    let (cin, kh, kw) = (geom.cin, geom.kh, geom.kw);
    assert_eq!(wdat.len(), geom.cout * cin * kh * kw, "weight size");
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }
    let kc = super::kept_count(keep, geom.cout);
    let krow = kc * kh * kw;
    assert_eq!(dst.len(), cin * krow, "packed buffer size");
    let mut j = 0usize;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        for ci in 0..cin {
            for kyf in 0..kh {
                let ky = kh - 1 - kyf;
                for kxf in 0..kw {
                    let kx = kw - 1 - kxf;
                    dst[ci * krow + (j * kh + kyf) * kw + kxf] =
                        wdat[((co * cin + ci) * kh + ky) * kw + kx];
                }
            }
        }
        j += 1;
    }
    kc
}

/// u8 flipped-transposed weight packing (see [`pack_wt_flip`]).
pub fn pack_wt_flip_u8(
    wdat: &[u8],
    geom: &super::ConvGeom,
    keep: Option<&[bool]>,
    dst: &mut [u8],
) -> usize {
    pack_wt_flip(wdat, geom, keep, dst)
}

/// f32 twin of [`pack_wt_flip_u8`].
pub fn pack_wt_flip_f32(
    wdat: &[f32],
    geom: &super::ConvGeom,
    keep: Option<&[bool]>,
    dst: &mut [f32],
) -> usize {
    pack_wt_flip(wdat, geom, keep, dst)
}

/// Packed-weight twin of [`pack_wt_flip_u8`]: reads the weight tensor
/// straight from its packed sub-byte representation and writes plain u8
/// lanes in the flipped-transposed layout. The source is addressed per
/// logical lane (`((co·Cin + ci)·Kh + ky)·Kw + kx` through
/// [`subbyte::extract_lane`]) because a kernel plane's base offset is not
/// byte-aligned at 2 or 4 lanes per byte. Bit-identical to unpacking the
/// whole tensor and running [`pack_wt_flip_u8`] (property-tested), and —
/// like that twin — masked channels occupy no rows at all.
pub fn pack_wt_flip_u8_pa(
    packed: &[u8],
    bits: WBits,
    geom: &super::ConvGeom,
    keep: Option<&[bool]>,
    dst: &mut [u8],
) -> usize {
    assert!(!geom.depthwise, "flipped packing is defined for dense convs only");
    let (cin, kh, kw) = (geom.cin, geom.kh, geom.kw);
    assert_eq!(packed.len(), bits.packed_len(geom.cout * cin * kh * kw), "packed weight size");
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }
    let kc = super::kept_count(keep, geom.cout);
    let krow = kc * kh * kw;
    assert_eq!(dst.len(), cin * krow, "packed buffer size");
    let mut j = 0usize;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        for ci in 0..cin {
            for kyf in 0..kh {
                let ky = kh - 1 - kyf;
                for kxf in 0..kw {
                    let kx = kw - 1 - kxf;
                    let lane = ((co * cin + ci) * kh + ky) * kw + kx;
                    dst[ci * krow + (j * kh + kyf) * kw + kxf] =
                        subbyte::extract_lane(packed, lane, bits);
                }
            }
        }
        j += 1;
    }
    kc
}

/// Pack the error map `[Cout, Oh, Ow]` into the backward column matrix
/// `col[kept·Kh·Kw, H·W]` (the im2col of the stride-dilated, edge-padded
/// error — the standard transposed-conv-as-correlation lowering). Row
/// `(j·Kh + kyf)·Kw + kxf`, column `iy·W + ix` holds `e[co_j, oy, ox]` with
/// `oy = (iy + pad_h − (Kh−1−kyf)) / stride` (and the analogous `ox`) when
/// that division is exact and in range, else `pad`.
///
/// Together with [`pack_wt_flip`] this computes `dX = wtᵀ_flip × col`
/// directly into the input layout — no separate col2im scatter pass. Masked
/// output channels are omitted from the packing (whole GEMM rows skipped).
fn im2col_bwd<T: Copy>(
    ed: &[T],
    oh: usize,
    ow: usize,
    geom: &super::ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    pad: T,
    col: &mut [T],
) {
    assert!(!geom.depthwise, "backward packing is defined for dense convs only");
    assert_eq!(ed.len(), geom.cout * oh * ow, "error size");
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }
    let kc = super::kept_count(keep, geom.cout);
    let n = in_h * in_w;
    assert_eq!(col.len(), kc * geom.kh * geom.kw * n, "backward col buffer size");
    let s = geom.stride as isize;
    let mut r = 0usize;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        let plane = &ed[co * oh * ow..(co + 1) * oh * ow];
        for kyf in 0..geom.kh {
            let ky = geom.kh - 1 - kyf;
            for kxf in 0..geom.kw {
                let kx = geom.kw - 1 - kxf;
                let dst = &mut col[r * n..(r + 1) * n];
                let mut p = 0usize;
                for iy in 0..in_h {
                    let ty = iy as isize + geom.pad_h as isize - ky as isize;
                    if ty < 0 || ty % s != 0 || ty / s >= oh as isize {
                        dst[p..p + in_w].fill(pad);
                        p += in_w;
                        continue;
                    }
                    let rowbase = (ty / s) as usize * ow;
                    for ix in 0..in_w {
                        let tx = ix as isize + geom.pad_w as isize - kx as isize;
                        dst[p] = if tx < 0 || tx % s != 0 || tx / s >= ow as isize {
                            pad
                        } else {
                            plane[rowbase + (tx / s) as usize]
                        };
                        p += 1;
                    }
                }
                r += 1;
            }
        }
    }
}

/// u8 backward im2col. With `pad` = the error zero point, padded and
/// stride-gap entries contribute exactly zero to the integer GEMM.
#[allow(clippy::too_many_arguments)]
pub fn im2col_bwd_u8(
    ed: &[u8],
    oh: usize,
    ow: usize,
    geom: &super::ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    pad: u8,
    col: &mut [u8],
) {
    im2col_bwd(ed, oh, ow, geom, in_h, in_w, keep, pad, col);
}

/// Float twin of [`im2col_bwd_u8`]; padding positions are 0.0 and add an
/// exact `w·0.0` to the GEMM sum.
#[allow(clippy::too_many_arguments)]
pub fn im2col_bwd_f32(
    ed: &[f32],
    oh: usize,
    ow: usize,
    geom: &super::ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    col: &mut [f32],
) {
    im2col_bwd(ed, oh, ow, geom, in_h, in_w, keep, 0.0, col);
}

/// Integer GEMM against a transposed B with per-row skipping:
/// `out[i·n + j] = Σ_k (a[i·kd + k] − za)·(b[j·kd + k] − zb)`, with rows `i`
/// where `keep[i]` is false left at zero (and their dot products never
/// computed — this is the whole-GEMM-row skip the sparse controller's
/// masks map onto).
///
/// Both operands are row-major over the shared reduction dimension, so each
/// output element is one contiguous dot product (the weight-gradient
/// lowering: A = error `[Cout, Oh·Ow]`, B = forward im2col `[Cin·Kh·Kw,
/// Oh·Ow]`). Accumulation is i32 and exact.
#[allow(clippy::too_many_arguments)]
pub fn gemm_abt_u8_i32(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    m: usize,
    n: usize,
    kd: usize,
    keep: Option<&[bool]>,
    out: &mut [i32],
) {
    gemm_abt_u8_i32_sel(KernelSel::Auto, a, za, b, zb, m, n, kd, keep, out);
}

/// [`gemm_abt_u8_i32`] with an explicit kernel selection. `Auto` resolves
/// from the global mode and the reduction-depth cost table
/// ([`tune::prefer_dot`]); the SIMD driver reduces each kept output with
/// the lane dot kernel — exact i32 sums, bit-identical to the scalar
/// oracle.
#[allow(clippy::too_many_arguments)]
pub fn gemm_abt_u8_i32_sel(
    sel: KernelSel,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    m: usize,
    n: usize,
    kd: usize,
    keep: Option<&[bool]>,
    out: &mut [i32],
) {
    match simd::resolve_isa(sel, tune::prefer_dot(kd)) {
        Some(isa) => gemm_abt_u8_i32_simd(isa, a, za, b, zb, m, n, kd, keep, out),
        None => gemm_abt_u8_i32_scalar(a, za, b, zb, m, n, kd, keep, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_abt_u8_i32_simd(
    isa: Isa,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    m: usize,
    n: usize,
    kd: usize,
    keep: Option<&[bool]>,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * kd, "A shape mismatch");
    assert_eq!(b.len(), n * kd, "B shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(k) = keep {
        assert_eq!(k.len(), m, "keep mask length mismatch");
    }
    out.fill(0);
    for i in 0..m {
        if let Some(k) = keep {
            if !k[i] {
                continue;
            }
        }
        let arow = &a[i * kd..(i + 1) * kd];
        for j in 0..n {
            out[i * n + j] = simd::dot_u8(Some(isa), arow, za, &b[j * kd..(j + 1) * kd], zb);
        }
    }
}

/// The scalar A·Bᵀ micro-kernel — the register-blocked reference path and
/// the bit-exactness oracle the SIMD driver is verified against.
#[allow(clippy::too_many_arguments)]
pub fn gemm_abt_u8_i32_scalar(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    m: usize,
    n: usize,
    kd: usize,
    keep: Option<&[bool]>,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * kd, "A shape mismatch");
    assert_eq!(b.len(), n * kd, "B shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(k) = keep {
        assert_eq!(k.len(), m, "keep mask length mismatch");
    }
    out.fill(0);
    // Register-blocked over kept A-rows: up to MR rows share every B-value
    // load, with MR independent i32 accumulators per output column. The
    // k-loop is unit-stride in both operands; i32 sums are exact, so the
    // blocking is bit-identical to the per-row dot products. Masked rows
    // are never gathered into a block (whole-row skip).
    let mut blk = [0usize; MR];
    let mut bl = 0usize;
    let mut run_block = |rows: &[usize]| {
        let mut arows: [&[u8]; MR] = [&[]; MR];
        for (ii, &row) in rows.iter().enumerate() {
            arows[ii] = &a[row * kd..(row + 1) * kd];
        }
        for j in 0..n {
            let brow = &b[j * kd..(j + 1) * kd];
            let mut acc = [0i32; MR];
            if rows.len() == MR {
                // full tile: constant bounds, MR independent accumulator
                // chains sharing every B load
                for (kk, &bvq) in brow.iter().enumerate() {
                    let bv = bvq as i32 - zb;
                    for ii in 0..MR {
                        acc[ii] += (arows[ii][kk] as i32 - za) * bv;
                    }
                }
            } else {
                for (kk, &bvq) in brow.iter().enumerate() {
                    let bv = bvq as i32 - zb;
                    for (ac, arow) in acc[..rows.len()].iter_mut().zip(arows.iter()) {
                        *ac += (arow[kk] as i32 - za) * bv;
                    }
                }
            }
            for (ii, &row) in rows.iter().enumerate() {
                out[row * n + j] = acc[ii];
            }
        }
    };
    for i in 0..m {
        if let Some(k) = keep {
            if !k[i] {
                continue;
            }
        }
        blk[bl] = i;
        bl += 1;
        if bl == MR {
            run_block(&blk);
            bl = 0;
        }
    }
    if bl > 0 {
        run_block(&blk[..bl]);
    }
}

/// Float twin of [`gemm_abt_u8_i32`]: `out[i·n + j] = Σ_k a[i·kd + k] ·
/// b[j·kd + k]`, skipped rows left at zero. Each dot product accumulates in
/// ascending-`k` order — for the weight-gradient lowering that is the
/// scalar float kernel's `(oy, ox)` order, so results are value-identical.
pub fn gemm_abt_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    kd: usize,
    keep: Option<&[bool]>,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * kd, "A shape mismatch");
    assert_eq!(b.len(), n * kd, "B shape mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(k) = keep {
        assert_eq!(k.len(), m, "keep mask length mismatch");
    }
    out.fill(0.0);
    // Register-blocked like the integer twin. Each accumulator still sums
    // its own dot product in ascending-k order (the blocking only shares
    // B loads across rows, it never reassociates a single output's sum),
    // so results are bit-identical to the per-row dots.
    let mut blk = [0usize; MR];
    let mut bl = 0usize;
    let mut run_block = |rows: &[usize]| {
        let mut arows: [&[f32]; MR] = [&[]; MR];
        for (ii, &row) in rows.iter().enumerate() {
            arows[ii] = &a[row * kd..(row + 1) * kd];
        }
        for j in 0..n {
            let brow = &b[j * kd..(j + 1) * kd];
            let mut acc = [0f32; MR];
            if rows.len() == MR {
                for (kk, &bv) in brow.iter().enumerate() {
                    for ii in 0..MR {
                        acc[ii] += arows[ii][kk] * bv;
                    }
                }
            } else {
                for (kk, &bv) in brow.iter().enumerate() {
                    for (ac, arow) in acc[..rows.len()].iter_mut().zip(arows.iter()) {
                        *ac += arow[kk] * bv;
                    }
                }
            }
            for (ii, &row) in rows.iter().enumerate() {
                out[row * n + j] = acc[ii];
            }
        }
    };
    for i in 0..m {
        if let Some(k) = keep {
            if !k[i] {
                continue;
            }
        }
        blk[bl] = i;
        bl += 1;
        if bl == MR {
            run_block(&blk);
            bl = 0;
        }
    }
    if bl > 0 {
        run_block(&blk[..bl]);
    }
}

/// Integer GEMM with per-operand zero points:
/// `out[m·n] = row_init[m] + Σ_k (a[m·k] − za)·(b[k·n] − zb)`.
///
/// The compute core is the MR×NR register-blocked micro-kernel (see the
/// module docs): an [`MR`]×[`NR`] i32 accumulator tile held in a local
/// array, k-loop streaming one A column slice (MR values) and one
/// contiguous B row slice (NR values) per step. Accumulation is i32 and
/// exact, so the result is independent of the tile schedule —
/// bit-identical to any naive triple loop over the same operands
/// (edge tiles included; property-tested around the tile boundaries).
pub fn gemm_u8_i32(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    gemm_u8_i32_sel(KernelSel::Auto, a, za, b, zb, row_init, m, k, n, out);
}

/// [`gemm_u8_i32`] with an explicit kernel selection. `Auto` resolves from
/// the global mode and the shape cost table ([`tune::prefer_gemm`]); the
/// SIMD driver runs full-width tiles on the lane kernel, edge columns on
/// the scalar loop, and `n == 1` matvecs on the lane dot kernel — exact
/// i32 sums throughout, bit-identical to the scalar oracle.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_sel(
    sel: KernelSel,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    match simd::resolve_isa(sel, tune::prefer_gemm(m, k, n)) {
        Some(isa) => gemm_u8_i32_simd(isa, a, za, b, zb, row_init, m, k, n, out),
        None => gemm_u8_i32_scalar(a, za, b, zb, row_init, m, k, n, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_u8_i32_simd(
    isa: Isa,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if n == 1 {
        // matvec: B is one contiguous k-vector, each output one lane dot
        for i in 0..m {
            out[i] = row_init[i].wrapping_add(simd::dot_u8(
                Some(isa),
                &a[i * k..(i + 1) * k],
                za,
                b,
                zb,
            ));
        }
        return;
    }
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0i32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if nrr == NR {
                simd::tile_u8(isa, &mut acc, mrr, a, mb * k, k, za, b, nb, n, zb, k);
            } else {
                // edge columns: the scalar micro-kernel's clamped loop
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * (bv as i32 - zb);
                        }
                    }
                }
            }
            for ii in 0..mrr {
                let orow = &mut out[(mb + ii) * n + nb..(mb + ii) * n + nb + nrr];
                orow.copy_from_slice(&acc[ii][..nrr]);
            }
            nb += nrr;
        }
        mb += mrr;
    }
}

/// The scalar MR×NR micro-kernel — the register-blocked reference path and
/// the bit-exactness oracle the SIMD driver is verified against.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_scalar(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0i32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if mrr == MR && nrr == NR {
                // full tile: constant loop bounds, fully unrollable
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + NR];
                    for ii in 0..MR {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii];
                        for jj in 0..NR {
                            ai[jj] += av * (brow[jj] as i32 - zb);
                        }
                    }
                }
            } else {
                // edge tile: same loops with clamped bounds (i32 sums are
                // order-independent, so numerics are unaffected)
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * (bv as i32 - zb);
                        }
                    }
                }
            }
            for ii in 0..mrr {
                let orow = &mut out[(mb + ii) * n + nb..(mb + ii) * n + nb + nrr];
                orow.copy_from_slice(&acc[ii][..nrr]);
            }
            nb += nrr;
        }
        mb += mrr;
    }
}

/// The fused quantized epilogue descriptor: everything a micro-kernel needs
/// to map its i32 accumulator tile straight to uint8 output while the tile
/// is still in registers — the requantization multiplier (Eq. 4), the
/// output quantization parameters, and whether the layer's ReLU is folded
/// into the clamp (Fig. 2b's monolithic QConv block).
///
/// Built once per kernel call by the layer ops; applying it per tile is
/// bit-identical to running [`gemm_u8_i32`] into an i32 buffer followed by
/// a separate [`requantize`] sweep (the retained unfused oracle path),
/// because [`requantize`] is a pure per-element map.
#[derive(Clone, Copy, Debug)]
pub struct QEpilogue {
    /// Requantization multiplier `s_a·s_b/s_out` (see
    /// [`crate::quant::requant_multiplier`]).
    pub mult: f32,
    /// Output quantization parameters; the zero point anchors the folded
    /// ReLU clamp.
    pub qp: QParams,
    /// Fold the layer's ReLU into the requantization clamp.
    pub relu: bool,
}

/// [`gemm_u8_i32`] with the quantized epilogue fused into the tile
/// writeout: each MR×NR accumulator tile is requantized to uint8 (bias add
/// via `row_init`, ReLU clamp via `epi.relu`) while still in registers,
/// so no `m·n` i32 intermediate ever materializes.
///
/// Two optional extras ride along on the same register tile:
///
///  * `dequant` — when `Some`, the float dequantization of every output
///    byte is emitted alongside it (`epi.qp.dequantize(q)`), which is what
///    lets the plan fold a following `DequantizeOp` into this kernel call
///    (the fused producer stages the float activation directly);
///  * the return value — the number of output values saturating the uint8
///    range (always counting 255; counting 0 only for non-ReLU epilogues,
///    whose lower clamp is a real saturation rather than the folded ReLU),
///    exactly the per-layer telemetry `NativeModel::forward_adapt`
///    otherwise gathers with a separate sweep.
///
/// Bit-identical to [`gemm_u8_i32`] + a separate [`requantize`] pass over
/// the i32 result (property-tested), since i32 accumulation is exact and
/// the epilogue is a pure per-element map.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_fused(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &QEpilogue,
    out: &mut [u8],
    dequant: Option<&mut [f32]>,
) -> u64 {
    gemm_u8_i32_fused_sel(KernelSel::Auto, a, za, b, zb, row_init, m, k, n, epi, out, dequant)
}

/// [`gemm_u8_i32_fused`] with an explicit kernel selection. The SIMD
/// driver computes each accumulator tile with the lane kernel and then
/// runs the *identical* scalar epilogue over it — the epilogue is a pure
/// per-element map over exact i32 sums, so output bytes, dequant emit,
/// and saturation counts all stay bit-identical to the scalar oracle.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_fused_sel(
    sel: KernelSel,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &QEpilogue,
    out: &mut [u8],
    dequant: Option<&mut [f32]>,
) -> u64 {
    match simd::resolve_isa(sel, tune::prefer_gemm(m, k, n)) {
        Some(isa) => gemm_u8_i32_fused_simd(isa, a, za, b, zb, row_init, m, k, n, epi, out, dequant),
        None => gemm_u8_i32_fused_scalar(a, za, b, zb, row_init, m, k, n, epi, out, dequant),
    }
}

/// [`gemm_u8_i32_sel`] over a packed sub-byte A operand. The m×k panel is
/// unpacked once into the caller-provided `a_lanes` scratch span (the
/// dispatched word-parallel unpacker under the same `sel`), then the
/// unchanged u8 micro-kernel runs on the lanes. Unpacked lanes are
/// ordinary affine values in `[0, qmax] ⊂ [0, 255]`, so the GEMM itself
/// needs no changes and a packed-8 call is bit-identical to
/// [`gemm_u8_i32_sel`] on the original bytes by construction. The unpack
/// is an O(m·k) panel pass against the O(m·k·n) GEMM, which is what keeps
/// steady-state cost unchanged while the stored weights shrink 2–4×.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_pa_sel(
    sel: KernelSel,
    a_packed: &[u8],
    bits: WBits,
    a_lanes: &mut [u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a_packed.len(), bits.packed_len(m * k), "packed A shape mismatch");
    assert!(a_lanes.len() >= m * k, "A lane scratch too small");
    simd::unpack_lanes_sel(sel, a_packed, m * k, bits, a_lanes);
    gemm_u8_i32_sel(sel, &a_lanes[..m * k], za, b, zb, row_init, m, k, n, out);
}

/// [`gemm_u8_i32_fused_sel`] over a packed sub-byte A operand — the fused
/// twin of [`gemm_u8_i32_pa_sel`]: unpack the A panel into `a_lanes`, then
/// run the unchanged fused kernel (epilogue, dequant emit, and saturation
/// count all bit-identical to the u8 path on the same lanes).
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_fused_pa_sel(
    sel: KernelSel,
    a_packed: &[u8],
    bits: WBits,
    a_lanes: &mut [u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &QEpilogue,
    out: &mut [u8],
    dequant: Option<&mut [f32]>,
) -> u64 {
    assert_eq!(a_packed.len(), bits.packed_len(m * k), "packed A shape mismatch");
    assert!(a_lanes.len() >= m * k, "A lane scratch too small");
    simd::unpack_lanes_sel(sel, a_packed, m * k, bits, a_lanes);
    gemm_u8_i32_fused_sel(sel, &a_lanes[..m * k], za, b, zb, row_init, m, k, n, epi, out, dequant)
}

#[allow(clippy::too_many_arguments)]
fn gemm_u8_i32_fused_simd(
    isa: Isa,
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &QEpilogue,
    out: &mut [u8],
    mut dequant: Option<&mut [f32]>,
) -> u64 {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(d) = dequant.as_deref() {
        assert_eq!(d.len(), m * n, "dequant emit shape mismatch");
    }
    if m == 0 || n == 0 {
        return 0;
    }
    let count_lo = !epi.relu;
    let mut sat = 0u64;
    if n == 1 {
        // matvec: lane dot per row, then the per-element epilogue
        for i in 0..m {
            let av =
                row_init[i].wrapping_add(simd::dot_u8(Some(isa), &a[i * k..(i + 1) * k], za, b, zb));
            let q = requantize(av, epi.mult, epi.qp.zero_point, epi.relu);
            out[i] = q;
            if let Some(d) = dequant.as_deref_mut() {
                d[i] = epi.qp.dequantize(q);
            }
            sat += (q == 255 || (count_lo && q == 0)) as u64;
        }
        return sat;
    }
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0i32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if nrr == NR {
                simd::tile_u8(isa, &mut acc, mrr, a, mb * k, k, za, b, nb, n, zb, k);
            } else {
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * (bv as i32 - zb);
                        }
                    }
                }
            }
            // the scalar path's epilogue, verbatim, over the lane-computed
            // tile — exact sums in, identical bytes out
            for ii in 0..mrr {
                let base = (mb + ii) * n + nb;
                let arow = &acc[ii][..nrr];
                match dequant.as_deref_mut() {
                    Some(d) => {
                        for (jj, &av) in arow.iter().enumerate() {
                            let q = requantize(av, epi.mult, epi.qp.zero_point, epi.relu);
                            out[base + jj] = q;
                            d[base + jj] = epi.qp.dequantize(q);
                            sat += (q == 255 || (count_lo && q == 0)) as u64;
                        }
                    }
                    None => {
                        for (jj, &av) in arow.iter().enumerate() {
                            let q = requantize(av, epi.mult, epi.qp.zero_point, epi.relu);
                            out[base + jj] = q;
                            sat += (q == 255 || (count_lo && q == 0)) as u64;
                        }
                    }
                }
            }
            nb += nrr;
        }
        mb += mrr;
    }
    sat
}

/// The scalar fused micro-kernel — the register-blocked reference path and
/// the bit-exactness oracle the SIMD driver is verified against.
#[allow(clippy::too_many_arguments)]
pub fn gemm_u8_i32_fused_scalar(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &QEpilogue,
    out: &mut [u8],
    mut dequant: Option<&mut [f32]>,
) -> u64 {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if let Some(d) = dequant.as_deref() {
        assert_eq!(d.len(), m * n, "dequant emit shape mismatch");
    }
    if m == 0 || n == 0 {
        return 0;
    }
    let count_lo = !epi.relu;
    let mut sat = 0u64;
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0i32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if mrr == MR && nrr == NR {
                // full tile: constant loop bounds, fully unrollable
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + NR];
                    for ii in 0..MR {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii];
                        for jj in 0..NR {
                            ai[jj] += av * (brow[jj] as i32 - zb);
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk] as i32 - za;
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * (bv as i32 - zb);
                        }
                    }
                }
            }
            // epilogue on the register tile: requantize, optional dequant
            // emit, saturation count — no i32 writeback
            for ii in 0..mrr {
                let base = (mb + ii) * n + nb;
                let arow = &acc[ii][..nrr];
                match dequant.as_deref_mut() {
                    Some(d) => {
                        for (jj, &av) in arow.iter().enumerate() {
                            let q = requantize(av, epi.mult, epi.qp.zero_point, epi.relu);
                            out[base + jj] = q;
                            d[base + jj] = epi.qp.dequantize(q);
                            sat += (q == 255 || (count_lo && q == 0)) as u64;
                        }
                    }
                    None => {
                        for (jj, &av) in arow.iter().enumerate() {
                            let q = requantize(av, epi.mult, epi.qp.zero_point, epi.relu);
                            out[base + jj] = q;
                            sat += (q == 255 || (count_lo && q == 0)) as u64;
                        }
                    }
                }
            }
            nb += nrr;
        }
        mb += mrr;
    }
    sat
}

/// The pre-micro-kernel cache-blocked integer GEMM (PR 1–3 compute core),
/// retained verbatim as the property-test oracle and the bench baseline
/// the micro-kernel path is measured against: NC×KC tiles, AXPY inner
/// loop over a contiguous `b` row segment, zero-point rows of `a` skipped.
/// Bit-identical to [`gemm_u8_i32`] (exact i32 accumulation on both
/// sides).
pub fn gemm_u8_i32_tiled(
    a: &[u8],
    za: i32,
    b: &[u8],
    zb: i32,
    row_init: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    for (mr, orow) in out.chunks_exact_mut(n).enumerate() {
        orow.fill(row_init[mr]);
    }
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NC).min(n);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KC).min(k);
            for mr in 0..m {
                let arow = &a[mr * k..(mr + 1) * k];
                let orow = &mut out[mr * n + nb..mr * n + ne];
                for kk in kb..ke {
                    let av = arow[kk] as i32 - za;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b[kk * n + nb..kk * n + ne];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * (bv as i32 - zb);
                    }
                }
            }
            kb = ke;
        }
        nb = ne;
    }
}

/// f32 GEMM: `out[m·n] = row_init[m] + Σ_k a[m·k]·b[k·n]`, on the MR×NR
/// register-blocked micro-kernel.
///
/// Per output element the products are added in ascending-`k` order (the
/// accumulator tile is initialized with `row_init`, then the k-loop
/// ascends; the tile only shares loads across outputs, it never
/// reassociates one output's sum), which matches the scalar float conv's
/// `(ci, ky, kx)` loop nesting — results are value-identical to the
/// reference kernel and to the retained [`gemm_f32_tiled`] path (padded
/// entries add an exact `a·0.0`).
pub fn gemm_f32(
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    gemm_f32_sel(KernelSel::Auto, a, b, row_init, m, k, n, out);
}

/// [`gemm_f32`] with an explicit kernel selection. The SIMD tile keeps
/// every output lane's ascending-`k` accumulation order with a separate
/// multiply and add per step (no FMA), so the float path stays
/// bit-identical to the scalar oracle; edge columns and `n == 1` shapes
/// run the scalar loops outright (a lane reduction would reassociate).
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_sel(
    sel: KernelSel,
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    match simd::resolve_isa(sel, tune::prefer_gemm(m, k, n)) {
        Some(isa) if n >= NR => gemm_f32_simd(isa, a, b, row_init, m, k, n, out),
        _ => gemm_f32_scalar(a, b, row_init, m, k, n, out),
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_f32_simd(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0f32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if nrr == NR {
                simd::tile_f32(isa, &mut acc, mrr, a, mb * k, k, b, nb, n, k);
            } else {
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk];
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * bv;
                        }
                    }
                }
            }
            for ii in 0..mrr {
                let orow = &mut out[(mb + ii) * n + nb..(mb + ii) * n + nb + nrr];
                orow.copy_from_slice(&acc[ii][..nrr]);
            }
            nb += nrr;
        }
        mb += mrr;
    }
}

/// The scalar f32 micro-kernel — the register-blocked reference path and
/// the bit-exactness oracle the SIMD driver is verified against.
pub fn gemm_f32_scalar(
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    let mut mb = 0;
    while mb < m {
        let mrr = MR.min(m - mb);
        let mut nb = 0;
        while nb < n {
            let nrr = NR.min(n - nb);
            let mut acc = [[0f32; NR]; MR];
            for (ii, row) in acc[..mrr].iter_mut().enumerate() {
                row.fill(row_init[mb + ii]);
            }
            if mrr == MR && nrr == NR {
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + NR];
                    for ii in 0..MR {
                        let av = a[(mb + ii) * k + kk];
                        let ai = &mut acc[ii];
                        for jj in 0..NR {
                            ai[jj] += av * brow[jj];
                        }
                    }
                }
            } else {
                for kk in 0..k {
                    let brow = &b[kk * n + nb..kk * n + nb + nrr];
                    for ii in 0..mrr {
                        let av = a[(mb + ii) * k + kk];
                        let ai = &mut acc[ii][..nrr];
                        for (aj, &bv) in ai.iter_mut().zip(brow.iter()) {
                            *aj += av * bv;
                        }
                    }
                }
            }
            for ii in 0..mrr {
                let orow = &mut out[(mb + ii) * n + nb..(mb + ii) * n + nb + nrr];
                orow.copy_from_slice(&acc[ii][..nrr]);
            }
            nb += nrr;
        }
        mb += mrr;
    }
}

/// The pre-micro-kernel cache-blocked f32 GEMM (PR 1–3 compute core),
/// retained as oracle and bench baseline. Same ascending-`k` per-output
/// accumulation order as [`gemm_f32`], so the two are bit-identical.
pub fn gemm_f32_tiled(
    a: &[f32],
    b: &[f32],
    row_init: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(row_init.len(), m, "row_init length mismatch");
    assert_eq!(out.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    for (mr, orow) in out.chunks_exact_mut(n).enumerate() {
        orow.fill(row_init[mr]);
    }
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NC).min(n);
        let mut kb = 0;
        while kb < k {
            let ke = (kb + KC).min(k);
            for mr in 0..m {
                let arow = &a[mr * k..(mr + 1) * k];
                let orow = &mut out[mr * n + nb..mr * n + ne];
                for kk in kb..ke {
                    let av = arow[kk];
                    let brow = &b[kk * n + nb..kk * n + ne];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
            kb = ke;
        }
        nb = ne;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvGeom;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    fn naive_gemm_i32(
        a: &[u8],
        za: i32,
        b: &[u8],
        zb: i32,
        init: &[i32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for mr in 0..m {
            for nc in 0..n {
                let mut acc = init[mr];
                for kk in 0..k {
                    acc += (a[mr * k + kk] as i32 - za) * (b[kk * n + nc] as i32 - zb);
                }
                out[mr * n + nc] = acc;
            }
        }
        out
    }

    #[test]
    fn prop_microkernel_gemm_matches_naive_triple_loop() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                // spans the tile boundaries: k and n around KC/NC
                let m = 1 + r.below(9) as usize;
                let k = 1 + r.below(300) as usize;
                let n = 1 + r.below(600) as usize;
                (m, k, n, r.next_u64())
            },
            |&(m, k, n, s)| {
                let mut v = Vec::new();
                for m2 in shrink_dim(m, 1) {
                    v.push((m2, k, n, s));
                }
                for k2 in shrink_dim(k, 1) {
                    v.push((m, k2, n, s));
                }
                for n2 in shrink_dim(n, 1) {
                    v.push((m, k, n2, s));
                }
                v
            },
            |&(m, k, n, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
                let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
                let mut out = vec![0i32; m * n];
                gemm_u8_i32(&a, za, &b, zb, &init, m, k, n, &mut out);
                let want = naive_gemm_i32(&a, za, &b, zb, &init, m, k, n);
                if out != want {
                    return Err("tiled result differs from naive triple loop".into());
                }
                Ok(())
            },
        );
    }

    /// The fused epilogue must be bit-identical to the unfused sequence
    /// (GEMM into i32, then a separate requantize sweep), its dequant emit
    /// must equal `QParams::dequantize` of every output byte, and its
    /// saturation count must match the separate telemetry sweep — for ReLU
    /// and non-ReLU epilogues across tile-edge shapes.
    #[test]
    fn prop_fused_epilogue_matches_unfused_sequence() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let m = 1 + r.below(9) as usize;
                let k = 1 + r.below(100) as usize;
                let n = 1 + r.below(80) as usize;
                (m, k, n, r.next_u64())
            },
            |&(m, k, n, s)| {
                let mut v = Vec::new();
                for m2 in shrink_dim(m, 1) {
                    v.push((m2, k, n, s));
                }
                for n2 in shrink_dim(n, 1) {
                    v.push((m, k, n2, s));
                }
                v
            },
            |&(m, k, n, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
                let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
                let qp = QParams::from_min_max(rng.uniform(-6.0, -0.1), rng.uniform(0.1, 6.0));
                let epi = QEpilogue {
                    mult: rng.uniform(1e-4, 0.5),
                    qp,
                    relu: rng.below(2) == 1,
                };
                // unfused oracle: plain GEMM then a separate requantize
                // sweep and a separate saturation sweep
                let mut acc = vec![0i32; m * n];
                gemm_u8_i32(&a, za, &b, zb, &init, m, k, n, &mut acc);
                let want: Vec<u8> =
                    acc.iter().map(|&v| requantize(v, epi.mult, qp.zero_point, epi.relu)).collect();
                let want_sat = want
                    .iter()
                    .filter(|&&q| q == 255 || (!epi.relu && q == 0))
                    .count() as u64;

                let mut out = vec![0u8; m * n];
                let sat = gemm_u8_i32_fused(&a, za, &b, zb, &init, m, k, n, &epi, &mut out, None);
                if out != want {
                    return Err("fused output differs from unfused sequence".into());
                }
                if sat != want_sat {
                    return Err(format!("fused sat {sat} != swept sat {want_sat}"));
                }

                let mut out2 = vec![0u8; m * n];
                let mut deq = vec![0f32; m * n];
                let sat2 = gemm_u8_i32_fused(
                    &a, za, &b, zb, &init, m, k, n, &epi, &mut out2, Some(&mut deq),
                );
                if out2 != want || sat2 != want_sat {
                    return Err("dequant-emitting variant diverged".into());
                }
                for (d, &q) in deq.iter().zip(out2.iter()) {
                    if d.to_bits() != qp.dequantize(q).to_bits() {
                        return Err("dequant emit differs from QParams::dequantize".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gemm_f32_matches_naive_order() {
        let mut rng = Pcg32::seeded(5);
        let (m, k, n) = (4, 150, 300); // crosses both tile boundaries
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_normal(&mut a, 0.5);
        rng.fill_normal(&mut b, 0.5);
        let init: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; m * n];
        gemm_f32(&a, &b, &init, m, k, n, &mut out);
        for mr in 0..m {
            for nc in 0..n {
                let mut acc = init[mr];
                for kk in 0..k {
                    acc += a[mr * k + kk] * b[kk * n + nc];
                }
                // ascending-k accumulation on both sides -> exactly equal
                assert_eq!(out[mr * n + nc], acc, "({mr},{nc})");
            }
        }
    }

    #[test]
    fn im2col_identity_for_pointwise() {
        // 1x1/stride-1/no-pad im2col is the identity layout [Cin, H·W]
        let g = ConvGeom {
            cin: 3,
            cout: 1,
            kh: 1,
            kw: 1,
            stride: 1,
            pad_h: 0,
            pad_w: 0,
            depthwise: false,
        };
        let xd: Vec<u8> = (0..3 * 4 * 4).map(|v| v as u8).collect();
        let mut col = vec![0u8; 3 * 16];
        im2col_u8(&xd, 4, 4, &g, 4, 4, 99, &mut col);
        assert_eq!(col, xd);
    }

    #[test]
    fn im2col_pads_with_zero_point() {
        let g = ConvGeom {
            cin: 1,
            cout: 1,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let xd = vec![10u8; 4]; // 2x2 map
        let mut col = vec![0u8; 9 * 4];
        im2col_u8(&xd, 2, 2, &g, 2, 2, 7, &mut col);
        // row (ky=0,kx=0), output (0,0) reads input (-1,-1) -> pad
        assert_eq!(col[0], 7);
        // center tap (ky=1,kx=1) reads the map itself
        let center = &col[4 * 4..5 * 4];
        assert_eq!(center, &[10, 10, 10, 10]);
        // 2x2 map, 3x3 kernel, pad 1: each of the 4 output positions sees
        // 4 in-bounds taps -> 16 of the 36 col entries are real values
        let in_bounds = col.iter().filter(|&&v| v == 10).count();
        assert_eq!(in_bounds, 16);
    }

    #[test]
    fn abt_u8_matches_naive_dots_and_skips_rows() {
        let mut rng = Pcg32::seeded(11);
        let (m, n, kd) = (5, 7, 37);
        let a: Vec<u8> = (0..m * kd).map(|_| rng.below(256) as u8).collect();
        let b: Vec<u8> = (0..n * kd).map(|_| rng.below(256) as u8).collect();
        let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
        let keep: Vec<bool> = (0..m).map(|i| i % 2 == 0).collect();
        let mut out = vec![-1i32; m * n];
        gemm_abt_u8_i32(&a, za, &b, zb, m, n, kd, Some(&keep), &mut out);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = if keep[i] {
                    (0..kd).map(|k| (a[i * kd + k] as i32 - za) * (b[j * kd + k] as i32 - zb)).sum()
                } else {
                    0
                };
                assert_eq!(out[i * n + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn abt_f32_matches_ascending_k_dots() {
        let mut rng = Pcg32::seeded(12);
        let (m, n, kd) = (3, 4, 41);
        let mut a = vec![0f32; m * kd];
        let mut b = vec![0f32; n * kd];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let mut out = vec![9f32; m * n];
        gemm_abt_f32(&a, &b, m, n, kd, None, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for k in 0..kd {
                    acc += a[i * kd + k] * b[j * kd + k];
                }
                assert_eq!(out[i * n + j], acc, "({i},{j})");
            }
        }
    }

    #[test]
    fn pack_wt_flip_transposes_and_flips() {
        // Cout=2, Cin=1, 2x2 kernel with recognizable values co*100 + ky*10 + kx.
        let g = ConvGeom {
            cin: 1,
            cout: 2,
            kh: 2,
            kw: 2,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let w: Vec<u8> = vec![0, 1, 10, 11, 100, 101, 110, 111];
        let mut dst = vec![0u8; 8];
        let kc = pack_wt_flip_u8(&w, &g, None, &mut dst);
        assert_eq!(kc, 2);
        // row ci=0: channels ascending, each kernel flipped in both axes
        assert_eq!(dst, vec![11, 10, 1, 0, 111, 110, 101, 100]);

        // masking drops channel 0 entirely
        let mut dst2 = vec![0u8; 4];
        let kc2 = pack_wt_flip_u8(&w, &g, Some(&[false, true]), &mut dst2);
        assert_eq!(kc2, 1);
        assert_eq!(dst2, vec![111, 110, 101, 100]);
    }

    /// The packed-weight flip must be bit-identical to unpacking the whole
    /// tensor and running the u8 flip — across bit widths, odd kernel
    /// geometries (3×3 planes are not byte-aligned at 2 or 4 lanes/byte),
    /// and sparse keep masks.
    #[test]
    fn prop_packed_pack_wt_flip_matches_unpacked_oracle() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let cin = 1 + r.below(5) as usize;
                let cout = 1 + r.below(5) as usize;
                let k = 1 + r.below(3) as usize;
                let bits = match r.below(3) {
                    0 => WBits::W8,
                    1 => WBits::W4,
                    _ => WBits::W2,
                };
                (cin, cout, k, bits, r.next_u64())
            },
            |&(cin, cout, k, bits, s)| {
                shrink_dim(cout, 1).into_iter().map(|c2| (cin, c2, k, bits, s)).collect()
            },
            |&(cin, cout, k, bits, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = ConvGeom {
                    cin,
                    cout,
                    kh: k,
                    kw: k,
                    stride: 1,
                    pad_h: 0,
                    pad_w: 0,
                    depthwise: false,
                };
                let span = bits.qmax() as u32 + 1;
                let lanes: Vec<u8> =
                    (0..cout * cin * k * k).map(|_| rng.below(span) as u8).collect();
                let packed = subbyte::pack_lanes(&lanes, bits);
                let keep: Vec<bool> = (0..cout).map(|_| rng.below(2) == 1).collect();
                for mask in [None, Some(keep.as_slice())] {
                    let kc = super::super::kept_count(mask, cout);
                    let mut want = vec![0u8; cin * kc * k * k];
                    let mut got = vec![0u8; cin * kc * k * k];
                    pack_wt_flip_u8(&lanes, &g, mask, &mut want);
                    let kc2 = pack_wt_flip_u8_pa(&packed, bits, &g, mask, &mut got);
                    if kc2 != kc {
                        return Err(format!("kept count {kc2} != {kc}"));
                    }
                    if got != want {
                        return Err(format!("packed flip differs ({bits:?}, mask={mask:?})"));
                    }
                }
                Ok(())
            },
        );
    }

    /// The packed-A GEMM twins must be bit-identical to the u8 kernels on
    /// the same lanes, at every bit width, across the MR/NR tile edges —
    /// plain and fused (output bytes, dequant emit, saturation count).
    #[test]
    fn packed_gemm_edge_tiles_bit_exact() {
        let mut rng = Pcg32::seeded(79);
        for &bits in &[WBits::W8, WBits::W4, WBits::W2] {
            let span = bits.qmax() as u32 + 1;
            for &m in &[1usize, MR - 1, MR + 1, 7] {
                for &n in &[1usize, NR - 1, NR + 1, 13] {
                    let k = 1 + rng.below(31) as usize;
                    let a: Vec<u8> = (0..m * k).map(|_| rng.below(span) as u8).collect();
                    let packed = subbyte::pack_lanes(&a, bits);
                    let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
                    let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                    let za = rng.below(span) as i32;
                    let zb = rng.below(256) as i32;
                    let mut lanes = vec![0u8; m * k];

                    let mut want = vec![0i32; m * n];
                    gemm_u8_i32_sel(KernelSel::Scalar, &a, za, &b, zb, &init, m, k, n, &mut want);
                    let mut got = vec![0i32; m * n];
                    gemm_u8_i32_pa_sel(
                        KernelSel::Scalar,
                        &packed,
                        bits,
                        &mut lanes,
                        za,
                        &b,
                        zb,
                        &init,
                        m,
                        k,
                        n,
                        &mut got,
                    );
                    assert_eq!(got, want, "plain {bits:?} m={m} n={n} k={k}");

                    let epi = QEpilogue {
                        mult: 0.03,
                        qp: QParams { scale: 0.1, zero_point: 90 },
                        relu: m % 2 == 0,
                    };
                    let mut wq = vec![0u8; m * n];
                    let mut wd = vec![0f32; m * n];
                    let sat_w = gemm_u8_i32_fused_sel(
                        KernelSel::Scalar,
                        &a,
                        za,
                        &b,
                        zb,
                        &init,
                        m,
                        k,
                        n,
                        &epi,
                        &mut wq,
                        Some(&mut wd),
                    );
                    let mut gq = vec![0u8; m * n];
                    let mut gd = vec![0f32; m * n];
                    let sat_g = gemm_u8_i32_fused_pa_sel(
                        KernelSel::Scalar,
                        &packed,
                        bits,
                        &mut lanes,
                        za,
                        &b,
                        zb,
                        &init,
                        m,
                        k,
                        n,
                        &epi,
                        &mut gq,
                        Some(&mut gd),
                    );
                    assert_eq!(gq, wq, "fused bytes {bits:?} m={m} n={n} k={k}");
                    assert_eq!(sat_g, sat_w, "fused sat {bits:?} m={m} n={n} k={k}");
                    let wb: Vec<u32> = wd.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = gd.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "fused dequant {bits:?} m={m} n={n} k={k}");
                }
            }
        }
    }

    /// The full backward-input lowering (pack_wt_flip × im2col_bwd through
    /// the plain GEMM) must reproduce the naive transposed-conv scatter in
    /// exact integer arithmetic, across strides and paddings.
    #[test]
    fn prop_bwd_lowering_matches_naive_scatter() {
        Prop::new(32).check(
            |r: &mut Pcg32| {
                let cin = 1 + r.below(4) as usize;
                let cout = 1 + r.below(4) as usize;
                let k = 1 + r.below(3) as usize;
                let stride = 1 + r.below(2) as usize;
                let pad = r.below(2) as usize;
                let h = k.max(2) + r.below(6) as usize;
                (cin, cout, k, stride, pad, h, r.next_u64())
            },
            |&(cin, cout, k, stride, pad, h, s)| {
                shrink_dim(h, k).into_iter().map(|h2| (cin, cout, k, stride, pad, h2, s)).collect()
            },
            |&(cin, cout, k, stride, pad, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = ConvGeom {
                    cin,
                    cout,
                    kh: k,
                    kw: k,
                    stride,
                    pad_h: pad,
                    pad_w: pad,
                    depthwise: false,
                };
                let (oh, ow) = g.out_hw(h, h);
                let ed: Vec<u8> = (0..cout * oh * ow).map(|_| rng.below(256) as u8).collect();
                let wd: Vec<u8> = (0..cout * cin * k * k).map(|_| rng.below(256) as u8).collect();
                let (ze, zw) = (rng.below(256) as i32, rng.below(256) as i32);

                // naive scatter (the scalar backward kernel's loop order)
                let mut want = vec![0i32; cin * h * h];
                for co in 0..cout {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let ev = ed[(co * oh + oy) * ow + ox] as i32 - ze;
                            for ci in 0..cin {
                                for ky in 0..k {
                                    let iy = (oy * stride + ky) as isize - pad as isize;
                                    if iy < 0 || iy >= h as isize {
                                        continue;
                                    }
                                    for kx in 0..k {
                                        let ix = (ox * stride + kx) as isize - pad as isize;
                                        if ix < 0 || ix >= h as isize {
                                            continue;
                                        }
                                        let wv =
                                            wd[((co * cin + ci) * k + ky) * k + kx] as i32 - zw;
                                        want[(ci * h + iy as usize) * h + ix as usize] += ev * wv;
                                    }
                                }
                            }
                        }
                    }
                }

                let krow = cout * k * k;
                let n = h * h;
                let mut wt = vec![0u8; cin * krow];
                pack_wt_flip_u8(&wd, &g, None, &mut wt);
                let mut col = vec![0u8; krow * n];
                let ze_byte = ze.clamp(0, 255) as u8;
                im2col_bwd_u8(&ed, oh, ow, &g, h, h, None, ze_byte, &mut col);
                let init = vec![0i32; cin];
                let mut got = vec![0i32; cin * n];
                gemm_u8_i32(&wt, zw, &col, ze, &init, cin, krow, n, &mut got);
                if got != want {
                    return Err("backward lowering differs from naive scatter".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_dims_are_safe() {
        let mut out: Vec<i32> = Vec::new();
        gemm_u8_i32(&[], 0, &[], 0, &[], 0, 0, 3, &mut out);
        let mut out2 = vec![1i32; 2];
        // k == 0: output is just row_init
        gemm_u8_i32(&[], 3, &[], 4, &[7, -7], 2, 0, 1, &mut out2);
        assert_eq!(out2, vec![7, -7]);
    }

    /// Deterministic sweep of M/N/K around the MR/NR tile boundaries
    /// (±1, primes, 1×N, M×1): the micro-kernel must be bit-exact with
    /// the retained tiled path and the naive triple loop (i32), and
    /// bit-identical to the tiled path (f32 — same per-output ascending-k
    /// accumulation order).
    #[test]
    fn microkernel_edge_tiles_bit_exact() {
        let dims_m = [1usize, MR - 1, MR, MR + 1, 2 * MR + 1, 7];
        let dims_n = [1usize, NR - 1, NR, NR + 1, 2 * NR + 3, 13];
        let dims_k = [1usize, 2, 31];
        let mut rng = Pcg32::seeded(77);
        for &m in &dims_m {
            for &n in &dims_n {
                for &k in &dims_k {
                    let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
                    let b: Vec<u8> = (0..k * n).map(|_| rng.below(256) as u8).collect();
                    let init: Vec<i32> = (0..m).map(|_| rng.below(1000) as i32 - 500).collect();
                    let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
                    let mut micro = vec![0i32; m * n];
                    let mut tiled = vec![0i32; m * n];
                    gemm_u8_i32(&a, za, &b, zb, &init, m, k, n, &mut micro);
                    gemm_u8_i32_tiled(&a, za, &b, zb, &init, m, k, n, &mut tiled);
                    assert_eq!(micro, tiled, "i32 micro vs tiled at m={m} n={n} k={k}");
                    let naive = naive_gemm_i32(&a, za, &b, zb, &init, m, k, n);
                    assert_eq!(micro, naive, "i32 micro vs naive at m={m} n={n} k={k}");

                    let mut af = vec![0f32; m * k];
                    let mut bf = vec![0f32; k * n];
                    rng.fill_normal(&mut af, 0.7);
                    rng.fill_normal(&mut bf, 0.7);
                    let initf: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
                    let mut fmicro = vec![0f32; m * n];
                    let mut ftiled = vec![0f32; m * n];
                    gemm_f32(&af, &bf, &initf, m, k, n, &mut fmicro);
                    gemm_f32_tiled(&af, &bf, &initf, m, k, n, &mut ftiled);
                    let mb: Vec<u32> = fmicro.iter().map(|v| v.to_bits()).collect();
                    let tb: Vec<u32> = ftiled.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(mb, tb, "f32 micro vs tiled at m={m} n={n} k={k}");
                }
            }
        }
    }

    /// The A·Bᵀ row-blocking must stay bit-exact across kept-row counts
    /// that land on every residue of the MR block size, dense and with
    /// sparse `keep` masks (including masks that leave 1 or MR±1 rows).
    #[test]
    fn abt_row_blocking_edge_cases_bit_exact() {
        let mut rng = Pcg32::seeded(78);
        for &m in &[1usize, MR - 1, MR, MR + 1, 2 * MR, 11] {
            for &(n, kd) in &[(1usize, 7usize), (5, 1), (17, 64)] {
                let a: Vec<u8> = (0..m * kd).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..n * kd).map(|_| rng.below(256) as u8).collect();
                let mut af = vec![0f32; m * kd];
                let mut bf = vec![0f32; n * kd];
                rng.fill_normal(&mut af, 1.0);
                rng.fill_normal(&mut bf, 1.0);
                let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
                let masks: [Option<Vec<bool>>; 3] = [
                    None,
                    Some((0..m).map(|i| i % 2 == 0).collect()),
                    Some((0..m).map(|_| rng.below(2) == 1).collect()),
                ];
                for keep in masks.iter().map(|k| k.as_deref()) {
                    let mut got = vec![-1i32; m * n];
                    gemm_abt_u8_i32(&a, za, &b, zb, m, n, kd, keep, &mut got);
                    for i in 0..m {
                        for j in 0..n {
                            let kept = match keep {
                                Some(k) => k[i],
                                None => true,
                            };
                            let mut want = 0i32;
                            if kept {
                                for t in 0..kd {
                                    want += (a[i * kd + t] as i32 - za)
                                        * (b[j * kd + t] as i32 - zb);
                                }
                            }
                            assert_eq!(got[i * n + j], want, "i32 abt m={m} ({i},{j})");
                        }
                    }
                    let mut gotf = vec![9f32; m * n];
                    gemm_abt_f32(&af, &bf, m, n, kd, keep, &mut gotf);
                    for i in 0..m {
                        for j in 0..n {
                            let kept = match keep {
                                Some(k) => k[i],
                                None => true,
                            };
                            let mut want = 0f32;
                            if kept {
                                for t in 0..kd {
                                    want += af[i * kd + t] * bf[j * kd + t];
                                }
                            }
                            assert_eq!(
                                gotf[i * n + j].to_bits(),
                                want.to_bits(),
                                "f32 abt m={m} ({i},{j})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// The stride-1 im2col fast path (contiguous interior `copy_from_slice`
    /// rows) must stay byte-identical to the per-element packing rule,
    /// including pads wider than the kernel offset and tiny maps.
    #[test]
    fn im2col_stride1_fast_path_matches_reference() {
        for &(cin, k, pad, h, w) in &[
            (2usize, 3usize, 1usize, 5usize, 7usize),
            (1, 3, 0, 4, 4),
            (3, 5, 2, 6, 5),
            (1, 1, 0, 3, 3),
            (2, 3, 2, 3, 3), // pad wider than some kernel offsets
        ] {
            let g = ConvGeom {
                cin,
                cout: 1,
                kh: k,
                kw: k,
                stride: 1,
                pad_h: pad,
                pad_w: pad,
                depthwise: false,
            };
            let (oh, ow) = g.out_hw(h, w);
            let xd: Vec<u8> = (0..cin * h * w).map(|i| (i * 7 + 3) as u8).collect();
            let mut col = vec![0u8; cin * k * k * oh * ow];
            im2col_u8(&xd, h, w, &g, oh, ow, 211, &mut col);
            let nsp = oh * ow;
            let mut r = 0usize;
            for ci in 0..cin {
                for ky in 0..k {
                    for kx in 0..k {
                        for oy in 0..oh {
                            for ox in 0..ow {
                                let iy = (oy + ky) as isize - pad as isize;
                                let ix = (ox + kx) as isize - pad as isize;
                                let oob = iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize;
                                let want = if oob {
                                    211
                                } else {
                                    xd[(ci * h + iy as usize) * w + ix as usize]
                                };
                                assert_eq!(
                                    col[r * nsp + oy * ow + ox],
                                    want,
                                    "ci={ci} ky={ky} kx={kx} oy={oy} ox={ox}"
                                );
                            }
                        }
                        r += 1;
                    }
                }
            }
        }
    }
}
