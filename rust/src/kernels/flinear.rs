//! Float32 twin of the quantized linear kernels (`float32` configuration
//! and the float classification head of the `mixed` configuration).

use crate::kernels::{gemm, kept_count, OpCounter};
use crate::memplan::Scratch;
use crate::tensor::TensorF32;

/// Forward: `y = relu?(W·x + b)` in f32.
pub fn flinear_fwd(
    x: &TensorF32,
    w: &TensorF32,
    bias: &[f32],
    relu: bool,
    ops: &mut OpCounter,
) -> TensorF32 {
    let n_in = x.len();
    let n_out = w.shape()[0];
    assert_eq!(w.shape()[1], n_in);
    let mut out = TensorF32::zeros(&[n_out]);
    for o in 0..n_out {
        let row = w.outer(o);
        let mut acc = bias[o];
        for (xv, wv) in x.data().iter().zip(row.iter()) {
            acc += xv * wv;
        }
        out.data_mut()[o] = if relu { acc.max(0.0) } else { acc };
    }
    ops.float_macs += (n_in * n_out) as u64;
    ops.bytes += ((n_in + n_in * n_out + n_out) * 4) as u64;
    out
}

/// Error backprop `e_in = Wᵀ·e_out`, optional row mask.
pub fn flinear_bwd_input(
    e: &TensorF32,
    w: &TensorF32,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> TensorF32 {
    let n_out = e.len();
    let n_in = w.shape()[1];
    let mut out = TensorF32::zeros(&[n_in]);
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        let ev = e.data()[o];
        if ev == 0.0 {
            continue;
        }
        let row = w.outer(o);
        for (acc, wv) in out.data_mut().iter_mut().zip(row.iter()) {
            *acc += ev * wv;
        }
    }
    ops.float_macs += kept * n_in as u64;
    ops.bytes += ((n_out + n_out * n_in + n_in) * 4) as u64;
    out
}

/// GEMM-routed error backprop, value-identical to [`flinear_bwd_input`]:
/// `e_in = eᵀ·W` as a 1×`n_out`×`n_in` float GEMM whose ascending-k
/// accumulation is the scalar kernel's row order. Masked rows are zeroed in
/// the scratch copy of `e` (their AXPY adds an exact `0.0·w`).
pub fn flinear_bwd_input_gemm(
    e: &TensorF32,
    w: &TensorF32,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> TensorF32 {
    let n_out = e.len();
    let n_in = w.shape()[1];
    assert_eq!(w.shape()[0], n_out);
    let kept = kept_count(keep, n_out) as u64;
    let mut out = TensorF32::zeros(&[n_in]);
    {
        let (_, ecopy, init) = scratch.fconv_bwd_bufs(0, n_out, 1);
        for (dst, (i, &src)) in ecopy.iter_mut().zip(e.data().iter().enumerate()) {
            *dst = match keep {
                Some(k) if !k[i] => 0.0,
                _ => src,
            };
        }
        gemm::gemm_f32(ecopy, w.data(), init, 1, n_out, n_in, out.data_mut());
    }
    ops.float_macs += kept * n_in as u64;
    ops.bytes += ((n_out + n_out * n_in + n_in) * 4) as u64;
    out
}

/// Weight + bias gradient `∇W = e·xᵀ`, optional row mask.
pub fn flinear_bwd_weight(
    e: &TensorF32,
    x: &TensorF32,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let n_out = e.len();
    let n_in = x.len();
    let mut gw = TensorF32::zeros(&[n_out, n_in]);
    let mut gb = TensorF32::zeros(&[n_out]);
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        let ev = e.data()[o];
        gb.data_mut()[o] = ev;
        if ev == 0.0 {
            continue;
        }
        let row = gw.outer_mut(o);
        for (gv, xv) in row.iter_mut().zip(x.data().iter()) {
            *gv = ev * xv;
        }
    }
    ops.float_macs += kept * n_in as u64;
    ops.bytes += ((n_out + n_in + n_out * n_in) * 4) as u64;
    (gw, gb)
}

/// GEMM-routed weight gradient, value-identical to [`flinear_bwd_weight`]:
/// the outer product is a rank-1 A·Bᵀ GEMM ([`gemm::gemm_abt_f32`] with
/// reduction depth 1); `keep` skips masked rows as whole GEMM rows. Each
/// element is the same single product the scalar kernel computes.
pub fn flinear_bwd_weight_gemm(
    e: &TensorF32,
    x: &TensorF32,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let n_out = e.len();
    let n_in = x.len();
    let mut gw = TensorF32::zeros(&[n_out, n_in]);
    let mut gb = TensorF32::zeros(&[n_out]);
    gemm::gemm_abt_f32(e.data(), x.data(), n_out, n_in, 1, keep, gw.data_mut());
    let gbd = gb.data_mut();
    let mut kept = 0u64;
    for o in 0..n_out {
        if let Some(k) = keep {
            if !k[o] {
                continue;
            }
        }
        kept += 1;
        gbd[o] = e.data()[o];
    }
    ops.float_macs += kept * n_in as u64;
    ops.bytes += ((n_out + n_in + n_out * n_in) * 4) as u64;
    (gw, gb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn fwd_bwd_consistency_via_fd() {
        let mut rng = Pcg32::seeded(41);
        let (n_in, n_out) = (12, 5);
        let mut x = TensorF32::zeros(&[n_in]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut w = TensorF32::zeros(&[n_out, n_in]);
        rng.fill_normal(w.data_mut(), 0.3);
        let b = vec![0.0; n_out];

        let e = TensorF32::full(&[n_out], 1.0);
        let mut ops = OpCounter::new();
        let (gw, gb) = flinear_bwd_weight(&e, &x, None, &mut ops);
        let gx = flinear_bwd_input(&e, &w, None, &mut ops);

        let loss = |w: &TensorF32, x: &TensorF32| -> f32 {
            let mut o = OpCounter::new();
            flinear_fwd(x, w, &b, false, &mut o).data().iter().sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 13, 42] {
            let mut wp = w.clone();
            wp.data_mut()[idx] += eps;
            let mut wm = w.clone();
            wm.data_mut()[idx] -= eps;
            let num = (loss(&wp, &x) - loss(&wm, &x)) / (2.0 * eps);
            assert!((num - gw.data()[idx]).abs() < 1e-2);
        }
        for idx in [0usize, 6, 11] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let num = (loss(&w, &xp) - loss(&w, &xm)) / (2.0 * eps);
            assert!((num - gx.data()[idx]).abs() < 1e-2);
        }
        assert!(gb.data().iter().all(|&g| (g - 1.0).abs() < 1e-6));
    }

    #[test]
    fn relu_clamps_forward() {
        let x = TensorF32::from_vec(&[2], vec![1.0, 1.0]);
        let w = TensorF32::from_vec(&[2, 2], vec![-1.0, -1.0, 1.0, 1.0]);
        let mut ops = OpCounter::new();
        let y = flinear_fwd(&x, &w, &[0.0, 0.0], true, &mut ops);
        assert_eq!(y.data(), &[0.0, 2.0]);
    }

    /// The GEMM-routed float backward kernels must equal the scalar
    /// references exactly, across sizes and masks, with identical op
    /// accounting.
    #[test]
    fn gemm_bwd_equals_scalar_reference() {
        let mut rng = Pcg32::seeded(42);
        let mut scratch = crate::memplan::Scratch::new();
        for &(n_in, n_out) in &[(1usize, 1usize), (12, 5), (33, 17), (64, 10)] {
            let mut x = TensorF32::zeros(&[n_in]);
            rng.fill_normal(x.data_mut(), 1.0);
            let mut w = TensorF32::zeros(&[n_out, n_in]);
            rng.fill_normal(w.data_mut(), 0.3);
            let mut e = TensorF32::zeros(&[n_out]);
            rng.fill_normal(e.data_mut(), 1.0);
            let mask: Vec<bool> = (0..n_out).map(|i| i % 2 == 0).collect();
            for keep in [None, Some(&mask[..])] {
                let mut ops_s = OpCounter::new();
                let mut ops_g = OpCounter::new();
                let (gws, gbs) = flinear_bwd_weight(&e, &x, keep, &mut ops_s);
                let (gwg, gbg) = flinear_bwd_weight_gemm(&e, &x, keep, &mut ops_g);
                assert_eq!(gws.data(), gwg.data(), "gw {n_in}->{n_out}");
                assert_eq!(gbs.data(), gbg.data(), "gb {n_in}->{n_out}");
                assert_eq!(ops_s, ops_g, "bwd_weight ops {n_in}->{n_out}");

                let mut ops_s2 = OpCounter::new();
                let mut ops_g2 = OpCounter::new();
                let es = flinear_bwd_input(&e, &w, keep, &mut ops_s2);
                let eg = flinear_bwd_input_gemm(&e, &w, keep, &mut scratch, &mut ops_g2);
                assert_eq!(es.data(), eg.data(), "dx {n_in}->{n_out}");
                assert_eq!(ops_s2, ops_g2, "bwd_input ops {n_in}->{n_out}");
            }
        }
    }

    #[test]
    fn mask_skips_rows() {
        let x = TensorF32::from_vec(&[2], vec![1.0, 2.0]);
        let e = TensorF32::from_vec(&[2], vec![3.0, 4.0]);
        let keep = vec![false, true];
        let mut ops = OpCounter::new();
        let (gw, gb) = flinear_bwd_weight(&e, &x, Some(&keep), &mut ops);
        assert_eq!(gw.outer(0), &[0.0, 0.0]);
        assert_eq!(gw.outer(1), &[4.0, 8.0]);
        assert_eq!(gb.data(), &[0.0, 4.0]);
        assert_eq!(ops.float_macs, 2);
    }
}
