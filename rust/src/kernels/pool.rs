//! Pooling kernels: max pooling (with argmax capture for the backward
//! routing) and global average pooling, in both quantized and float flavors.
//!
//! Max pooling commutes with the monotone affine quantization map, so the
//! quantized forward operates directly on the uint8 codes and the output
//! reuses the input's quantization parameters — no requantization needed.
//! Ties pick the *first* maximum (row-major scan order); the Pallas kernels
//! implement the same first-occurrence rule so backward routing is
//! bit-identical across backends.

use crate::kernels::OpCounter;
use crate::quant::{requantize, QParams, QTensor};
use crate::tensor::{idx3, TensorF32, TensorU8};

/// Result of a max-pool forward: the pooled tensor plus, for every output
/// position, the flat input index that won (needed by the backward pass).
pub struct MaxPoolOut<T> {
    pub y: T,
    pub argmax: Vec<u32>,
}

/// Quantized max pool with square window/stride `k`.
pub fn qmaxpool_fwd(x: &QTensor, k: usize, ops: &mut OpCounter) -> MaxPoolOut<QTensor> {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    // window clamped to the input extent so 1-high (time-series) maps pool
    // along the remaining dimension instead of collapsing to zero size
    let (kh, kw) = (k.min(h), k.min(w));
    let (oh, ow) = (h / kh, w / kw);
    let xd = x.values.data();
    let mut y = TensorU8::zeros(&[c, oh, ow]);
    let mut argmax = vec![0u32; c * oh * ow];
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = 0u8;
                let mut best_i = 0u32;
                let mut first = true;
                for ky in 0..kh {
                    for kx in 0..kw {
                        let i = idx3(ci, oy * kh + ky, ox * kw + kx, h, w);
                        if first || xd[i] > best {
                            best = xd[i];
                            best_i = i as u32;
                            first = false;
                        }
                    }
                }
                let o = idx3(ci, oy, ox, oh, ow);
                y.data_mut()[o] = best;
                argmax[o] = best_i;
            }
        }
    }
    ops.int_ops += (c * oh * ow * kh * kw) as u64;
    ops.bytes += (x.len() + c * oh * ow) as u64;
    MaxPoolOut { y: QTensor { values: y, qp: x.qp }, argmax }
}

/// Quantized max pool backward: route each output error to the winning
/// input position; everything else gets the error zero point. The error
/// keeps its quantization parameters.
pub fn qmaxpool_bwd(
    e: &QTensor,
    argmax: &[u32],
    in_shape: &[usize],
    ops: &mut OpCounter,
) -> QTensor {
    let mut out = QTensor::zeros(in_shape, e.qp);
    let od = out.values.data_mut();
    for (o, &src) in e.values.data().iter().zip(argmax.iter()) {
        od[src as usize] = *o;
    }
    ops.int_ops += e.len() as u64;
    ops.bytes += (e.len() + out.len()) as u64;
    out
}

/// Float max pool.
pub fn fmaxpool_fwd(x: &TensorF32, k: usize, ops: &mut OpCounter) -> MaxPoolOut<TensorF32> {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (kh, kw) = (k.min(h), k.min(w));
    let (oh, ow) = (h / kh, w / kw);
    let xd = x.data();
    let mut y = TensorF32::zeros(&[c, oh, ow]);
    let mut argmax = vec![0u32; c * oh * ow];
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f32::NEG_INFINITY;
                let mut best_i = 0u32;
                for ky in 0..kh {
                    for kx in 0..kw {
                        let i = idx3(ci, oy * kh + ky, ox * kw + kx, h, w);
                        if xd[i] > best {
                            best = xd[i];
                            best_i = i as u32;
                        }
                    }
                }
                let o = idx3(ci, oy, ox, oh, ow);
                y.data_mut()[o] = best;
                argmax[o] = best_i;
            }
        }
    }
    ops.float_ops += (c * oh * ow * kh * kw) as u64;
    ops.bytes += ((x.len() + c * oh * ow) * 4) as u64;
    MaxPoolOut { y, argmax }
}

/// Float max pool backward.
pub fn fmaxpool_bwd(
    e: &TensorF32,
    argmax: &[u32],
    in_shape: &[usize],
    ops: &mut OpCounter,
) -> TensorF32 {
    let mut out = TensorF32::zeros(in_shape);
    for (ev, &src) in e.data().iter().zip(argmax.iter()) {
        out.data_mut()[src as usize] = *ev;
    }
    ops.float_ops += e.len() as u64;
    out
}

/// Quantized global average pool `[C,H,W] -> [C]`. The i32 channel sum is
/// requantized with multiplier `s_x / (H·W · s_out)`.
pub fn qgap_fwd(x: &QTensor, out_qp: QParams, ops: &mut OpCounter) -> QTensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let n = (h * w) as f32;
    let mult = x.qp.scale / (n * out_qp.scale);
    let mut y = QTensor::zeros(&[c], out_qp);
    for ci in 0..c {
        let mut acc = 0i32;
        for &v in x.values.outer(ci) {
            acc += v as i32 - x.qp.zero_point;
        }
        y.values.data_mut()[ci] = requantize(acc, mult, out_qp.zero_point, false);
    }
    ops.int_ops += x.len() as u64;
    ops.bytes += (x.len() + c) as u64;
    y
}

/// Quantized GAP backward: each input position receives `e/HW`; requantized
/// with multiplier `s_e / (H·W · s_out)`.
pub fn qgap_bwd(e: &QTensor, in_shape: &[usize], out_qp: QParams, ops: &mut OpCounter) -> QTensor {
    let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
    let n = (h * w) as f32;
    let mult = e.qp.scale / (n * out_qp.scale);
    let mut out = QTensor::zeros(in_shape, out_qp);
    for ci in 0..c {
        let ev = e.values.data()[ci] as i32 - e.qp.zero_point;
        let q = requantize(ev, mult, out_qp.zero_point, false);
        for o in out.values.outer_mut(ci) {
            *o = q;
        }
    }
    ops.int_ops += (c * h * w) as u64;
    out
}

/// Float GAP forward.
pub fn fgap_fwd(x: &TensorF32, ops: &mut OpCounter) -> TensorF32 {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let n = (h * w) as f32;
    let mut y = TensorF32::zeros(&[c]);
    for ci in 0..c {
        y.data_mut()[ci] = x.outer(ci).iter().sum::<f32>() / n;
    }
    ops.float_ops += x.len() as u64;
    y
}

/// Float GAP backward.
pub fn fgap_bwd(e: &TensorF32, in_shape: &[usize], ops: &mut OpCounter) -> TensorF32 {
    let (c, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
    let n = (h * w) as f32;
    let mut out = TensorF32::zeros(in_shape);
    for ci in 0..c {
        let v = e.data()[ci] / n;
        for o in out.data_mut()[ci * h * w..(ci + 1) * h * w].iter_mut() {
            *o = v;
        }
    }
    ops.float_ops += (c * h * w) as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QParams;
    use crate::util::prng::Pcg32;

    #[test]
    fn qmaxpool_commutes_with_dequant() {
        let mut rng = Pcg32::seeded(51);
        let mut xf = TensorF32::zeros(&[2, 4, 4]);
        rng.fill_normal(xf.data_mut(), 1.0);
        let xq = QTensor::quantize(&xf);
        let mut ops = OpCounter::new();
        let pooled = qmaxpool_fwd(&xq, 2, &mut ops);
        // pooling then dequantizing == dequantizing then pooling
        let deq = pooled.y.dequantize();
        let fx = xq.dequantize();
        let fp = fmaxpool_fwd(&fx, 2, &mut ops);
        assert_eq!(deq.data(), fp.y.data());
        assert_eq!(pooled.y.qp, xq.qp);
    }

    #[test]
    fn maxpool_bwd_routes_to_argmax() {
        let x = QTensor {
            values: TensorU8::from_vec(&[1, 2, 2], vec![10, 20, 30, 40]),
            qp: QParams::unit(),
        };
        let mut ops = OpCounter::new();
        let p = qmaxpool_fwd(&x, 2, &mut ops);
        assert_eq!(p.y.values.data(), &[40]);
        assert_eq!(p.argmax, vec![3]);
        let e = QTensor {
            values: TensorU8::from_vec(&[1, 1, 1], vec![200]),
            qp: QParams { scale: 0.1, zero_point: 128 },
        };
        let back = qmaxpool_bwd(&e, &p.argmax, &[1, 2, 2], &mut ops);
        assert_eq!(back.values.data(), &[128, 128, 128, 200]);
    }

    #[test]
    fn maxpool_tie_picks_first() {
        let x = QTensor {
            values: TensorU8::from_vec(&[1, 2, 2], vec![7, 7, 7, 7]),
            qp: QParams::unit(),
        };
        let mut ops = OpCounter::new();
        let p = qmaxpool_fwd(&x, 2, &mut ops);
        assert_eq!(p.argmax, vec![0]);
    }

    #[test]
    fn gap_fwd_bwd_roundtrip() {
        let mut rng = Pcg32::seeded(52);
        let mut xf = TensorF32::zeros(&[3, 4, 4]);
        rng.fill_normal(xf.data_mut(), 1.0);
        let xq = QTensor::quantize(&xf);
        let out_qp = QParams::from_min_max(-1.0, 1.0);
        let mut ops = OpCounter::new();
        let y = qgap_fwd(&xq, out_qp, &mut ops);
        // compare against float mean of dequantized input
        let fx = xq.dequantize();
        let fy = fgap_fwd(&fx, &mut ops);
        for (a, b) in y.dequantize().data().iter().zip(fy.data().iter()) {
            assert!((a - b).abs() < 2.0 * out_qp.scale, "{a} vs {b}");
        }
        // bwd distributes uniformly
        let in_qp = QParams::from_min_max(-0.5, 0.5);
        let back = qgap_bwd(&y, &[3, 4, 4], in_qp, &mut ops);
        for ci in 0..3 {
            let vals = back.values.outer(ci);
            assert!(vals.iter().all(|&v| v == vals[0]));
        }
    }

    #[test]
    fn fgap_bwd_uniform_scaling() {
        let e = TensorF32::from_vec(&[2], vec![4.0, 8.0]);
        let mut ops = OpCounter::new();
        let b = fgap_bwd(&e, &[2, 2, 2], &mut ops);
        assert!(b.outer(0).iter().all(|&v| (v - 1.0).abs() < 1e-6));
        assert!(b.outer(1).iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }
}
