//! Native (MCU-faithful) compute kernels.
//!
//! These are Rust ports of what the paper's C framework executes on the
//! Cortex-M: integer-only quantized conv / linear forward passes and their
//! two backward derivatives (Eq. 1 error backprop, Eq. 2 weight gradients),
//! plus pooling and the softmax cross-entropy head. Float twins exist for
//! the `float32` and `mixed` DNN configurations.
//!
//! Every kernel accounts its arithmetic into an [`OpCounter`]; the device
//! model (`crate::device`) converts op counts into per-MCU cycles and energy
//! (that is how the hardware study of Figs. 4b/5/6d/7b is simulated — see
//! DESIGN.md §7).
//!
//! Numerics contract: the integer paths here are **bit-exact** with the
//! Pallas kernels in `python/compile/kernels/` (same round-half-away-from-
//! zero, same i32 accumulation), verified end-to-end through PJRT in
//! `rust/tests/xla_cross_validation.rs`.

pub mod dwconv;
pub mod fconv;
pub mod flinear;
pub mod gemm;
pub mod pool;
pub mod qconv;
pub mod qlinear;
pub mod simd;
pub mod softmax;

/// Arithmetic accounting for the device cost model. A "MAC" is one
/// multiply-accumulate; `int_ops`/`float_ops` count non-MAC elementwise work
/// (requantization, masking, pooling compares).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCounter {
    pub int_macs: u64,
    pub float_macs: u64,
    pub int_ops: u64,
    pub float_ops: u64,
    /// Bytes moved through the activation arena (load + store), an input to
    /// the memory-bound part of the cost model.
    pub bytes: u64,
}

impl OpCounter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, other: &OpCounter) {
        self.int_macs += other.int_macs;
        self.float_macs += other.float_macs;
        self.int_ops += other.int_ops;
        self.float_ops += other.float_ops;
        self.bytes += other.bytes;
    }

    pub fn total_macs(&self) -> u64 {
        self.int_macs + self.float_macs
    }
}

/// Number of kept structures under an optional §III-B sparse-update mask
/// (`None` means everything is kept). Shared by the executor's telemetry
/// and the GEMM backward kernels' op accounting.
pub fn kept_count(keep: Option<&[bool]>, total: usize) -> usize {
    keep.map_or(total, |k| k.iter().filter(|&&b| b).count())
}

/// Geometry of a 2-D convolution (shared by fwd and both bwd kernels).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ConvGeom {
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    /// Depthwise convolution: `cout == cin`, one filter per channel.
    pub depthwise: bool,
}

impl ConvGeom {
    /// Output spatial size for an input of `(h, w)`.
    ///
    /// Degenerate geometry (a kernel larger than the padded input, or a
    /// zero stride) is reported with a descriptive panic instead of the
    /// silent usize underflow it used to produce.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(self.stride > 0, "conv stride must be non-zero");
        let (eh, ew) = (h + 2 * self.pad_h, w + 2 * self.pad_w);
        assert!(
            self.kh <= eh && self.kw <= ew,
            "conv kernel {}x{} exceeds padded input {}x{} (input {}x{}, padding {}x{})",
            self.kh,
            self.kw,
            eh,
            ew,
            h,
            w,
            self.pad_h,
            self.pad_w
        );
        ((eh - self.kh) / self.stride + 1, (ew - self.kw) / self.stride + 1)
    }

    /// Pointwise geometry (1×1 kernel, stride 1, no padding): the im2col
    /// packing is the identity, so the GEMM engine's fast paths skip it.
    /// Pure geometry — callers that need a dense conv check `depthwise`
    /// separately.
    pub fn is_pointwise(&self) -> bool {
        self.kh == 1 && self.kw == 1 && self.stride == 1 && self.pad_h == 0 && self.pad_w == 0
    }

    /// MACs of one forward pass over an `(h, w)` input.
    pub fn fwd_macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_hw(h, w);
        let per_out = if self.depthwise {
            self.kh * self.kw
        } else {
            self.cin * self.kh * self.kw
        };
        (self.cout * oh * ow * per_out) as u64
    }

    /// Number of weight parameters.
    pub fn weights(&self) -> usize {
        if self.depthwise {
            self.cout * self.kh * self.kw
        } else {
            self.cout * self.cin * self.kh * self.kw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geom_shapes() {
        let g = ConvGeom {
            cin: 3,
            cout: 8,
            kh: 3,
            kw: 3,
            stride: 2,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        assert_eq!(g.out_hw(32, 32), (16, 16));
        assert_eq!(g.weights(), 8 * 3 * 9);
        assert_eq!(g.fwd_macs(32, 32), (8 * 16 * 16 * 27) as u64);
    }

    #[test]
    fn depthwise_geom() {
        let g = ConvGeom {
            cin: 8,
            cout: 8,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: true,
        };
        assert_eq!(g.weights(), 8 * 9);
        assert_eq!(g.fwd_macs(10, 10), (8 * 10 * 10 * 9) as u64);
    }

    /// Regression: `kh > h + 2·pad_h` used to underflow usize and panic
    /// with an inscrutable overflow message (or wrap in release builds).
    #[test]
    #[should_panic(expected = "exceeds padded input")]
    fn oversized_kernel_panics_descriptively() {
        let g = ConvGeom {
            cin: 1,
            cout: 1,
            kh: 5,
            kw: 3,
            stride: 1,
            pad_h: 0,
            pad_w: 1,
            depthwise: false,
        };
        g.out_hw(2, 2);
    }

    #[test]
    #[should_panic(expected = "stride must be non-zero")]
    fn zero_stride_panics_descriptively() {
        let g = ConvGeom {
            cin: 1,
            cout: 1,
            kh: 1,
            kw: 1,
            stride: 0,
            pad_h: 0,
            pad_w: 0,
            depthwise: false,
        };
        g.out_hw(4, 4);
    }

    #[test]
    fn boundary_kernel_equal_to_padded_input_is_valid() {
        let g = ConvGeom {
            cin: 1,
            cout: 1,
            kh: 4,
            kw: 4,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        assert_eq!(g.out_hw(2, 2), (1, 1));
    }

    #[test]
    fn op_counter_accumulates() {
        let mut a = OpCounter { int_macs: 1, float_macs: 2, int_ops: 3, float_ops: 4, bytes: 5 };
        let b = a;
        a.add(&b);
        assert_eq!(a.int_macs, 2);
        assert_eq!(a.total_macs(), 6);
    }
}
