//! Quantized 2-D convolution: forward (Eq. 3), error backprop (Eq. 1 / Eq. 4)
//! and weight gradient (Eq. 2).
//!
//! Layouts: input `[Cin, H, W]`, weights `[Cout, Cin, Kh, Kw]` (depthwise:
//! `[C, 1, Kh, Kw]`), output `[Cout, Oh, Ow]`. All quantized tensors are
//! uint8 with per-tensor affine parameters; accumulation is i32 (exact — the
//! worst case `255·255·Cin·Kh·Kw` stays far below 2³¹ for every model here).
//!
//! Zero padding pads with the input zero point, so padded positions
//! contribute `(z_x − z_x)(w − z_w) = 0` and are simply skipped.
//!
//! Sparse gradient updates (§III-B): both backward kernels accept an
//! optional `keep` mask over **output channels** (the conv "structures" of
//! the paper). Masked-out channels are skipped entirely — their gradient is
//! not computed and they contribute nothing to the backpropagated error —
//! which is exactly the computational-tree pruning the paper describes.

use crate::kernels::simd::{self, KernelSel};
use crate::kernels::{gemm, kept_count, ConvGeom, OpCounter};
use crate::memplan::Scratch;
use crate::quant::subbyte::{PackedQTensor, WBits};
use crate::quant::{requant_multiplier, requantize, QParams, QTensor};
use crate::tensor::{idx3, idx4, TensorF32};

/// Forward pass of the folded QConv block (conv + bias + optional ReLU).
///
/// `bias` is i32 at scale `s_x·s_w` (see [`crate::quant::quantize_bias`]).
/// Returns the quantized output at `out_qp`.
pub fn qconv2d_fwd(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> QTensor {
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let zx = x.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(x.qp.scale, w.qp.scale, out_qp.scale);
    let xd = x.values.data();
    let wdat = w.values.data();

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    let od = out.values.data_mut();

    // Fast path for pointwise (1×1, stride 1, no pad) convolutions — the
    // dominant op of the MobileNet-style stacks (§Perf): a plain matmul
    // with the spatial dim innermost so the compiler can vectorize the
    // per-position MAC over a contiguous row.
    if geom.is_pointwise() && !geom.depthwise {
        let hw = h * wd;
        let mut acc = vec![0i32; hw];
        for co in 0..geom.cout {
            acc.fill(bias[co]);
            for ci in 0..geom.cin {
                let wv = wdat[co * geom.cin + ci] as i32 - zw;
                if wv == 0 {
                    continue;
                }
                let row = &xd[ci * hw..(ci + 1) * hw];
                for (a, &xv) in acc.iter_mut().zip(row.iter()) {
                    *a += wv * (xv as i32 - zx);
                }
            }
            let orow = &mut od[co * hw..(co + 1) * hw];
            for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                *o = requantize(a, mult, out_qp.zero_point, relu);
            }
        }
        ops.int_macs += geom.fwd_macs(h, wd);
        ops.int_ops += (geom.cout * oh * ow) as u64;
        ops.bytes += (x.len() + w.len() + geom.cout * oh * ow) as u64;
        return out;
    }

    let cin_per_filter = if geom.depthwise { 1 } else { geom.cin };
    for co in 0..geom.cout {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i32 = bias[co];
                for cf in 0..cin_per_filter {
                    let ci = if geom.depthwise { co } else { cf };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let xv = xd[idx3(ci, iy as usize, ix as usize, h, wd)] as i32 - zx;
                            let wv = wdat
                                [idx4(co, cf, ky, kx, cin_per_filter, geom.kh, geom.kw)]
                                as i32
                                - zw;
                            acc += xv * wv;
                        }
                    }
                }
                od[idx3(co, oy, ox, oh, ow)] = requantize(acc, mult, out_qp.zero_point, relu);
            }
        }
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * oh * ow) as u64; // requantization
    ops.bytes += (x.len() + w.len() + geom.cout * oh * ow) as u64;
    out
}

/// GEMM-routed forward of the folded QConv block: im2col packing plus the
/// tiled integer GEMM core of [`crate::kernels::gemm`], **bit-exact** with
/// [`qconv2d_fwd`] (i32 accumulation is order-independent; padded im2col
/// entries hold the input zero point and contribute exactly zero, matching
/// the scalar kernel's skip).
///
/// Non-depthwise geometry only — depthwise convolutions have no useful
/// im2col lowering and stay on the scalar kernel. For pointwise
/// (1×1/stride-1/no-pad) convs the packing step is skipped entirely: the
/// input's `[Cin, H·W]` layout already *is* the column matrix.
///
/// `scratch` supplies the packing/accumulator buffers (one arena per model
/// or per batch worker, see [`crate::memplan::Scratch`]); op accounting is
/// identical to the scalar kernel so the device cost model is unaffected
/// by the routing choice.
pub fn qconv2d_fwd_gemm(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qconv2d_fwd_gemm_sel(KernelSel::Auto, x, w, bias, geom, out_qp, relu, scratch, ops)
}

/// [`qconv2d_fwd_gemm`] with an explicit micro-kernel selection (see
/// [`crate::kernels::simd`]); the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_fwd_gemm_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    let zx = x.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(x.qp.scale, w.qp.scale, out_qp.scale);

    let pointwise = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    {
        let (col_buf, acc) =
            scratch.qconv_bufs(if pointwise { 0 } else { kdim * n }, geom.cout * n);
        let col: &[u8] = if pointwise {
            x.values.data()
        } else {
            gemm::im2col_u8(x.values.data(), h, wd, geom, oh, ow, x.qp.qzero(), col_buf);
            col_buf
        };
        gemm::gemm_u8_i32_sel(sel, w.values.data(), zw, col, zx, bias, geom.cout, kdim, n, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, relu);
        }
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * n) as u64; // requantization
    ops.bytes += (x.len() + w.len() + geom.cout * n) as u64;
    out
}

/// [`qconv2d_fwd_gemm`] with the quantized epilogue fused into the GEMM
/// micro-kernel ([`gemm::gemm_u8_i32_fused`]): requantization, bias add and
/// the folded ReLU run on the MR×NR accumulator tile while it is still in
/// registers, so the `Cout·Oh·Ow` i32 accumulator buffer of the unfused
/// path never materializes (the scratch request drops to the im2col packing
/// alone).
///
/// `dequant`: when `Some`, the float dequantization of the output is
/// emitted alongside it — the staging buffer of a `DequantizeOp` the plan
/// folded into this producer. Returns the output plus the count of
/// saturated output values (the telemetry `NativeModel::forward_adapt`
/// otherwise gathers with a separate sweep; see
/// [`gemm::gemm_u8_i32_fused`]).
///
/// Bit-identical to [`qconv2d_fwd_gemm`] (same GEMM core, same per-element
/// epilogue map) with identical op accounting — the unfused kernel is
/// retained as the parity oracle behind `TT_NO_FUSE=1`.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_fwd_gemm_fused(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    qconv2d_fwd_gemm_fused_sel(
        KernelSel::Auto,
        x,
        w,
        bias,
        geom,
        out_qp,
        relu,
        dequant,
        scratch,
        ops,
    )
}

/// [`qconv2d_fwd_gemm_fused`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_fwd_gemm_fused_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    let zx = x.qp.zero_point;
    let zw = w.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(x.qp.scale, w.qp.scale, out_qp.scale),
        qp: out_qp,
        relu,
    };
    let pointwise = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    let sat;
    {
        let (col_buf, _) = scratch.qconv_bufs(if pointwise { 0 } else { kdim * n }, 0);
        let col: &[u8] = if pointwise {
            x.values.data()
        } else {
            gemm::im2col_u8(x.values.data(), h, wd, geom, oh, ow, x.qp.qzero(), col_buf);
            col_buf
        };
        sat = gemm::gemm_u8_i32_fused_sel(
            sel,
            w.values.data(),
            zw,
            col,
            zx,
            bias,
            geom.cout,
            kdim,
            n,
            &epi,
            out.values.data_mut(),
            dequant,
        );
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * n) as u64; // requantization
    ops.bytes += (x.len() + w.len() + geom.cout * n) as u64;
    (out, sat)
}

/// Error backprop through the conv (Eq. 1, quantized per Eq. 4): given the
/// error `e` w.r.t. this layer's output (already ReLU-masked by the caller,
/// see [`relu_bwd_mask_q`]), produce the quantized error w.r.t. its input.
///
/// `keep`: optional per-output-channel mask from the sparse-update
/// controller; `None` means all channels participate.
pub fn qconv2d_bwd_input(
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> QTensor {
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale);
    let ed = e.values.data();
    let wdat = w.values.data();

    let cin_per_filter = if geom.depthwise { 1 } else { geom.cin };
    // Accumulate in i32 over the full input map (transposed-conv scatter
    // expressed as a gather per input position).
    let mut acc = vec![0i32; geom.cin * in_h * in_w];
    let mut kept_channels = 0u64;

    // Pointwise fast path (see qconv2d_fwd): per (co, ci) the weight tap is
    // constant, so the position loop is a vectorizable AXPY.
    if geom.is_pointwise() && !geom.depthwise {
        let hw = in_h * in_w;
        for co in 0..geom.cout {
            if let Some(k) = keep {
                if !k[co] {
                    continue;
                }
            }
            kept_channels += 1;
            let erow = &ed[co * hw..(co + 1) * hw];
            for ci in 0..geom.cin {
                let wv = wdat[co * geom.cin + ci] as i32 - zw;
                if wv == 0 {
                    continue;
                }
                let arow = &mut acc[ci * hw..(ci + 1) * hw];
                for (a, &evq) in arow.iter_mut().zip(erow.iter()) {
                    *a += wv * (evq as i32 - ze);
                }
            }
        }
        let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
        ops.int_macs += kept_channels * (hw * geom.cin) as u64;
        ops.int_ops += (geom.cin * hw) as u64;
        ops.bytes += (e.len() + w.len() + geom.cin * hw) as u64;
        return out;
    }

    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept_channels += 1;
        for oy in 0..oh {
            for ox in 0..ow {
                let ev = ed[idx3(co, oy, ox, oh, ow)] as i32 - ze;
                if ev == 0 {
                    continue; // exact zero error contributes nothing
                }
                for cf in 0..cin_per_filter {
                    let ci = if geom.depthwise { co } else { cf };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            let wv = wdat
                                [idx4(co, cf, ky, kx, cin_per_filter, geom.kh, geom.kw)]
                                as i32
                                - zw;
                            acc[idx3(ci, iy as usize, ix as usize, in_h, in_w)] += ev * wv;
                        }
                    }
                }
            }
        }
    }

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    let od = out.values.data_mut();
    for (o, &a) in od.iter_mut().zip(acc.iter()) {
        *o = requantize(a, mult, out_qp.zero_point, false);
    }

    let per_co = (oh * ow * cin_per_filter * geom.kh * geom.kw) as u64;
    ops.int_macs += kept_channels * per_co;
    ops.int_ops += (geom.cin * in_h * in_w) as u64;
    ops.bytes += (e.len() + w.len() + geom.cin * in_h * in_w) as u64;
    out
}

/// GEMM-routed error backprop, **bit-exact** with [`qconv2d_bwd_input`]:
/// the transposed conv is lowered to `dX[Cin, H·W] = wt_flip × colE` where
/// `wt_flip` is the flipped-transposed weight packing and `colE` the
/// backward im2col of the error (see [`crate::kernels::gemm`]); i32
/// accumulation makes the result independent of the lowering.
///
/// `keep` masks **whole GEMM rows**: masked output channels are dropped
/// from both packings, so the reduction depth shrinks from `Cout·Kh·Kw` to
/// `kept·Kh·Kw` — the Eq. 9 controller's kept ratio becomes a proportional
/// FLOP reduction rather than a per-element filter. Non-depthwise only;
/// op accounting is identical to the scalar kernel.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm(
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qconv2d_bwd_input_gemm_sel(KernelSel::Auto, e, w, geom, in_h, in_w, out_qp, keep, scratch, ops)
}

/// [`qconv2d_bwd_input_gemm`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale);
    let kc = kept_count(keep, geom.cout);
    let krow = kc * geom.kh * geom.kw;
    let n = in_h * in_w;
    // Dense pointwise shortcut: the error's `[Cout, H·W]` layout already is
    // the backward column matrix (flip and dilation are trivial at 1×1/s1).
    let pointwise_dense = geom.is_pointwise() && keep.is_none();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        // The flipped-weight buffer is reserved at its dense bound (the
        // kc == cout size) no matter how many channels the mask keeps, so
        // a sparse run grows the scratch arena exactly once — on its
        // first masked pack — instead of re-growing at every new
        // high-water kept count.
        let (wt_full, col_buf, acc, init) = scratch.qconv_bwd_bufs(
            geom.cin * geom.cout * geom.kh * geom.kw,
            if pointwise_dense { 0 } else { krow * n },
            geom.cin * n,
            geom.cin,
        );
        let wt_buf = &mut wt_full[..geom.cin * krow];
        gemm::pack_wt_flip_u8(w.values.data(), geom, keep, wt_buf);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                keep,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_sel(sel, wt_buf, zw, col, ze, init, geom.cin, krow, n, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += kc as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + w.len() + geom.cin * n) as u64;
    out
}

/// [`qconv2d_bwd_input_gemm`] with the requantization epilogue fused into
/// the GEMM micro-kernel: the `Cin·H·W` i32 accumulator of the unfused path
/// never materializes. Bit-identical to the unfused kernel with identical
/// op accounting (same GEMM core, same per-element epilogue map).
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_fused(
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qconv2d_bwd_input_gemm_fused_sel(
        KernelSel::Auto,
        e,
        w,
        geom,
        in_h,
        in_w,
        out_qp,
        keep,
        scratch,
        ops,
    )
}

/// [`qconv2d_bwd_input_gemm_fused`] with an explicit micro-kernel selection;
/// the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_fused_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let kc = kept_count(keep, geom.cout);
    let krow = kc * geom.kh * geom.kw;
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise() && keep.is_none();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (wt_full, col_buf, _, init) = scratch.qconv_bwd_bufs(
            geom.cin * geom.cout * geom.kh * geom.kw,
            if pointwise_dense { 0 } else { krow * n },
            0,
            geom.cin,
        );
        let wt_buf = &mut wt_full[..geom.cin * krow];
        gemm::pack_wt_flip_u8(w.values.data(), geom, keep, wt_buf);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                keep,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_fused_sel(
            sel,
            wt_buf,
            zw,
            col,
            ze,
            init,
            geom.cin,
            krow,
            n,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += kc as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + w.len() + geom.cin * n) as u64;
    out
}

/// Dense error backprop against a **pre-packed** flipped-transposed weight
/// matrix `wt_pack[Cin, Cout·Kh·Kw]` (the plan-owned pack cache,
/// `graph::packs`): bit-exact with [`qconv2d_bwd_input_gemm`] at
/// `keep == None`, with the per-sample `pack_wt_flip_u8` step skipped
/// entirely. `w` supplies the quantization parameters and byte accounting
/// only; `wt_pack` must be the dense packing of exactly those weights —
/// the cache's version check guarantees it. Op accounting is identical to
/// the unpacked dense call (the packing was never counted as MACs).
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed(
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qconv2d_bwd_input_gemm_packed_sel(
        KernelSel::Auto,
        e,
        w,
        wt_pack,
        geom,
        in_h,
        in_w,
        out_qp,
        scratch,
        ops,
    )
}

/// [`qconv2d_bwd_input_gemm_packed`] with an explicit micro-kernel
/// selection; the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale);
    let krow = geom.cout * geom.kh * geom.kw;
    assert_eq!(wt_pack.len(), geom.cin * krow, "packed weight size");
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (_, col_buf, acc, init) = scratch.qconv_bwd_bufs(
            0,
            if pointwise_dense { 0 } else { krow * n },
            geom.cin * n,
            geom.cin,
        );
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                None,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_sel(sel, wt_pack, zw, col, ze, init, geom.cin, krow, n, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += geom.cout as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + w.len() + geom.cin * n) as u64;
    out
}

/// [`qconv2d_bwd_input_gemm_packed`] with the requantization epilogue fused
/// into the GEMM micro-kernel (see [`qconv2d_bwd_input_gemm_fused`]).
/// Bit-identical to the unfused packed kernel with identical op accounting.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed_fused(
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qconv2d_bwd_input_gemm_packed_fused_sel(
        KernelSel::Auto,
        e,
        w,
        wt_pack,
        geom,
        in_h,
        in_w,
        out_qp,
        scratch,
        ops,
    )
}

/// [`qconv2d_bwd_input_gemm_packed_fused`] with an explicit micro-kernel
/// selection; the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed_fused_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = w.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, w.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let krow = geom.cout * geom.kh * geom.kw;
    assert_eq!(wt_pack.len(), geom.cin * krow, "packed weight size");
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (_, col_buf, _, init) = scratch.qconv_bwd_bufs(
            0,
            if pointwise_dense { 0 } else { krow * n },
            0,
            geom.cin,
        );
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                None,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_fused_sel(
            sel,
            wt_pack,
            zw,
            col,
            ze,
            init,
            geom.cin,
            krow,
            n,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += geom.cout as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + w.len() + geom.cin * n) as u64;
    out
}

// ---- packed sub-byte weight twins (`quant::subbyte`) ----------------------
//
// Each `_pa_sel` kernel is the packed-weight twin of the `_sel` kernel above
// it: the weight tensor arrives as a [`PackedQTensor`] (2 or 4 lanes per
// byte; `WBits::W8` is 1:1), the lanes are unpacked into scratch in one
// panel pass (`kernels::simd::unpack_lanes_sel` — SWAR word-parallel under
// SIMD selections) and the existing GEMM core runs on them unchanged.
// Because unpacked lanes are ordinary u8 values in `[0, qmax] ⊆ [0, 255]`
// and the GEMM only ever subtracts the zero point, a packed-8 call is
// bit-identical to its u8 twin; op accounting uses the *logical* lane count
// (`pw.len()`), keeping the device cost model independent of the storage
// width.

/// Packed-weight twin of [`qconv2d_fwd_gemm_sel`]: the weight lanes are
/// unpacked into the `wq_u8` scratch span and consumed as the GEMM A
/// operand. Bit-exact with the u8 kernel on `pw.to_qtensor()`.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_fwd_gemm_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    assert_eq!(pw.len(), geom.cout * kdim, "weight size");
    let zx = x.qp.zero_point;
    let zw = pw.qp.zero_point;
    let mult = requant_multiplier(x.qp.scale, pw.qp.scale, out_qp.scale);
    let pointwise = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    {
        let (wq, col_buf, acc) = scratch.qconv_pa_bufs(
            geom.cout * kdim,
            if pointwise { 0 } else { kdim * n },
            geom.cout * n,
        );
        let col: &[u8] = if pointwise {
            x.values.data()
        } else {
            gemm::im2col_u8(x.values.data(), h, wd, geom, oh, ow, x.qp.qzero(), col_buf);
            col_buf
        };
        gemm::gemm_u8_i32_pa_sel(
            sel,
            pw.data.data(),
            pw.bits,
            wq,
            zw,
            col,
            zx,
            bias,
            geom.cout,
            kdim,
            n,
            acc,
        );
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, relu);
        }
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * n) as u64; // requantization
    ops.bytes += (x.len() + pw.len() + geom.cout * n) as u64;
    out
}

/// Packed-weight twin of [`qconv2d_fwd_gemm_fused_sel`]. Bit-exact with the
/// u8 fused kernel on `pw.to_qtensor()`, same saturation count and dequant
/// emission.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_fwd_gemm_fused_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    dequant: Option<&mut [f32]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    assert_eq!(pw.len(), geom.cout * kdim, "weight size");
    let zx = x.qp.zero_point;
    let zw = pw.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(x.qp.scale, pw.qp.scale, out_qp.scale),
        qp: out_qp,
        relu,
    };
    let pointwise = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    let sat;
    {
        let (wq, col_buf, _) =
            scratch.qconv_pa_bufs(geom.cout * kdim, if pointwise { 0 } else { kdim * n }, 0);
        let col: &[u8] = if pointwise {
            x.values.data()
        } else {
            gemm::im2col_u8(x.values.data(), h, wd, geom, oh, ow, x.qp.qzero(), col_buf);
            col_buf
        };
        sat = gemm::gemm_u8_i32_fused_pa_sel(
            sel,
            pw.data.data(),
            pw.bits,
            wq,
            zw,
            col,
            zx,
            bias,
            geom.cout,
            kdim,
            n,
            &epi,
            out.values.data_mut(),
            dequant,
        );
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * n) as u64; // requantization
    ops.bytes += (x.len() + pw.len() + geom.cout * n) as u64;
    (out, sat)
}

/// Packed-weight twin of [`qconv2d_bwd_input_gemm_sel`]: the flip-transpose
/// pack extracts lanes straight from the packed weights
/// ([`gemm::pack_wt_flip_u8_pa`]), so no separate unpack pass or extra
/// scratch is needed. Bit-exact with the u8 kernel on `pw.to_qtensor()`.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale);
    let kc = kept_count(keep, geom.cout);
    let krow = kc * geom.kh * geom.kw;
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise() && keep.is_none();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (wt_full, col_buf, acc, init) = scratch.qconv_bwd_bufs(
            geom.cin * geom.cout * geom.kh * geom.kw,
            if pointwise_dense { 0 } else { krow * n },
            geom.cin * n,
            geom.cin,
        );
        let wt_buf = &mut wt_full[..geom.cin * krow];
        gemm::pack_wt_flip_u8_pa(pw.data.data(), pw.bits, geom, keep, wt_buf);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                keep,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_sel(sel, wt_buf, zw, col, ze, init, geom.cin, krow, n, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += kc as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + pw.len() + geom.cin * n) as u64;
    out
}

/// Packed-weight twin of [`qconv2d_bwd_input_gemm_fused_sel`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_fused_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let kc = kept_count(keep, geom.cout);
    let krow = kc * geom.kh * geom.kw;
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise() && keep.is_none();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (wt_full, col_buf, _, init) = scratch.qconv_bwd_bufs(
            geom.cin * geom.cout * geom.kh * geom.kw,
            if pointwise_dense { 0 } else { krow * n },
            0,
            geom.cin,
        );
        let wt_buf = &mut wt_full[..geom.cin * krow];
        gemm::pack_wt_flip_u8_pa(pw.data.data(), pw.bits, geom, keep, wt_buf);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                keep,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_fused_sel(
            sel,
            wt_buf,
            zw,
            col,
            ze,
            init,
            geom.cin,
            krow,
            n,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += kc as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + pw.len() + geom.cin * n) as u64;
    out
}

/// Packed-weight twin of [`qconv2d_bwd_input_gemm_packed_sel`]: the
/// plan-owned flip-transpose pack is itself stored packed at `bits`
/// (flipped *before* packing, so a plain lane unpack restores the flipped
/// layout). The whole pack is unpacked into the `wq_u8` scratch span —
/// distinct from the backward lane buffers — and the GEMM runs on it
/// unchanged. `pw` supplies quantization parameters and byte accounting.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    wt_pack: &[u8],
    bits: WBits,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let mult = requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale);
    let krow = geom.cout * geom.kh * geom.kw;
    let wt_lanes = geom.cin * krow;
    assert_eq!(wt_pack.len(), bits.packed_len(wt_lanes), "packed weight size");
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (wq, col_buf, acc, init) = scratch.qconv_bwd_pa_bufs(
            wt_lanes,
            if pointwise_dense { 0 } else { krow * n },
            geom.cin * n,
            geom.cin,
        );
        simd::unpack_lanes_sel(sel, wt_pack, wt_lanes, bits, wq);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                None,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_sel(sel, wq, zw, col, ze, init, geom.cin, krow, n, acc);
        for (o, &a) in out.values.data_mut().iter_mut().zip(acc.iter()) {
            *o = requantize(a, mult, out_qp.zero_point, false);
        }
    }

    ops.int_macs += geom.cout as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + pw.len() + geom.cin * n) as u64;
    out
}

/// Packed-weight twin of [`qconv2d_bwd_input_gemm_packed_fused_sel`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_input_gemm_packed_fused_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    wt_pack: &[u8],
    bits: WBits,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zw = pw.qp.zero_point;
    let epi = gemm::QEpilogue {
        mult: requant_multiplier(e.qp.scale, pw.qp.scale, out_qp.scale),
        qp: out_qp,
        relu: false,
    };
    let krow = geom.cout * geom.kh * geom.kw;
    let wt_lanes = geom.cin * krow;
    assert_eq!(wt_pack.len(), bits.packed_len(wt_lanes), "packed weight size");
    let n = in_h * in_w;
    let pointwise_dense = geom.is_pointwise();

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    {
        let (wq, col_buf, _, init) = scratch.qconv_bwd_pa_bufs(
            wt_lanes,
            if pointwise_dense { 0 } else { krow * n },
            0,
            geom.cin,
        );
        simd::unpack_lanes_sel(sel, wt_pack, wt_lanes, bits, wq);
        let col: &[u8] = if pointwise_dense {
            e.values.data()
        } else {
            gemm::im2col_bwd_u8(
                e.values.data(),
                oh,
                ow,
                geom,
                in_h,
                in_w,
                None,
                e.qp.qzero(),
                col_buf,
            );
            col_buf
        };
        gemm::gemm_u8_i32_fused_sel(
            sel,
            wq,
            zw,
            col,
            ze,
            init,
            geom.cin,
            krow,
            n,
            &epi,
            out.values.data_mut(),
            None,
        );
    }

    ops.int_macs += geom.cout as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.int_ops += (geom.cin * n) as u64;
    ops.bytes += (e.len() + pw.len() + geom.cin * n) as u64;
    out
}

/// Weight gradient (Eq. 2) in float: `∇W = (s_e · s_x) · Σ (e−z_e)(x−z_x)`.
/// Per the paper, the gradient is *not* requantized — the SGD step (Eq. 5)
/// consumes it in float space. Returns `(grad_w [Cout,Cf,Kh,Kw], grad_b
/// [Cout])`.
///
/// The reduction runs in i32 (exact: `|e·x| ≤ 255²·Oh·Ow` stays far below
/// 2³¹ for every model here) and is scaled to float once at the end, so the
/// result is independent of summation order — the property the GEMM twin
/// ([`qconv2d_bwd_weight_gemm`]) relies on for bit-exactness.
pub fn qconv2d_bwd_weight(
    e: &QTensor,
    x: &QTensor,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zx = x.qp.zero_point;
    let s = e.qp.scale * x.qp.scale;
    let ed = e.values.data();
    let xd = x.values.data();

    let cin_per_filter = if geom.depthwise { 1 } else { geom.cin };
    let mut gw = TensorF32::zeros(&[geom.cout, cin_per_filter, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    let gwd = gw.data_mut();
    let gbd = gb.data_mut();

    let mut kept_channels = 0u64;

    // Pointwise fast path: ∇W[co][ci] is a single dot product over the
    // spatial positions — i32-exact, vectorizable.
    if geom.is_pointwise() && !geom.depthwise {
        let hw = oh * ow;
        for co in 0..geom.cout {
            if let Some(k) = keep {
                if !k[co] {
                    continue;
                }
            }
            kept_channels += 1;
            let erow = &ed[co * hw..(co + 1) * hw];
            let mut bias_acc: i32 = 0;
            for &evq in erow {
                bias_acc += evq as i32 - ze;
            }
            gbd[co] = bias_acc as f32 * e.qp.scale;
            for ci in 0..geom.cin {
                let xrow = &xd[ci * hw..(ci + 1) * hw];
                let mut acc: i32 = 0;
                for (&evq, &xvq) in erow.iter().zip(xrow.iter()) {
                    acc += (evq as i32 - ze) * (xvq as i32 - zx);
                }
                gwd[co * geom.cin + ci] = acc as f32 * s;
            }
        }
        ops.int_macs += kept_channels * (hw * geom.cin) as u64;
        ops.float_ops += gw.len() as u64;
        ops.bytes += (e.len() + x.len() + gw.len() * 4) as u64;
        return (gw, gb);
    }

    let mut acc = vec![0i32; gwd.len()];
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept_channels += 1;
        let mut bias_acc: i32 = 0;
        for oy in 0..oh {
            for ox in 0..ow {
                let ev = ed[idx3(co, oy, ox, oh, ow)] as i32 - ze;
                bias_acc += ev;
                if ev == 0 {
                    continue;
                }
                for cf in 0..cin_per_filter {
                    let ci = if geom.depthwise { co } else { cf };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let xv = xd[idx3(ci, iy as usize, ix as usize, h, wd)] as i32 - zx;
                            acc[idx4(co, cf, ky, kx, cin_per_filter, geom.kh, geom.kw)] += ev * xv;
                        }
                    }
                }
            }
        }
        gbd[co] = bias_acc as f32 * e.qp.scale;
    }
    // Scale the i32-accumulated weight grads to float once at the end.
    for (g, &a) in gwd.iter_mut().zip(acc.iter()) {
        *g = a as f32 * s;
    }

    let per_co = (oh * ow * cin_per_filter * geom.kh * geom.kw) as u64;
    ops.int_macs += kept_channels * per_co;
    ops.float_ops += gw.len() as u64;
    ops.bytes += (e.len() + x.len() + gw.len() * 4) as u64;
    (gw, gb)
}

/// GEMM-routed weight gradient, **bit-exact** with [`qconv2d_bwd_weight`]:
/// `∇W[Cout, Cin·Kh·Kw] = E[Cout, Oh·Ow] × colᵀ` where `col` is the same
/// forward im2col packing of the layer input the forward GEMM uses — both
/// operands are row-major over the spatial reduction, so each gradient
/// element is one contiguous dot product ([`gemm::gemm_abt_u8_i32`]).
///
/// `keep` skips masked output channels as whole GEMM rows (their `∇W` rows
/// and `∇b` entries stay exactly zero, as with the scalar kernel). The i32
/// reduction matches the scalar kernel's exact accumulation. Non-depthwise
/// only; op accounting is identical to the scalar kernel.
pub fn qconv2d_bwd_weight_gemm(
    e: &QTensor,
    x: &QTensor,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    qconv2d_bwd_weight_gemm_sel(KernelSel::Auto, e, x, geom, keep, scratch, ops)
}

/// [`qconv2d_bwd_weight_gemm`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_bwd_weight_gemm_sel(
    sel: KernelSel,
    e: &QTensor,
    x: &QTensor,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zx = x.qp.zero_point;
    let s = e.qp.scale * x.qp.scale;
    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    let pointwise = geom.is_pointwise();

    let mut gw = TensorF32::zeros(&[geom.cout, geom.cin, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    {
        let (col_buf, acc) =
            scratch.qconv_bufs(if pointwise { 0 } else { kdim * n }, geom.cout * kdim);
        let col: &[u8] = if pointwise {
            x.values.data()
        } else {
            gemm::im2col_u8(x.values.data(), h, wd, geom, oh, ow, x.qp.qzero(), col_buf);
            col_buf
        };
        gemm::gemm_abt_u8_i32_sel(sel, e.values.data(), ze, col, zx, geom.cout, kdim, n, keep, acc);
        for (g, &a) in gw.data_mut().iter_mut().zip(acc.iter()) {
            *g = a as f32 * s;
        }
    }

    let ed = e.values.data();
    let gbd = gb.data_mut();
    let mut kept_channels = 0u64;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept_channels += 1;
        let mut bias_acc: i32 = 0;
        for &evq in &ed[co * n..(co + 1) * n] {
            bias_acc += evq as i32 - ze;
        }
        gbd[co] = bias_acc as f32 * e.qp.scale;
    }

    ops.int_macs += kept_channels * (n * geom.cin * geom.kh * geom.kw) as u64;
    ops.float_ops += gw.len() as u64;
    ops.bytes += (e.len() + x.len() + gw.len() * 4) as u64;
    (gw, gb)
}

/// ReLU backward for quantized error tensors: where the forward output sat
/// at its zero point (pre-activation ≤ 0), the gradient is zero — replace
/// the error with its own zero point.
pub fn relu_bwd_mask_q(e: &mut QTensor, y_fwd: &QTensor, ops: &mut OpCounter) {
    assert_eq!(e.shape(), y_fwd.shape());
    let zy = y_fwd.qp.qzero();
    let zev = e.qp.qzero();
    let yd = y_fwd.values.data();
    for (ev, &yv) in e.values.data_mut().iter_mut().zip(yd.iter()) {
        if yv <= zy {
            *ev = zev;
        }
    }
    ops.int_ops += e.len() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QParams;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    /// Float reference conv used as the oracle for the quantized kernel.
    fn ref_conv_f32(
        x: &TensorF32,
        w: &TensorF32,
        b: &[f32],
        g: &ConvGeom,
        relu: bool,
    ) -> TensorF32 {
        let (h, wd) = (x.shape()[1], x.shape()[2]);
        let (oh, ow) = g.out_hw(h, wd);
        let cf = if g.depthwise { 1 } else { g.cin };
        let mut out = TensorF32::zeros(&[g.cout, oh, ow]);
        for co in 0..g.cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = b[co];
                    for c in 0..cf {
                        let ci = if g.depthwise { co } else { c };
                        for ky in 0..g.kh {
                            let iy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..g.kw {
                                let ix = (ox * g.stride + kx) as isize - g.pad_w as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                acc += x.data()[idx3(ci, iy as usize, ix as usize, h, wd)]
                                    * w.data()[idx4(co, c, ky, kx, cf, g.kh, g.kw)];
                            }
                        }
                    }
                    out.data_mut()[idx3(co, oy, ox, oh, ow)] =
                        if relu { acc.max(0.0) } else { acc };
                }
            }
        }
        out
    }

    fn rand_setup(
        rng: &mut Pcg32,
        g: &ConvGeom,
        h: usize,
        w: usize,
    ) -> (TensorF32, TensorF32, Vec<f32>) {
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let cf = if g.depthwise { 1 } else { g.cin };
        let mut wt = TensorF32::zeros(&[g.cout, cf, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);
        let b: Vec<f32> = (0..g.cout).map(|_| rng.normal() * 0.1).collect();
        (x, wt, b)
    }

    /// The quantized forward must approximate the float forward to within a
    /// few output quantization steps (error budget: input/weight rounding
    /// amplified by the reduction, plus one output rounding).
    #[test]
    fn fwd_tracks_float_reference() {
        let mut rng = Pcg32::seeded(1);
        let g = ConvGeom {
            cin: 3,
            cout: 4,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let (x, wt, b) = rand_setup(&mut rng, &g, 8, 8);
        let yref = ref_conv_f32(&x, &wt, &b, &g, true);

        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        let oqp = QParams::observe(yref.data());
        let mut ops = OpCounter::new();
        let yq = qconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
        let y = yq.dequantize();

        let mut max_err = 0.0f32;
        for (a, r) in y.data().iter().zip(yref.data()) {
            max_err = max_err.max((a - r).abs());
        }
        // tolerance: ~couple of quantization steps across the reduction
        let tol = 3.0 * oqp.scale + 0.05;
        assert!(max_err < tol, "max_err={max_err} tol={tol}");
        assert_eq!(ops.int_macs, g.fwd_macs(8, 8));
    }

    #[test]
    fn depthwise_fwd_tracks_reference() {
        let mut rng = Pcg32::seeded(2);
        let g = ConvGeom {
            cin: 4,
            cout: 4,
            kh: 3,
            kw: 3,
            stride: 2,
            pad_h: 1,
            pad_w: 1,
            depthwise: true,
        };
        let (x, wt, b) = rand_setup(&mut rng, &g, 9, 9);
        let yref = ref_conv_f32(&x, &wt, &b, &g, false);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        let oqp = QParams::observe(yref.data());
        let mut ops = OpCounter::new();
        let y = qconv2d_fwd(&xq, &wq, &bq, &g, oqp, false, &mut ops).dequantize();
        for (a, r) in y.data().iter().zip(yref.data()) {
            assert!((a - r).abs() < 3.0 * oqp.scale + 0.05);
        }
    }

    /// bwd_input must match the float transposed conv on dequantized data.
    #[test]
    fn bwd_input_tracks_float_reference() {
        let mut rng = Pcg32::seeded(3);
        let g = ConvGeom {
            cin: 3,
            cout: 5,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let (h, w) = (6, 6);
        let (oh, ow) = g.out_hw(h, w);
        let mut e = TensorF32::zeros(&[g.cout, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, g.cin, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);

        // float reference: full scatter
        let mut eref = TensorF32::zeros(&[g.cin, h, w]);
        for co in 0..g.cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let ev = e.data()[idx3(co, oy, ox, oh, ow)];
                    for ci in 0..g.cin {
                        for ky in 0..g.kh {
                            let iy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..g.kw {
                                let ix = (ox * g.stride + kx) as isize - g.pad_w as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                eref.data_mut()[idx3(ci, iy as usize, ix as usize, h, w)] +=
                                    ev * wt.data()[idx4(co, ci, ky, kx, g.cin, g.kh, g.kw)];
                            }
                        }
                    }
                }
            }
        }

        let eq = QTensor::quantize(&e);
        let wq = QTensor::quantize(&wt);
        let oqp = QParams::observe(eref.data());
        let mut ops = OpCounter::new();
        let got = qconv2d_bwd_input(&eq, &wq, &g, h, w, oqp, None, &mut ops).dequantize();
        for (a, r) in got.data().iter().zip(eref.data()) {
            assert!((a - r).abs() < 4.0 * oqp.scale + 0.1, "{a} vs {r}");
        }
    }

    /// bwd_weight must match e ⊛ x computed in float.
    #[test]
    fn bwd_weight_tracks_float_reference() {
        let mut rng = Pcg32::seeded(4);
        let g = ConvGeom {
            cin: 2,
            cout: 3,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 0,
            pad_w: 0,
            depthwise: false,
        };
        let (h, w) = (6, 6);
        let (oh, ow) = g.out_hw(h, w);
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut e = TensorF32::zeros(&[g.cout, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);

        let mut gref = TensorF32::zeros(&[g.cout, g.cin, g.kh, g.kw]);
        for co in 0..g.cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let ev = e.data()[idx3(co, oy, ox, oh, ow)];
                    for ci in 0..g.cin {
                        for ky in 0..g.kh {
                            for kx in 0..g.kw {
                                let xv = x.data()[idx3(ci, oy + ky, ox + kx, h, w)];
                                gref.data_mut()[idx4(co, ci, ky, kx, g.cin, g.kh, g.kw)] +=
                                    ev * xv;
                            }
                        }
                    }
                }
            }
        }

        let eq = QTensor::quantize(&e);
        let xq = QTensor::quantize(&x);
        let mut ops = OpCounter::new();
        let (gw, gb) = qconv2d_bwd_weight(&eq, &xq, &g, None, &mut ops);
        // grad error budget ~ quant steps of e and x times reduction size
        let red = (oh * ow) as f32;
        let tol = red * (eq.qp.scale * xq.qp.scale) * 3.0 + red.sqrt() * 0.1;
        for (a, r) in gw.data().iter().zip(gref.data()) {
            assert!((a - r).abs() < tol, "{a} vs {r} tol={tol}");
        }
        // bias grad = sum of error per out channel
        for co in 0..g.cout {
            let want: f32 = (0..oh * ow).map(|i| e.data()[co * oh * ow + i]).sum();
            assert!((gb.data()[co] - want).abs() < red * eq.qp.scale);
        }
    }

    /// Masked-out channels must produce exactly zero gradient and exactly
    /// zero contribution to the backpropagated error.
    #[test]
    fn sparse_mask_skips_channels_exactly() {
        let mut rng = Pcg32::seeded(5);
        let g = ConvGeom {
            cin: 3,
            cout: 6,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let (h, w) = (5, 5);
        let (oh, ow) = g.out_hw(h, w);
        let mut e = TensorF32::zeros(&[g.cout, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, g.cin, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);

        let eq = QTensor::quantize(&e);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let keep = vec![true, false, true, false, false, true];

        let mut ops = OpCounter::new();
        let (gw, gb) = qconv2d_bwd_weight(&eq, &xq, &g, Some(&keep), &mut ops);
        for co in 0..g.cout {
            let z = gw.outer(co).iter().all(|&v| v == 0.0);
            assert_eq!(z, !keep[co], "channel {co}");
            if !keep[co] {
                assert_eq!(gb.data()[co], 0.0);
            }
        }

        // bwd_input with mask == bwd_input where masked channels' error is
        // replaced by the error zero point (exact-zero contribution).
        let oqp = QParams::from_min_max(-1.0, 1.0);
        let mut ops2 = OpCounter::new();
        let masked = qconv2d_bwd_input(&eq, &wq, &g, h, w, oqp, Some(&keep), &mut ops2);
        let mut ez = eq.clone();
        for co in 0..g.cout {
            if !keep[co] {
                let z = ez.qp.qzero();
                for v in ez.values.outer_mut(co) {
                    *v = z;
                }
            }
        }
        let mut ops3 = OpCounter::new();
        let zeroed = qconv2d_bwd_input(&ez, &wq, &g, h, w, oqp, None, &mut ops3);
        assert_eq!(masked.values.data(), zeroed.values.data());
        // and the mask must reduce counted MACs proportionally
        assert_eq!(ops2.int_macs, ops3.int_macs / 6 * 3);
    }

    #[test]
    fn relu_mask_zeroes_inactive_positions() {
        let y = QTensor {
            values: crate::tensor::TensorU8::from_vec(&[1, 2, 2], vec![5, 10, 5, 200]),
            qp: QParams { scale: 0.1, zero_point: 5 },
        };
        let mut e = QTensor {
            values: crate::tensor::TensorU8::from_vec(&[1, 2, 2], vec![77, 88, 99, 111]),
            qp: QParams { scale: 0.2, zero_point: 100 },
        };
        let mut ops = OpCounter::new();
        relu_bwd_mask_q(&mut e, &y, &mut ops);
        assert_eq!(e.values.data(), &[100, 88, 100, 111]);
    }

    /// Property: the GEMM-routed forward is bit-exact with the scalar
    /// reference across random geometries (kernel size, stride, padding,
    /// channel counts, relu on/off), and its op accounting is identical.
    #[test]
    fn prop_gemm_fwd_bit_exact_with_scalar() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let cin = 1 + r.below(5) as usize;
                let cout = 1 + r.below(6) as usize;
                let k = 1 + 2 * r.below(2) as usize; // 1 or 3
                let stride = 1 + r.below(2) as usize;
                let pad = r.below(2) as usize;
                let h = k.max(2) + r.below(8) as usize;
                (cin, cout, k, stride, pad, h, r.next_u64())
            },
            |&(cin, cout, k, stride, pad, h, s)| {
                shrink_dim(h, k).into_iter().map(|h2| (cin, cout, k, stride, pad, h2, s)).collect()
            },
            |&(cin, cout, k, stride, pad, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = ConvGeom {
                    cin,
                    cout,
                    kh: k,
                    kw: k,
                    stride,
                    pad_h: pad,
                    pad_w: pad,
                    depthwise: false,
                };
                let (x, wt, b) = rand_setup(&mut rng, &g, h, h);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
                let oqp = QParams::from_min_max(-2.0, 2.0);
                let relu = seed % 2 == 0;
                let mut ops_s = OpCounter::new();
                let mut ops_g = OpCounter::new();
                let ys = qconv2d_fwd(&xq, &wq, &bq, &g, oqp, relu, &mut ops_s);
                let mut scratch = crate::memplan::Scratch::new();
                let yg =
                    qconv2d_fwd_gemm(&xq, &wq, &bq, &g, oqp, relu, &mut scratch, &mut ops_g);
                if ys.values.data() != yg.values.data() {
                    return Err("GEMM forward differs from scalar reference".into());
                }
                if ops_s.int_macs != ops_g.int_macs || ops_s.int_ops != ops_g.int_ops {
                    return Err(format!(
                        "op accounting differs: macs {} vs {}, ops {} vs {}",
                        ops_s.int_macs, ops_g.int_macs, ops_s.int_ops, ops_g.int_ops
                    ));
                }
                Ok(())
            },
        );
    }

    /// Property: both GEMM-routed backward kernels are bit-exact with the
    /// scalar references across random geometries (kernel size, stride,
    /// padding, channel counts) and random sparse masks, with identical op
    /// accounting.
    #[test]
    fn prop_gemm_bwd_bit_exact_with_scalar() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let cin = 1 + r.below(5) as usize;
                let cout = 1 + r.below(6) as usize;
                let k = 1 + 2 * r.below(2) as usize; // 1 or 3
                let stride = 1 + r.below(2) as usize;
                let pad = r.below(2) as usize;
                let h = k.max(2) + r.below(8) as usize;
                (cin, cout, k, stride, pad, h, r.next_u64())
            },
            |&(cin, cout, k, stride, pad, h, s)| {
                shrink_dim(h, k).into_iter().map(|h2| (cin, cout, k, stride, pad, h2, s)).collect()
            },
            |&(cin, cout, k, stride, pad, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = ConvGeom {
                    cin,
                    cout,
                    kh: k,
                    kw: k,
                    stride,
                    pad_h: pad,
                    pad_w: pad,
                    depthwise: false,
                };
                let (oh, ow) = g.out_hw(h, h);
                let mut e = TensorF32::zeros(&[cout, oh, ow]);
                rng.fill_normal(e.data_mut(), 1.0);
                let (x, wt, _) = rand_setup(&mut rng, &g, h, h);
                let eq = QTensor::quantize(&e);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                // one of: dense, random mask, all-masked
                let keep: Option<Vec<bool>> = match seed % 3 {
                    0 => None,
                    1 => Some((0..cout).map(|_| rng.below(2) == 1).collect()),
                    _ => Some(vec![false; cout]),
                };
                let keep = keep.as_deref();
                let mut scratch = crate::memplan::Scratch::new();

                let mut ops_s = OpCounter::new();
                let mut ops_g = OpCounter::new();
                let (gws, gbs) = qconv2d_bwd_weight(&eq, &xq, &g, keep, &mut ops_s);
                let (gwg, gbg) =
                    qconv2d_bwd_weight_gemm(&eq, &xq, &g, keep, &mut scratch, &mut ops_g);
                if gws.data() != gwg.data() || gbs.data() != gbg.data() {
                    return Err("GEMM weight gradient differs from scalar".into());
                }
                if ops_s != ops_g {
                    return Err("bwd_weight op accounting differs".into());
                }

                let oqp = QParams::from_min_max(-2.0, 2.0);
                let mut ops_s2 = OpCounter::new();
                let mut ops_g2 = OpCounter::new();
                let es = qconv2d_bwd_input(&eq, &wq, &g, h, h, oqp, keep, &mut ops_s2);
                let eg = qconv2d_bwd_input_gemm(
                    &eq,
                    &wq,
                    &g,
                    h,
                    h,
                    oqp,
                    keep,
                    &mut scratch,
                    &mut ops_g2,
                );
                if es.values.data() != eg.values.data() {
                    return Err("GEMM input gradient differs from scalar".into());
                }
                if ops_s2 != ops_g2 {
                    return Err("bwd_input op accounting differs".into());
                }
                Ok(())
            },
        );
    }

    /// The GEMM path must also be bit-exact on the pointwise shortcut (no
    /// im2col copy) and reuse a shared scratch across different layers.
    #[test]
    fn gemm_fwd_pointwise_and_scratch_reuse() {
        let mut rng = Pcg32::seeded(9);
        let mut scratch = crate::memplan::Scratch::new();
        let oqp = QParams::from_min_max(-2.0, 2.0);
        for &(cin, cout, k, h) in &[(8usize, 16usize, 1usize, 6usize), (4, 8, 3, 7), (8, 4, 1, 5)] {
            let g = ConvGeom {
                cin,
                cout,
                kh: k,
                kw: k,
                stride: 1,
                pad_h: k / 2,
                pad_w: k / 2,
                depthwise: false,
            };
            let (x, wt, b) = rand_setup(&mut rng, &g, h, h);
            let xq = QTensor::quantize(&x);
            let wq = QTensor::quantize(&wt);
            let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
            let mut ops = OpCounter::new();
            let ys = qconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
            let yg = qconv2d_fwd_gemm(&xq, &wq, &bq, &g, oqp, true, &mut scratch, &mut ops);
            assert_eq!(ys.values.data(), yg.values.data(), "{cin}x{h}x{h} k{k}");
        }
    }

    /// The fused forward / backward-input kernels must be bit-identical to
    /// their unfused twins (values, op accounting), the fused forward's
    /// dequant emit must equal a full `dequantize()` of the output, and the
    /// returned saturation count must match a separate telemetry sweep.
    #[test]
    fn fused_kernels_bit_exact_with_unfused() {
        let mut rng = Pcg32::seeded(21);
        let mut scratch = crate::memplan::Scratch::new();
        let oqp = QParams::from_min_max(-2.0, 2.0);
        for &(cin, cout, k, stride, h, relu) in &[
            (3usize, 5usize, 3usize, 1usize, 7usize, true),
            (8, 6, 1, 1, 6, false),
            (2, 4, 3, 2, 9, false),
        ] {
            let g = ConvGeom {
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                pad_h: k / 2,
                pad_w: k / 2,
                depthwise: false,
            };
            let (x, wt, b) = rand_setup(&mut rng, &g, h, h);
            let xq = QTensor::quantize(&x);
            let wq = QTensor::quantize(&wt);
            let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);

            let mut ops_u = OpCounter::new();
            let mut ops_f = OpCounter::new();
            let yu = qconv2d_fwd_gemm(&xq, &wq, &bq, &g, oqp, relu, &mut scratch, &mut ops_u);
            let mut deq = vec![0f32; yu.len()];
            let (yf, sat) = qconv2d_fwd_gemm_fused(
                &xq,
                &wq,
                &bq,
                &g,
                oqp,
                relu,
                Some(&mut deq),
                &mut scratch,
                &mut ops_f,
            );
            assert_eq!(yu.values.data(), yf.values.data(), "fwd values");
            assert_eq!(ops_u, ops_f, "fwd op accounting");
            let want_deq = yu.dequantize();
            for (d, w) in deq.iter().zip(want_deq.data()) {
                assert_eq!(d.to_bits(), w.to_bits(), "dequant emit");
            }
            let want_sat = yu
                .values
                .data()
                .iter()
                .filter(|&&v| v == 255 || (!relu && v == 0))
                .count() as u64;
            assert_eq!(sat, want_sat, "saturation count");

            let (oh, ow) = g.out_hw(h, h);
            let mut e = TensorF32::zeros(&[cout, oh, ow]);
            rng.fill_normal(e.data_mut(), 1.0);
            let eq = QTensor::quantize(&e);
            for keep in [None, Some((0..cout).map(|i| i % 2 == 0).collect::<Vec<bool>>())] {
                let keep = keep.as_deref();
                let mut ops_bu = OpCounter::new();
                let mut ops_bf = OpCounter::new();
                let eu = qconv2d_bwd_input_gemm(
                    &eq, &wq, &g, h, h, oqp, keep, &mut scratch, &mut ops_bu,
                );
                let ef = qconv2d_bwd_input_gemm_fused(
                    &eq, &wq, &g, h, h, oqp, keep, &mut scratch, &mut ops_bf,
                );
                assert_eq!(eu.values.data(), ef.values.data(), "bwd_input values");
                assert_eq!(ops_bu, ops_bf, "bwd_input op accounting");
            }

            let krow = cout * k * k;
            let mut pack = vec![0u8; cin * krow];
            gemm::pack_wt_flip_u8(wq.values.data(), &g, None, &mut pack);
            let mut ops_pu = OpCounter::new();
            let mut ops_pf = OpCounter::new();
            let pu = qconv2d_bwd_input_gemm_packed(
                &eq, &wq, &pack, &g, h, h, oqp, &mut scratch, &mut ops_pu,
            );
            let pf = qconv2d_bwd_input_gemm_packed_fused(
                &eq, &wq, &pack, &g, h, h, oqp, &mut scratch, &mut ops_pf,
            );
            assert_eq!(pu.values.data(), pf.values.data(), "packed bwd_input values");
            assert_eq!(ops_pu, ops_pf, "packed bwd_input op accounting");
        }
    }

    /// Every `_pa_sel` kernel must be bit-identical to its u8 twin running
    /// on the allocating unpack (`PackedQTensor::to_qtensor`) of the same
    /// packed weights — at every width, including the pointwise shortcut
    /// and masked backward rows — with identical op accounting.
    #[test]
    fn packed_conv_paths_bit_exact_with_u8_twin() {
        let mut rng = Pcg32::seeded(33);
        let mut scratch = crate::memplan::Scratch::new();
        let oqp = QParams::from_min_max(-2.0, 2.0);
        for &(cin, cout, k, stride, h, relu) in
            &[(3usize, 5usize, 3usize, 1usize, 7usize, true), (8, 6, 1, 1, 6, false)]
        {
            let g = ConvGeom {
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                pad_h: k / 2,
                pad_w: k / 2,
                depthwise: false,
            };
            let (x, wt, b) = rand_setup(&mut rng, &g, h, h);
            let xq = QTensor::quantize(&x);
            let (oh, ow) = g.out_hw(h, h);
            let mut e = TensorF32::zeros(&[cout, oh, ow]);
            rng.fill_normal(e.data_mut(), 1.0);
            let eq = QTensor::quantize(&e);

            for bits in [WBits::W8, WBits::W4, WBits::W2] {
                let pw = PackedQTensor::quantize_bits(&wt, bits);
                let wq = pw.to_qtensor();
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);

                // forward, unfused + fused (with dequant emission + sat)
                let mut ops_a = OpCounter::new();
                let mut ops_b = OpCounter::new();
                let ya =
                    qconv2d_fwd_gemm(&xq, &wq, &bq, &g, oqp, relu, &mut scratch, &mut ops_a);
                let yb = qconv2d_fwd_gemm_pa_sel(
                    KernelSel::Auto,
                    &xq,
                    &pw,
                    &bq,
                    &g,
                    oqp,
                    relu,
                    &mut scratch,
                    &mut ops_b,
                );
                assert_eq!(ya.values.data(), yb.values.data(), "fwd {bits:?}");
                assert_eq!(ops_a, ops_b, "fwd ops {bits:?}");

                let mut deq_a = vec![0f32; ya.len()];
                let mut deq_b = vec![0f32; ya.len()];
                let mut ops_fa = OpCounter::new();
                let mut ops_fb = OpCounter::new();
                let (yfa, sat_a) = qconv2d_fwd_gemm_fused(
                    &xq,
                    &wq,
                    &bq,
                    &g,
                    oqp,
                    relu,
                    Some(&mut deq_a),
                    &mut scratch,
                    &mut ops_fa,
                );
                let (yfb, sat_b) = qconv2d_fwd_gemm_fused_pa_sel(
                    KernelSel::Auto,
                    &xq,
                    &pw,
                    &bq,
                    &g,
                    oqp,
                    relu,
                    Some(&mut deq_b),
                    &mut scratch,
                    &mut ops_fb,
                );
                assert_eq!(yfa.values.data(), yfb.values.data(), "fused fwd {bits:?}");
                assert_eq!(sat_a, sat_b, "fused sat {bits:?}");
                assert_eq!(ops_fa, ops_fb, "fused fwd ops {bits:?}");
                for (a, bv) in deq_a.iter().zip(deq_b.iter()) {
                    assert_eq!(a.to_bits(), bv.to_bits(), "dequant emit {bits:?}");
                }

                // backward input, dense + masked, unfused + fused
                for keep in
                    [None, Some((0..cout).map(|i| i % 2 == 0).collect::<Vec<bool>>())]
                {
                    let keep = keep.as_deref();
                    let mut ops_ba = OpCounter::new();
                    let mut ops_bb = OpCounter::new();
                    let ea = qconv2d_bwd_input_gemm(
                        &eq, &wq, &g, h, h, oqp, keep, &mut scratch, &mut ops_ba,
                    );
                    let eb = qconv2d_bwd_input_gemm_pa_sel(
                        KernelSel::Auto,
                        &eq,
                        &pw,
                        &g,
                        h,
                        h,
                        oqp,
                        keep,
                        &mut scratch,
                        &mut ops_bb,
                    );
                    assert_eq!(ea.values.data(), eb.values.data(), "bwd {bits:?}");
                    assert_eq!(ops_ba, ops_bb, "bwd ops {bits:?}");

                    let mut ops_fba = OpCounter::new();
                    let mut ops_fbb = OpCounter::new();
                    let efa = qconv2d_bwd_input_gemm_fused(
                        &eq, &wq, &g, h, h, oqp, keep, &mut scratch, &mut ops_fba,
                    );
                    let efb = qconv2d_bwd_input_gemm_fused_pa_sel(
                        KernelSel::Auto,
                        &eq,
                        &pw,
                        &g,
                        h,
                        h,
                        oqp,
                        keep,
                        &mut scratch,
                        &mut ops_fbb,
                    );
                    assert_eq!(efa.values.data(), efb.values.data(), "fused bwd {bits:?}");
                    assert_eq!(ops_fba, ops_fbb, "fused bwd ops {bits:?}");
                }

                // cached flipped pack: u8 cache vs sub-byte cache (flipped
                // before packing, so lane order survives the storage width)
                let krow = cout * k * k;
                let mut flip = vec![0u8; cin * krow];
                gemm::pack_wt_flip_u8(wq.values.data(), &g, None, &mut flip);
                let packed_flip = crate::quant::subbyte::pack_lanes(&flip, bits);
                let mut ops_pa = OpCounter::new();
                let mut ops_pb = OpCounter::new();
                let pa = qconv2d_bwd_input_gemm_packed(
                    &eq, &wq, &flip, &g, h, h, oqp, &mut scratch, &mut ops_pa,
                );
                let pb = qconv2d_bwd_input_gemm_packed_pa_sel(
                    KernelSel::Auto,
                    &eq,
                    &pw,
                    &packed_flip,
                    bits,
                    &g,
                    h,
                    h,
                    oqp,
                    &mut scratch,
                    &mut ops_pb,
                );
                assert_eq!(pa.values.data(), pb.values.data(), "cached bwd {bits:?}");
                assert_eq!(ops_pa, ops_pb, "cached bwd ops {bits:?}");

                let mut ops_qa = OpCounter::new();
                let mut ops_qb = OpCounter::new();
                let qa = qconv2d_bwd_input_gemm_packed_fused(
                    &eq, &wq, &flip, &g, h, h, oqp, &mut scratch, &mut ops_qa,
                );
                let qb = qconv2d_bwd_input_gemm_packed_fused_pa_sel(
                    KernelSel::Auto,
                    &eq,
                    &pw,
                    &packed_flip,
                    bits,
                    &g,
                    h,
                    h,
                    oqp,
                    &mut scratch,
                    &mut ops_qb,
                );
                assert_eq!(qa.values.data(), qb.values.data(), "cached fused bwd {bits:?}");
                assert_eq!(ops_qa, ops_qb, "cached fused bwd ops {bits:?}");
            }
        }
    }

    /// Property: forward output always within the uint8 range and exactly at
    /// z_out where ReLU clips.
    #[test]
    fn prop_fwd_relu_floor_is_zero_point() {
        Prop::new(24).check(
            |r: &mut Pcg32| {
                let cin = 1 + r.below(3) as usize;
                let cout = 1 + r.below(4) as usize;
                let h = 3 + r.below(5) as usize;
                (cin, cout, h, r.next_u64())
            },
            |&(cin, cout, h, s)| {
                shrink_dim(h, 3).into_iter().map(|h2| (cin, cout, h2, s)).collect()
            },
            |&(cin, cout, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = ConvGeom {
                    cin,
                    cout,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad_h: 1,
                    pad_w: 1,
                    depthwise: false,
                };
                let (x, wt, b) = rand_setup(&mut rng, &g, h, h);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
                let oqp = QParams::from_min_max(-1.0, 3.0);
                let mut ops = OpCounter::new();
                let y = qconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
                for &v in y.values.data() {
                    if (v as i32) < oqp.zero_point {
                        return Err(format!("value {v} below zero point {}", oqp.zero_point));
                    }
                }
                Ok(())
            },
        );
    }
}
