//! Register-blocked depthwise convolution engine: forward, error backprop
//! (dX) and weight gradient (dW), quantized (u8/i32) and float.
//!
//! Depthwise convolutions have no useful im2col lowering (the GEMM engine's
//! reduction dimension collapses to `Kh·Kw` per channel), so since PR 1
//! they fell back to the scalar per-element kernels in `qconv`/`fconv` —
//! dropping the paper's headline MCUNet-style workloads off the fast path.
//! This module is their dedicated engine, mirroring the PR 4 micro-kernel
//! architecture:
//!
//!  * **register blocking** — each output row is processed in [`NR`]-wide
//!    column tiles whose accumulators live in a fixed-size local array
//!    (registers after unrolling); every weight tap is broadcast across
//!    the tile and the input/error streams are unit-stride slices.
//!  * **stride-1 interior fast path** — at stride 1 the in-bounds tap
//!    span of a tile is contiguous in both the tile and the source row,
//!    so the inner loop is a bounds-check-free AXPY; only the padded
//!    borders clamp the span (out-of-bounds taps are *skipped*, exactly
//!    like the scalar kernels).
//!  * **numerics contract** — integer paths accumulate in i32 (exact:
//!    `255²·Kh·Kw` is far below 2³¹), so any tile schedule is
//!    **bit-exact** with the scalar reference kernels. The float paths
//!    add each output element's in-bounds taps in the scalar kernels'
//!    ascending `(ky, kx)` order (forward, dW over `(oy, ox)`) resp. the
//!    scatter-equivalent ascending `(oy, ox)` order (dX via the flipped
//!    kernel), so they are value-identical to the scalar kernels.
//!  * **sparse masks** — for a depthwise conv a masked *out*-channel is a
//!    masked *in*-channel: both backward kernels skip masked channels as
//!    whole per-channel planes, so the Eq. 9 controller's `kept/total`
//!    ratio maps directly onto proportional FLOPs in both backward
//!    directions (the depthwise twin of the GEMM row-skip contract).
//!  * **weight packs** — dX consumes the 180°-flipped per-channel kernel
//!    (`pack_dw_flip_*`, layout `[C, Kh·Kw]`). The dense flipped pack is
//!    a pure function of the layer weights and is plan-owned
//!    (`graph::packs`, version-keyed like the dense GEMM packs); because
//!    channels are independent, the *same* cached pack also serves masked
//!    calls — only a stale entry falls back to packing into scratch.
//!
//! The scalar kernels in `qconv`/`fconv` remain the MCU-faithful oracle;
//! op accounting here is identical to theirs, so the device cost model is
//! unaffected by the routing choice. Property tests at the bottom enforce
//! bit-exactness over random shapes, strides, paddings and masks.

use crate::kernels::gemm::NR;
use crate::kernels::simd::{self, tune, KernelSel};
use crate::kernels::{ConvGeom, OpCounter};
use crate::memplan::Scratch;
use crate::quant::subbyte::{self, PackedQTensor, WBits};
use crate::quant::{requant_multiplier, requantize, QParams, QTensor};
use crate::tensor::TensorF32;

/// Pack depthwise weights `[C, 1, Kh, Kw]` into the 180°-flipped layout
/// `[C, Kh·Kw]` consumed by the backward-input kernels: entry
/// `c·Kh·Kw + kyf·Kw + kxf` holds `w[c, Kh−1−kyf, Kw−1−kxf]`. The flip
/// makes the gather loop visit contributions in the scalar scatter
/// kernel's ascending `(oy, ox)` order (see the module docs).
fn pack_dw_flip<T: Copy>(wdat: &[T], geom: &ConvGeom, dst: &mut [T]) {
    assert!(geom.depthwise, "flipped depthwise packing requires depthwise geometry");
    let khw = geom.kh * geom.kw;
    assert_eq!(wdat.len(), geom.cout * khw, "weight size");
    assert_eq!(dst.len(), geom.cout * khw, "packed buffer size");
    for c in 0..geom.cout {
        for kyf in 0..geom.kh {
            let ky = geom.kh - 1 - kyf;
            for kxf in 0..geom.kw {
                let kx = geom.kw - 1 - kxf;
                dst[c * khw + kyf * geom.kw + kxf] = wdat[c * khw + ky * geom.kw + kx];
            }
        }
    }
}

/// u8 flipped depthwise weight packing (see [`pack_dw_flip`]).
pub fn pack_dw_flip_u8(wdat: &[u8], geom: &ConvGeom, dst: &mut [u8]) {
    pack_dw_flip(wdat, geom, dst);
}

/// f32 twin of [`pack_dw_flip_u8`].
pub fn pack_dw_flip_f32(wdat: &[f32], geom: &ConvGeom, dst: &mut [f32]) {
    pack_dw_flip(wdat, geom, dst);
}

/// Packed-weight twin of [`pack_dw_flip_u8`]: reads the depthwise weights
/// straight from their packed sub-byte representation and writes plain u8
/// lanes in the flipped `[C, Kh·Kw]` layout. Lanes are addressed by global
/// index (`c·Kh·Kw + ky·Kw + kx` through [`subbyte::extract_lane`]) because
/// a channel plane's base offset is not byte-aligned when `Kh·Kw` is odd —
/// e.g. a 3×3 kernel at 2 or 4 lanes per byte. Bit-identical to unpacking
/// the whole tensor and running [`pack_dw_flip_u8`] (tested).
pub fn pack_dw_flip_u8_pa(packed: &[u8], bits: WBits, geom: &ConvGeom, dst: &mut [u8]) {
    assert!(geom.depthwise, "flipped depthwise packing requires depthwise geometry");
    let khw = geom.kh * geom.kw;
    assert_eq!(packed.len(), bits.packed_len(geom.cout * khw), "packed weight size");
    assert_eq!(dst.len(), geom.cout * khw, "packed buffer size");
    for c in 0..geom.cout {
        for kyf in 0..geom.kh {
            let ky = geom.kh - 1 - kyf;
            for kxf in 0..geom.kw {
                let kx = geom.kw - 1 - kxf;
                dst[c * khw + kyf * geom.kw + kxf] =
                    subbyte::extract_lane(packed, c * khw + ky * geom.kw + kx, bits);
            }
        }
    }
}

/// Blocked quantized depthwise forward, **bit-exact** with
/// [`crate::kernels::qconv::qconv2d_fwd`] on depthwise geometry (exact
/// order-independent i32 sums; out-of-bounds taps skipped on both paths).
/// Op accounting is identical to the scalar kernel.
pub fn qdwconv2d_fwd(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_fwd_impl(KernelSel::Auto, x, w, bias, geom, out_qp, relu, ops).0
}

/// [`qdwconv2d_fwd`] with an explicit micro-kernel selection (see
/// [`crate::kernels::simd`]); the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_fwd_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_fwd_impl(sel, x, w, bias, geom, out_qp, relu, ops).0
}

/// [`qdwconv2d_fwd`] that also returns the saturated-value count of the
/// output (`q == 255`, plus `q == 0` when `relu` is off — the clipped-range
/// telemetry the executor's range-adaptation sweep otherwise recomputes
/// with a separate pass over the tensor). The depthwise engine has fused
/// its requantize epilogue into the register tile since PR 5; this entry
/// point exposes the tile-resident saturation count to the fused `ExecPlan`
/// path. Output bytes and op accounting are identical to
/// [`qdwconv2d_fwd`].
pub fn qdwconv2d_fwd_fused(
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    qdwconv2d_fwd_impl(KernelSel::Auto, x, w, bias, geom, out_qp, relu, ops)
}

/// [`qdwconv2d_fwd_fused`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_fwd_fused_sel(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    qdwconv2d_fwd_impl(sel, x, w, bias, geom, out_qp, relu, ops)
}

#[allow(clippy::too_many_arguments)]
fn qdwconv2d_fwd_impl(
    sel: KernelSel,
    x: &QTensor,
    w: &QTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    qdwconv2d_fwd_core(sel, x, w.qp, w.len(), w.values.data(), bias, geom, out_qp, relu, ops)
}

/// [`qdwconv2d_fwd_fused_sel`] over a packed sub-byte weight tensor: the
/// weights are unpacked once into the scratch arena's depthwise lane span
/// (a panel pass, dispatched under the same `sel` as the kernel), then the
/// unchanged forward core runs on the lanes. Unpacked lanes are ordinary
/// affine values, so a packed-8 call is bit-identical to
/// [`qdwconv2d_fwd_fused_sel`] on the u8 twin; op accounting uses the
/// *logical* lane count, keeping the device cost model independent of the
/// storage width.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_fwd_fused_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    let wdat = scratch.dw_wt_u8(pw.len());
    simd::unpack_lanes_sel(sel, pw.data.data(), pw.len(), pw.bits, wdat);
    qdwconv2d_fwd_core(sel, x, pw.qp, pw.len(), wdat, bias, geom, out_qp, relu, ops)
}

/// Unfused twin of [`qdwconv2d_fwd_fused_pa_sel`] (drops the saturation
/// count), mirroring the [`qdwconv2d_fwd_sel`] / fused split.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_fwd_pa_sel(
    sel: KernelSel,
    x: &QTensor,
    pw: &PackedQTensor,
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_fwd_fused_pa_sel(sel, x, pw, bias, geom, out_qp, relu, scratch, ops).0
}

/// The shared forward core: weights arrive as plain u8 lanes plus their
/// quantization parameters, so the same body serves the [`QTensor`] path
/// (borrowing the tensor's payload) and the packed sub-byte path
/// (borrowing the scratch unpack span) — one compute loop, one numerics
/// contract.
#[allow(clippy::too_many_arguments)]
fn qdwconv2d_fwd_core(
    sel: KernelSel,
    x: &QTensor,
    wqp: QParams,
    wlen: usize,
    wdat: &[u8],
    bias: &[i32],
    geom: &ConvGeom,
    out_qp: QParams,
    relu: bool,
    ops: &mut OpCounter,
) -> (QTensor, u64) {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    assert_eq!(geom.cin, geom.cout, "depthwise conv has one filter per channel");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");
    let khw = geom.kh * geom.kw;
    let zx = x.qp.zero_point;
    let zw = wqp.zero_point;
    let mult = requant_multiplier(x.qp.scale, wqp.scale, out_qp.scale);
    let xd = x.values.data();
    assert_eq!(wlen, geom.cout * khw, "weight size");
    let wdat = &wdat[..wlen];

    let mut out = QTensor::zeros(&[geom.cout, oh, ow], out_qp);
    let od = out.values.data_mut();
    let count_lo = !relu;
    let mut sat = 0u64;
    // One ISA resolution per call: the stride-1 AXPY spans are `ow`-bounded,
    // so the per-layer tune verdict covers every tap of the map.
    let isa = simd::resolve_isa(sel, tune::prefer_axpy(ow));
    for c in 0..geom.cout {
        let plane = &xd[c * h * wd..(c + 1) * h * wd];
        let wch = &wdat[c * khw..(c + 1) * khw];
        let obase = c * oh * ow;
        for oy in 0..oh {
            let mut ox0 = 0usize;
            while ox0 < ow {
                let nrr = NR.min(ow - ox0);
                // NR i32 accumulators in a fixed-size local array — the
                // register tile; i32 sums are exact, so the tiling is
                // bit-identical to the scalar per-element loop.
                let mut acc = [0i32; NR];
                acc[..nrr].fill(bias[c]);
                for ky in 0..geom.kh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let xrow = &plane[iy as usize * wd..(iy as usize + 1) * wd];
                    for kx in 0..geom.kw {
                        let wv = wch[ky * geom.kw + kx] as i32 - zw;
                        if geom.stride == 1 {
                            // ix(jj) = ox0 + jj + kx − pad_w: the in-bounds
                            // jj span is contiguous — a unit-stride AXPY.
                            let lo = geom.pad_w.saturating_sub(ox0 + kx).min(nrr);
                            let hi = (wd + geom.pad_w).saturating_sub(ox0 + kx).min(nrr).max(lo);
                            if hi > lo {
                                let src = ox0 + lo + kx - geom.pad_w;
                                let xs = &xrow[src..src + (hi - lo)];
                                simd::axpy_u8_i32(isa, &mut acc[lo..hi], xs, zx, wv);
                            }
                        } else {
                            for (jj, a) in acc[..nrr].iter_mut().enumerate() {
                                let ix = ((ox0 + jj) * geom.stride + kx) as isize
                                    - geom.pad_w as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                *a += wv * (xrow[ix as usize] as i32 - zx);
                            }
                        }
                    }
                }
                let orow = &mut od[obase + oy * ow + ox0..obase + oy * ow + ox0 + nrr];
                for (o, &a) in orow.iter_mut().zip(acc[..nrr].iter()) {
                    let q = requantize(a, mult, out_qp.zero_point, relu);
                    *o = q;
                    sat += (q == 255 || (count_lo && q == 0)) as u64;
                }
                ox0 += nrr;
            }
        }
    }

    ops.int_macs += geom.fwd_macs(h, wd);
    ops.int_ops += (geom.cout * oh * ow) as u64;
    ops.bytes += (x.len() + wlen + geom.cout * oh * ow) as u64;
    (out, sat)
}

/// Blocked float depthwise forward, value-identical to
/// [`crate::kernels::fconv::fconv2d_fwd`] on depthwise geometry: each
/// output element's in-bounds taps are added in the scalar kernel's
/// ascending `(ky, kx)` order and out-of-bounds taps are skipped, so the
/// per-element sums are bit-for-bit the same.
pub fn fdwconv2d_fwd(
    x: &TensorF32,
    w: &TensorF32,
    bias: &[f32],
    geom: &ConvGeom,
    relu: bool,
    ops: &mut OpCounter,
) -> TensorF32 {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    assert_eq!(geom.cin, geom.cout, "depthwise conv has one filter per channel");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");
    let khw = geom.kh * geom.kw;
    let xd = x.data();
    let wdat = w.data();
    assert_eq!(wdat.len(), geom.cout * khw, "weight size");

    let mut out = TensorF32::zeros(&[geom.cout, oh, ow]);
    let od = out.data_mut();
    // Element-wise AXPY spans are bit-identical under vectorization (no
    // cross-lane reduction), so the float forward may always auto-resolve.
    let isa = simd::resolve_isa(KernelSel::Auto, tune::prefer_axpy(ow));
    for c in 0..geom.cout {
        let plane = &xd[c * h * wd..(c + 1) * h * wd];
        let wch = &wdat[c * khw..(c + 1) * khw];
        let obase = c * oh * ow;
        for oy in 0..oh {
            let mut ox0 = 0usize;
            while ox0 < ow {
                let nrr = NR.min(ow - ox0);
                let mut acc = [0f32; NR];
                acc[..nrr].fill(bias[c]);
                for ky in 0..geom.kh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let xrow = &plane[iy as usize * wd..(iy as usize + 1) * wd];
                    for kx in 0..geom.kw {
                        let wv = wch[ky * geom.kw + kx];
                        if geom.stride == 1 {
                            let lo = geom.pad_w.saturating_sub(ox0 + kx).min(nrr);
                            let hi = (wd + geom.pad_w).saturating_sub(ox0 + kx).min(nrr).max(lo);
                            if hi > lo {
                                let src = ox0 + lo + kx - geom.pad_w;
                                let xs = &xrow[src..src + (hi - lo)];
                                simd::axpy_f32(isa, &mut acc[lo..hi], xs, wv);
                            }
                        } else {
                            for (jj, a) in acc[..nrr].iter_mut().enumerate() {
                                let ix = ((ox0 + jj) * geom.stride + kx) as isize
                                    - geom.pad_w as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                *a += wv * xrow[ix as usize];
                            }
                        }
                    }
                }
                let orow = &mut od[obase + oy * ow + ox0..obase + oy * ow + ox0 + nrr];
                for (o, &a) in orow.iter_mut().zip(acc[..nrr].iter()) {
                    *o = if relu { a.max(0.0) } else { a };
                }
                ox0 += nrr;
            }
        }
    }

    ops.float_macs += geom.fwd_macs(h, wd);
    ops.bytes += ((x.len() + w.len() + geom.cout * oh * ow) * 4) as u64;
    out
}

/// Blocked quantized depthwise error backprop against a **pre-packed**
/// flipped kernel `wt_pack[C, Kh·Kw]` ([`pack_dw_flip_u8`] — typically the
/// plan-owned cache entry, `graph::packs`). **Bit-exact** with
/// [`crate::kernels::qconv::qconv2d_bwd_input`] on depthwise geometry for
/// any `keep` mask: i32 sums are exact, and masked channels produce the
/// same all-zero accumulator planes the scalar kernel requantizes.
///
/// Because depthwise channels are independent, a masked call consumes the
/// *dense* pack and simply skips masked planes — kept/total maps directly
/// to proportional FLOPs, and the cache stays valid under every mask. `w`
/// supplies the quantization parameters and byte accounting only; op
/// accounting is identical to the scalar kernel.
pub fn qdwconv2d_bwd_input_packed(
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_bwd_input_packed_sel(
        KernelSel::Auto,
        e,
        w,
        wt_pack,
        geom,
        in_h,
        in_w,
        out_qp,
        keep,
        ops,
    )
}

/// [`qdwconv2d_bwd_input_packed`] with an explicit micro-kernel selection;
/// the plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_bwd_input_packed_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_bwd_input_core(sel, e, w.qp, w.len(), wt_pack, geom, in_h, in_w, out_qp, keep, ops)
}

/// [`qdwconv2d_bwd_input_packed_sel`] over a packed sub-byte cache entry:
/// `wt_pack` holds the 180°-flipped kernel packed at `bits` lanes per
/// byte (flipped *before* packing, so a plain lane unpack restores the
/// flipped layout). The entry is unpacked once into the scratch arena's
/// depthwise lane span, then the unchanged backward core runs — bit-exact
/// with the u8 cached path on the same lanes. `pw` supplies quantization
/// parameters and the logical lane count for op accounting.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_bwd_input_packed_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    wt_pack: &[u8],
    bits: WBits,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let khw = geom.kh * geom.kw;
    let wt = scratch.dw_wt_u8(geom.cout * khw);
    simd::unpack_lanes_sel(sel, wt_pack, geom.cout * khw, bits, wt);
    qdwconv2d_bwd_input_core(sel, e, pw.qp, pw.len(), wt, geom, in_h, in_w, out_qp, keep, ops)
}

/// [`qdwconv2d_bwd_input_packed_pa_sel`] without a plan-owned pack: flips
/// the packed weights into the scratch arena lane-by-lane
/// ([`pack_dw_flip_u8_pa`] — the stale-cache bypass path), then runs the
/// shared backward core. Bit-exact with the cached route either way.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_bwd_input_pa_sel(
    sel: KernelSel,
    e: &QTensor,
    pw: &PackedQTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let wt = scratch.dw_wt_u8(geom.cout * geom.kh * geom.kw);
    pack_dw_flip_u8_pa(pw.data.data(), pw.bits, geom, wt);
    qdwconv2d_bwd_input_core(sel, e, pw.qp, pw.len(), wt, geom, in_h, in_w, out_qp, keep, ops)
}

/// The shared backward-input core (see [`qdwconv2d_fwd_core`] for the
/// lane-parameterization rationale): the flipped pack arrives as plain u8
/// lanes plus the weight tensor's quantization parameters and logical
/// length, serving both the [`QTensor`] cache and the packed sub-byte
/// cache through one compute loop.
#[allow(clippy::too_many_arguments)]
fn qdwconv2d_bwd_input_core(
    sel: KernelSel,
    e: &QTensor,
    wqp: QParams,
    wlen: usize,
    wt_pack: &[u8],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> QTensor {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let khw = geom.kh * geom.kw;
    let wt_pack = &wt_pack[..geom.cout * khw];
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }
    let ze = e.qp.zero_point;
    let zw = wqp.zero_point;
    let mult = requant_multiplier(e.qp.scale, wqp.scale, out_qp.scale);
    let ed = e.values.data();
    let s = geom.stride as isize;

    let mut out = QTensor::zeros(&[geom.cin, in_h, in_w], out_qp);
    let od = out.values.data_mut();
    // What the scalar kernel writes for a skipped channel's plane: the
    // requantization of an untouched (all-zero) accumulator.
    let zero_out = requantize(0, mult, out_qp.zero_point, false);
    let isa = simd::resolve_isa(sel, tune::prefer_axpy(in_w));
    let mut kept_channels = 0u64;
    for c in 0..geom.cout {
        let oplane = &mut od[c * in_h * in_w..(c + 1) * in_h * in_w];
        if let Some(k) = keep {
            if !k[c] {
                oplane.fill(zero_out);
                continue;
            }
        }
        kept_channels += 1;
        let eplane = &ed[c * oh * ow..(c + 1) * oh * ow];
        let wch = &wt_pack[c * khw..(c + 1) * khw];
        for iy in 0..in_h {
            let mut ix0 = 0usize;
            while ix0 < in_w {
                let nrr = NR.min(in_w - ix0);
                let mut acc = [0i32; NR];
                for kyf in 0..geom.kh {
                    let ky = geom.kh - 1 - kyf;
                    let ty = iy as isize + geom.pad_h as isize - ky as isize;
                    if ty < 0 || ty % s != 0 || ty / s >= oh as isize {
                        continue;
                    }
                    let erow = &eplane[(ty / s) as usize * ow..((ty / s) as usize + 1) * ow];
                    for kxf in 0..geom.kw {
                        let kx = geom.kw - 1 - kxf;
                        let wv = wch[kyf * geom.kw + kxf] as i32 - zw;
                        if geom.stride == 1 {
                            // ox(jj) = ix0 + jj + pad_w − kx: contiguous
                            // in-bounds span — a unit-stride AXPY.
                            let lo = kx.saturating_sub(geom.pad_w + ix0).min(nrr);
                            let hi = (ow + kx).saturating_sub(geom.pad_w + ix0).min(nrr).max(lo);
                            if hi > lo {
                                let src = ix0 + lo + geom.pad_w - kx;
                                let es = &erow[src..src + (hi - lo)];
                                simd::axpy_u8_i32(isa, &mut acc[lo..hi], es, ze, wv);
                            }
                        } else {
                            for (jj, a) in acc[..nrr].iter_mut().enumerate() {
                                let tx = (ix0 + jj) as isize + geom.pad_w as isize - kx as isize;
                                if tx < 0 || tx % s != 0 || tx / s >= ow as isize {
                                    continue;
                                }
                                *a += wv * (erow[(tx / s) as usize] as i32 - ze);
                            }
                        }
                    }
                }
                let orow = &mut oplane[iy * in_w + ix0..iy * in_w + ix0 + nrr];
                for (o, &a) in orow.iter_mut().zip(acc[..nrr].iter()) {
                    *o = requantize(a, mult, out_qp.zero_point, false);
                }
                ix0 += nrr;
            }
        }
    }

    ops.int_macs += kept_channels * (oh * ow * khw) as u64;
    ops.int_ops += (geom.cin * in_h * in_w) as u64;
    ops.bytes += (e.len() + wlen + geom.cin * in_h * in_w) as u64;
    out
}

/// [`qdwconv2d_bwd_input_packed`] without a plan-owned pack: flips the
/// weights into the scratch arena first (the stale-cache bypass path —
/// correct, just slower). Bit-exact with the scalar kernel either way.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_bwd_input(
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    qdwconv2d_bwd_input_sel(
        KernelSel::Auto,
        e,
        w,
        geom,
        in_h,
        in_w,
        out_qp,
        keep,
        scratch,
        ops,
    )
}

/// [`qdwconv2d_bwd_input`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_bwd_input_sel(
    sel: KernelSel,
    e: &QTensor,
    w: &QTensor,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    out_qp: QParams,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> QTensor {
    let wt = scratch.dw_wt_u8(geom.cout * geom.kh * geom.kw);
    pack_dw_flip_u8(w.values.data(), geom, wt);
    qdwconv2d_bwd_input_packed_sel(sel, e, w, wt, geom, in_h, in_w, out_qp, keep, ops)
}

/// Blocked float depthwise error backprop against a pre-packed flipped
/// kernel, value-identical to
/// [`crate::kernels::fconv::fconv2d_bwd_input`] on depthwise geometry:
/// per input element the flipped gather visits contributions in the
/// scalar scatter's ascending `(oy, ox)` order, and skipped channels keep
/// their all-zero planes. `wt_pack.len() == w.len()` for depthwise convs,
/// so byte accounting matches the scalar kernel.
pub fn fdwconv2d_bwd_input_packed(
    e: &TensorF32,
    wt_pack: &[f32],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> TensorF32 {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let khw = geom.kh * geom.kw;
    assert_eq!(wt_pack.len(), geom.cout * khw, "packed weight size");
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }
    let ed = e.data();
    let s = geom.stride as isize;

    let mut out = TensorF32::zeros(&[geom.cin, in_h, in_w]);
    let od = out.data_mut();
    let isa = simd::resolve_isa(KernelSel::Auto, tune::prefer_axpy(in_w));
    let mut kept_channels = 0u64;
    for c in 0..geom.cout {
        if let Some(k) = keep {
            if !k[c] {
                continue; // plane stays zero, as in the scalar kernel
            }
        }
        kept_channels += 1;
        let eplane = &ed[c * oh * ow..(c + 1) * oh * ow];
        let wch = &wt_pack[c * khw..(c + 1) * khw];
        let oplane = &mut od[c * in_h * in_w..(c + 1) * in_h * in_w];
        for iy in 0..in_h {
            let mut ix0 = 0usize;
            while ix0 < in_w {
                let nrr = NR.min(in_w - ix0);
                let mut acc = [0f32; NR];
                for kyf in 0..geom.kh {
                    let ky = geom.kh - 1 - kyf;
                    let ty = iy as isize + geom.pad_h as isize - ky as isize;
                    if ty < 0 || ty % s != 0 || ty / s >= oh as isize {
                        continue;
                    }
                    let erow = &eplane[(ty / s) as usize * ow..((ty / s) as usize + 1) * ow];
                    for kxf in 0..geom.kw {
                        let kx = geom.kw - 1 - kxf;
                        let wv = wch[kyf * geom.kw + kxf];
                        if geom.stride == 1 {
                            let lo = kx.saturating_sub(geom.pad_w + ix0).min(nrr);
                            let hi = (ow + kx).saturating_sub(geom.pad_w + ix0).min(nrr).max(lo);
                            if hi > lo {
                                let src = ix0 + lo + geom.pad_w - kx;
                                let es = &erow[src..src + (hi - lo)];
                                simd::axpy_f32(isa, &mut acc[lo..hi], es, wv);
                            }
                        } else {
                            for (jj, a) in acc[..nrr].iter_mut().enumerate() {
                                let tx = (ix0 + jj) as isize + geom.pad_w as isize - kx as isize;
                                if tx < 0 || tx % s != 0 || tx / s >= ow as isize {
                                    continue;
                                }
                                *a += wv * erow[(tx / s) as usize];
                            }
                        }
                    }
                }
                let orow = &mut oplane[iy * in_w + ix0..iy * in_w + ix0 + nrr];
                orow.copy_from_slice(&acc[..nrr]);
                ix0 += nrr;
            }
        }
    }

    ops.float_macs += kept_channels * (oh * ow * khw) as u64;
    ops.bytes += ((e.len() + wt_pack.len() + geom.cin * in_h * in_w) * 4) as u64;
    out
}

/// [`fdwconv2d_bwd_input_packed`] without a plan-owned pack: flips the
/// weights into the scratch arena first (the stale-cache bypass path).
#[allow(clippy::too_many_arguments)]
pub fn fdwconv2d_bwd_input(
    e: &TensorF32,
    w: &TensorF32,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> TensorF32 {
    let wt = scratch.dw_wt_f32(geom.cout * geom.kh * geom.kw);
    pack_dw_flip_f32(w.data(), geom, wt);
    fdwconv2d_bwd_input_packed(e, wt, geom, in_h, in_w, keep, ops)
}

/// Blocked quantized depthwise weight gradient, **bit-exact** with
/// [`crate::kernels::qconv::qconv2d_bwd_weight`] on depthwise geometry:
/// each `∇W[c, ky, kx]` is one exact-i32 dot of the channel's error plane
/// with the matching strided input window (unit-stride on both sides at
/// stride 1); masked channels are skipped whole, their `∇W` rows and `∇b`
/// entries staying exactly zero. Op accounting matches the scalar kernel.
pub fn qdwconv2d_bwd_weight(
    e: &QTensor,
    x: &QTensor,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    qdwconv2d_bwd_weight_sel(KernelSel::Auto, e, x, geom, keep, ops)
}

/// [`qdwconv2d_bwd_weight`] with an explicit micro-kernel selection; the
/// plain name forwards [`KernelSel::Auto`].
pub fn qdwconv2d_bwd_weight_sel(
    sel: KernelSel,
    e: &QTensor,
    x: &QTensor,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let ze = e.qp.zero_point;
    let zx = x.qp.zero_point;
    let sc = e.qp.scale * x.qp.scale;
    let khw = geom.kh * geom.kw;
    let ed = e.values.data();
    let xd = x.values.data();
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }

    let mut gw = TensorF32::zeros(&[geom.cout, 1, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    let gwd = gw.data_mut();
    let gbd = gb.data_mut();
    // Each ∇W element is a length-`ow`-bounded dot reduction; i32 sums are
    // exact, so the lane kernel's reduction order cannot change the result.
    let isa = simd::resolve_isa(sel, tune::prefer_dot(ow));
    let mut kept_channels = 0u64;
    for c in 0..geom.cout {
        if let Some(k) = keep {
            if !k[c] {
                continue;
            }
        }
        kept_channels += 1;
        let eplane = &ed[c * oh * ow..(c + 1) * oh * ow];
        let xplane = &xd[c * h * wd..(c + 1) * h * wd];
        let mut bacc: i32 = 0;
        for &evq in eplane {
            bacc += evq as i32 - ze;
        }
        gbd[c] = bacc as f32 * e.qp.scale;
        for ky in 0..geom.kh {
            for kx in 0..geom.kw {
                let mut acc: i32 = 0;
                for oy in 0..oh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let xrow = &xplane[iy as usize * wd..(iy as usize + 1) * wd];
                    let erow = &eplane[oy * ow..(oy + 1) * ow];
                    if geom.stride == 1 {
                        let lo = geom.pad_w.saturating_sub(kx).min(ow);
                        let hi = (wd + geom.pad_w).saturating_sub(kx).min(ow).max(lo);
                        if hi > lo {
                            let src = lo + kx - geom.pad_w;
                            let xs = &xrow[src..src + (hi - lo)];
                            acc = acc.wrapping_add(simd::dot_u8(isa, &erow[lo..hi], ze, xs, zx));
                        }
                    } else {
                        for (ox, &evq) in erow.iter().enumerate() {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            acc += (evq as i32 - ze) * (xrow[ix as usize] as i32 - zx);
                        }
                    }
                }
                gwd[c * khw + ky * geom.kw + kx] = acc as f32 * sc;
            }
        }
    }

    ops.int_macs += kept_channels * (oh * ow * khw) as u64;
    ops.float_ops += gw.len() as u64;
    ops.bytes += (e.len() + x.len() + gw.len() * 4) as u64;
    (gw, gb)
}

/// Blocked float depthwise weight gradient, value-identical to
/// [`crate::kernels::fconv::fconv2d_bwd_weight`] on depthwise geometry:
/// per `∇W` element the in-bounds products are added in the scalar
/// kernel's ascending `(oy, ox)` order, and the bias gradient accumulates
/// the error plane in the same row-major order.
pub fn fdwconv2d_bwd_weight(
    e: &TensorF32,
    x: &TensorF32,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    assert!(geom.depthwise, "depthwise engine requires depthwise geometry");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let khw = geom.kh * geom.kw;
    let ed = e.data();
    let xd = x.data();
    if let Some(k) = keep {
        assert_eq!(k.len(), geom.cout, "keep mask length");
    }

    let mut gw = TensorF32::zeros(&[geom.cout, 1, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    let gwd = gw.data_mut();
    let gbd = gb.data_mut();
    let mut kept_channels = 0u64;
    for c in 0..geom.cout {
        if let Some(k) = keep {
            if !k[c] {
                continue;
            }
        }
        kept_channels += 1;
        let eplane = &ed[c * oh * ow..(c + 1) * oh * ow];
        let xplane = &xd[c * h * wd..(c + 1) * h * wd];
        let mut bacc = 0f32;
        for &ev in eplane {
            bacc += ev;
        }
        gbd[c] = bacc;
        for ky in 0..geom.kh {
            for kx in 0..geom.kw {
                let mut acc = 0f32;
                for oy in 0..oh {
                    let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let xrow = &xplane[iy as usize * wd..(iy as usize + 1) * wd];
                    let erow = &eplane[oy * ow..(oy + 1) * ow];
                    if geom.stride == 1 {
                        let lo = geom.pad_w.saturating_sub(kx).min(ow);
                        let hi = (wd + geom.pad_w).saturating_sub(kx).min(ow).max(lo);
                        if hi > lo {
                            let src = lo + kx - geom.pad_w;
                            let xs = &xrow[src..src + (hi - lo)];
                            for (&ev, &xv) in erow[lo..hi].iter().zip(xs.iter()) {
                                acc += ev * xv;
                            }
                        }
                    } else {
                        for (ox, &ev) in erow.iter().enumerate() {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            acc += ev * xrow[ix as usize];
                        }
                    }
                }
                gwd[c * khw + ky * geom.kw + kx] = acc;
            }
        }
    }

    ops.float_macs += kept_channels * (oh * ow * khw) as u64;
    ops.bytes += ((e.len() + x.len() + gw.len()) * 4) as u64;
    (gw, gb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::qconv;
    use crate::kernels::{fconv, OpCounter};
    use crate::util::prng::Pcg32;
    use crate::util::proptest::{shrink_dim, Prop};

    fn dw_geom(c: usize, k: usize, stride: usize, pad: usize) -> ConvGeom {
        ConvGeom {
            cin: c,
            cout: c,
            kh: k,
            kw: k,
            stride,
            pad_h: pad,
            pad_w: pad,
            depthwise: true,
        }
    }

    fn rand_dw_setup(
        rng: &mut Pcg32,
        g: &ConvGeom,
        h: usize,
        w: usize,
    ) -> (TensorF32, TensorF32, Vec<f32>) {
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, 1, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);
        let b: Vec<f32> = (0..g.cout).map(|_| rng.normal() * 0.1).collect();
        (x, wt, b)
    }

    fn rand_mask(rng: &mut Pcg32, n: usize, kind: u64) -> Option<Vec<bool>> {
        match kind % 3 {
            0 => None,
            1 => Some((0..n).map(|_| rng.below(2) == 1).collect()),
            _ => Some(vec![false; n]),
        }
    }

    #[test]
    fn pack_dw_flip_rotates_each_channel() {
        // C=2, 2x2 kernels with recognizable values c*100 + ky*10 + kx.
        let g = dw_geom(2, 2, 1, 1);
        let w: Vec<u8> = vec![0, 1, 10, 11, 100, 101, 110, 111];
        let mut dst = vec![0u8; 8];
        pack_dw_flip_u8(&w, &g, &mut dst);
        assert_eq!(dst, vec![11, 10, 1, 0, 111, 110, 101, 100]);
    }

    /// The packed-weight flip must match unpack-then-flip at every width,
    /// on a 3×3 kernel whose 9-lane channel planes are *not* byte-aligned
    /// at 2 or 4 lanes per byte.
    #[test]
    fn pack_dw_flip_pa_matches_unpacked_oracle() {
        let mut rng = Pcg32::seeded(96);
        let g = dw_geom(5, 3, 1, 1);
        let khw = 9;
        for bits in [WBits::W8, WBits::W4, WBits::W2] {
            let span = bits.qmax() as u32 + 1;
            let lanes: Vec<u8> = (0..5 * khw).map(|_| rng.below(span) as u8).collect();
            let packed = subbyte::pack_lanes(&lanes, bits);
            let mut want = vec![0u8; 5 * khw];
            let mut got = vec![0u8; 5 * khw];
            pack_dw_flip_u8(&lanes, &g, &mut want);
            pack_dw_flip_u8_pa(&packed, bits, &g, &mut got);
            assert_eq!(got, want, "{bits:?}");
        }
    }

    /// The three packed-weight depthwise paths (forward, cached backward,
    /// stale-bypass backward) must be bit-exact with the u8 engine running
    /// on the unpacked twin, with identical op accounting — at every bit
    /// width and under sparse masks.
    #[test]
    fn packed_dw_paths_bit_exact_with_u8_twin() {
        let mut rng = Pcg32::seeded(97);
        let g = dw_geom(4, 3, 1, 1);
        let (h, w) = (9, 9);
        let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, w);
        let xq = QTensor::quantize(&x);
        let oqp = QParams::from_min_max(-2.0, 2.0);
        let (oh, ow) = g.out_hw(h, w);
        let mut e = TensorF32::zeros(&[4, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);
        let eq = QTensor::quantize(&e);
        let mask = [true, false, true, true];
        for bits in [WBits::W8, WBits::W4, WBits::W2] {
            let p = PackedQTensor::quantize_bits(&wt, bits);
            let q = p.to_qtensor();
            let bq = crate::quant::quantize_bias(&b, xq.qp.scale, q.qp.scale);

            let mut ops_u = OpCounter::new();
            let mut ops_p = OpCounter::new();
            let mut scratch = Scratch::new();
            let (yu, sat_u) = qdwconv2d_fwd_fused(&xq, &q, &bq, &g, oqp, true, &mut ops_u);
            let (yp, sat_p) = qdwconv2d_fwd_fused_pa_sel(
                KernelSel::Auto,
                &xq,
                &p,
                &bq,
                &g,
                oqp,
                true,
                &mut scratch,
                &mut ops_p,
            );
            assert_eq!(yu.values.data(), yp.values.data(), "fwd {bits:?}");
            assert_eq!(sat_u, sat_p, "fwd sat {bits:?}");
            assert_eq!(ops_u, ops_p, "fwd ops {bits:?}");

            for keep in [None, Some(&mask[..])] {
                let mut ops_su = OpCounter::new();
                let mut ops_sp = OpCounter::new();
                let mut sc_u = Scratch::new();
                let mut sc_p = Scratch::new();
                let eu =
                    qdwconv2d_bwd_input(&eq, &q, &g, h, w, oqp, keep, &mut sc_u, &mut ops_su);
                let ep = qdwconv2d_bwd_input_pa_sel(
                    KernelSel::Auto,
                    &eq,
                    &p,
                    &g,
                    h,
                    w,
                    oqp,
                    keep,
                    &mut sc_p,
                    &mut ops_sp,
                );
                assert_eq!(eu.values.data(), ep.values.data(), "bypass dx {bits:?}");
                assert_eq!(ops_su, ops_sp, "bypass dx ops {bits:?}");

                // cached route: the u8 cache holds flipped lanes, the packed
                // cache the same lanes re-packed at `bits`
                let mut flipped = vec![0u8; q.len()];
                pack_dw_flip_u8(q.values.data(), &g, &mut flipped);
                let packed_flip = subbyte::pack_lanes(&flipped, bits);
                let mut ops_cu = OpCounter::new();
                let mut ops_cp = OpCounter::new();
                let ecu = qdwconv2d_bwd_input_packed(
                    &eq, &q, &flipped, &g, h, w, oqp, keep, &mut ops_cu,
                );
                let ecp = qdwconv2d_bwd_input_packed_pa_sel(
                    KernelSel::Auto,
                    &eq,
                    &p,
                    &packed_flip,
                    bits,
                    &g,
                    h,
                    w,
                    oqp,
                    keep,
                    &mut sc_p,
                    &mut ops_cp,
                );
                assert_eq!(ecu.values.data(), ecp.values.data(), "cached dx {bits:?}");
                assert_eq!(ops_cu, ops_cp, "cached dx ops {bits:?}");
            }
        }
    }

    /// Property: the blocked quantized forward is bit-exact with the
    /// scalar depthwise reference across random channel counts, kernel
    /// sizes, strides, paddings and relu on/off, with identical op
    /// accounting.
    #[test]
    fn prop_blocked_fwd_bit_exact_with_scalar() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let c = 1 + r.below(6) as usize;
                let k = 1 + 2 * r.below(2) as usize; // 1 or 3
                let stride = 1 + r.below(2) as usize;
                let pad = r.below(3) as usize;
                let h = k.max(2) + r.below(22) as usize; // crosses the NR tile
                (c, k, stride, pad, h, r.next_u64())
            },
            |&(c, k, stride, pad, h, s)| {
                shrink_dim(h, k).into_iter().map(|h2| (c, k, stride, pad, h2, s)).collect()
            },
            |&(c, k, stride, pad, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = dw_geom(c, k, stride, pad);
                let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, h);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
                let oqp = QParams::from_min_max(-2.0, 2.0);
                let relu = seed % 2 == 0;
                let mut ops_s = OpCounter::new();
                let mut ops_b = OpCounter::new();
                let ys = qconv::qconv2d_fwd(&xq, &wq, &bq, &g, oqp, relu, &mut ops_s);
                let yb = qdwconv2d_fwd(&xq, &wq, &bq, &g, oqp, relu, &mut ops_b);
                if ys.values.data() != yb.values.data() {
                    return Err("blocked depthwise forward differs from scalar".into());
                }
                if ops_s != ops_b {
                    return Err("fwd op accounting differs".into());
                }
                Ok(())
            },
        );
    }

    /// Property: both blocked backward kernels (packed route and the
    /// scratch-packing bypass) are bit-exact with the scalar depthwise
    /// references across random geometries and masks, with identical op
    /// accounting.
    #[test]
    fn prop_blocked_bwd_bit_exact_with_scalar() {
        Prop::new(48).check(
            |r: &mut Pcg32| {
                let c = 1 + r.below(6) as usize;
                let k = 1 + 2 * r.below(2) as usize;
                let stride = 1 + r.below(2) as usize;
                let pad = r.below(2) as usize;
                let h = k.max(2) + r.below(22) as usize;
                (c, k, stride, pad, h, r.next_u64())
            },
            |&(c, k, stride, pad, h, s)| {
                shrink_dim(h, k).into_iter().map(|h2| (c, k, stride, pad, h2, s)).collect()
            },
            |&(c, k, stride, pad, h, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let g = dw_geom(c, k, stride, pad);
                let (oh, ow) = g.out_hw(h, h);
                let mut e = TensorF32::zeros(&[c, oh, ow]);
                rng.fill_normal(e.data_mut(), 1.0);
                let (x, wt, _) = rand_dw_setup(&mut rng, &g, h, h);
                let eq = QTensor::quantize(&e);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                let keep = rand_mask(&mut rng, c, seed);
                let keep = keep.as_deref();

                let mut ops_s = OpCounter::new();
                let mut ops_b = OpCounter::new();
                let (gws, gbs) = qconv::qconv2d_bwd_weight(&eq, &xq, &g, keep, &mut ops_s);
                let (gwb, gbb) = qdwconv2d_bwd_weight(&eq, &xq, &g, keep, &mut ops_b);
                if gws.data() != gwb.data() || gbs.data() != gbb.data() {
                    return Err("blocked depthwise weight gradient differs from scalar".into());
                }
                if ops_s != ops_b {
                    return Err("bwd_weight op accounting differs".into());
                }

                let oqp = QParams::from_min_max(-2.0, 2.0);
                let mut ops_s2 = OpCounter::new();
                let mut ops_p = OpCounter::new();
                let mut ops_u = OpCounter::new();
                let es = qconv::qconv2d_bwd_input(&eq, &wq, &g, h, h, oqp, keep, &mut ops_s2);
                let mut pack = vec![0u8; c * k * k];
                pack_dw_flip_u8(wq.values.data(), &g, &mut pack);
                let ep = qdwconv2d_bwd_input_packed(
                    &eq,
                    &wq,
                    &pack,
                    &g,
                    h,
                    h,
                    oqp,
                    keep,
                    &mut ops_p,
                );
                let mut scratch = Scratch::new();
                let eu = qdwconv2d_bwd_input(
                    &eq,
                    &wq,
                    &g,
                    h,
                    h,
                    oqp,
                    keep,
                    &mut scratch,
                    &mut ops_u,
                );
                if es.values.data() != ep.values.data() {
                    return Err("packed depthwise input gradient differs from scalar".into());
                }
                if es.values.data() != eu.values.data() {
                    return Err("bypass depthwise input gradient differs from scalar".into());
                }
                if ops_s2 != ops_p || ops_s2 != ops_u {
                    return Err("bwd_input op accounting differs".into());
                }
                Ok(())
            },
        );
    }

    /// Deterministic sweep over widths around the NR tile boundary (±1,
    /// 1, 2·NR+3): the quantized engine must stay bit-exact with the
    /// scalar reference on full tiles, edge tiles and single-column maps.
    #[test]
    fn blocked_edge_tiles_bit_exact() {
        let mut rng = Pcg32::seeded(91);
        let oqp = QParams::from_min_max(-2.0, 2.0);
        for &w in &[1usize, NR - 1, NR, NR + 1, 2 * NR + 3] {
            let h = 5usize;
            for &(k, stride, pad) in &[(3usize, 1usize, 1usize), (3, 2, 1), (1, 1, 0)] {
                if k > h + 2 * pad || k > w + 2 * pad {
                    continue;
                }
                let g = ConvGeom {
                    cin: 3,
                    cout: 3,
                    kh: k,
                    kw: k,
                    stride,
                    pad_h: pad,
                    pad_w: pad,
                    depthwise: true,
                };
                let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, w);
                let xq = QTensor::quantize(&x);
                let wq = QTensor::quantize(&wt);
                let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
                let mut ops = OpCounter::new();
                let ys = qconv::qconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
                let yb = qdwconv2d_fwd(&xq, &wq, &bq, &g, oqp, true, &mut ops);
                assert_eq!(ys.values.data(), yb.values.data(), "fwd w={w} k{k} s{stride}");

                let (oh, ow) = g.out_hw(h, w);
                let mut e = TensorF32::zeros(&[3, oh, ow]);
                rng.fill_normal(e.data_mut(), 1.0);
                let eq = QTensor::quantize(&e);
                let es = qconv::qconv2d_bwd_input(&eq, &wq, &g, h, w, oqp, None, &mut ops);
                let mut scratch = Scratch::new();
                let eb = qdwconv2d_bwd_input(
                    &eq,
                    &wq,
                    &g,
                    h,
                    w,
                    oqp,
                    None,
                    &mut scratch,
                    &mut ops,
                );
                assert_eq!(es.values.data(), eb.values.data(), "dx w={w} k{k} s{stride}");
            }
        }
    }

    /// The float engine must equal the scalar float kernels exactly (same
    /// per-element accumulation order — see the module docs), across
    /// geometries, relu masking zeros in the error, and sparse masks.
    #[test]
    fn float_engine_equals_scalar_reference() {
        let mut rng = Pcg32::seeded(92);
        for &(c, k, stride, pad, h) in &[
            (3usize, 3usize, 1usize, 1usize, 7usize),
            (4, 3, 2, 1, 9),
            (2, 3, 1, 0, 19), // crosses the NR tile at stride 1
            (5, 1, 1, 0, 6),
            (3, 3, 2, 0, 8),
        ] {
            let g = dw_geom(c, k, stride, pad);
            let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, h);
            let mut ops = OpCounter::new();
            let ys = fconv::fconv2d_fwd(&x, &wt, &b, &g, true, &mut ops);
            let yb = fdwconv2d_fwd(&x, &wt, &b, &g, true, &mut ops);
            assert_eq!(ys.data(), yb.data(), "fwd {c}ch k{k} s{stride}");

            let (oh, ow) = g.out_hw(h, h);
            let mut e = TensorF32::zeros(&[c, oh, ow]);
            rng.fill_normal(e.data_mut(), 1.0);
            // ReLU-masked errors carry exact zeros — the case the scalar
            // kernels' `ev == 0.0` skip special-cases.
            fconv::relu_bwd_mask_f(&mut e, &ys, &mut ops);
            let mask: Vec<bool> = (0..c).map(|i| i % 2 == 0).collect();
            for keep in [None, Some(&mask[..])] {
                let mut ops_s = OpCounter::new();
                let mut ops_b = OpCounter::new();
                let (gws, gbs) = fconv::fconv2d_bwd_weight(&e, &x, &g, keep, &mut ops_s);
                let (gwb, gbb) = fdwconv2d_bwd_weight(&e, &x, &g, keep, &mut ops_b);
                assert_eq!(gws.data(), gwb.data(), "gw {c}ch k{k} s{stride}");
                assert_eq!(gbs.data(), gbb.data(), "gb {c}ch k{k} s{stride}");
                assert_eq!(ops_s, ops_b, "bwd_weight ops {c}ch k{k} s{stride}");

                let mut ops_s2 = OpCounter::new();
                let mut ops_b2 = OpCounter::new();
                let es = fconv::fconv2d_bwd_input(&e, &wt, &g, h, h, keep, &mut ops_s2);
                let mut scratch = Scratch::new();
                let eb = fdwconv2d_bwd_input(&e, &wt, &g, h, h, keep, &mut scratch, &mut ops_b2);
                assert_eq!(es.data(), eb.data(), "dx {c}ch k{k} s{stride}");
                assert_eq!(ops_s2, ops_b2, "bwd_input ops {c}ch k{k} s{stride}");
            }
        }
    }

    /// Masked channels must cost proportionally fewer counted MACs and
    /// leave exactly-zero gradient planes (the depthwise sparse contract:
    /// masked out-channel == masked in-channel).
    #[test]
    fn mask_skips_whole_channels_proportionally() {
        let mut rng = Pcg32::seeded(93);
        let g = dw_geom(8, 3, 1, 1);
        let (h, w) = (10, 10);
        let (x, wt, _) = rand_dw_setup(&mut rng, &g, h, w);
        let (oh, ow) = g.out_hw(h, w);
        let mut e = TensorF32::zeros(&[8, oh, ow]);
        rng.fill_normal(e.data_mut(), 1.0);
        let eq = QTensor::quantize(&e);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let keep = vec![true, false, true, false, true, false, true, false];

        let mut ops_m = OpCounter::new();
        let mut ops_d = OpCounter::new();
        let (gw, gb) = qdwconv2d_bwd_weight(&eq, &xq, &g, Some(&keep), &mut ops_m);
        let _ = qdwconv2d_bwd_weight(&eq, &xq, &g, None, &mut ops_d);
        assert_eq!(ops_m.int_macs * 2, ops_d.int_macs, "kept=50% must halve dW MACs");
        for c in 0..8 {
            let z = gw.outer(c).iter().all(|&v| v == 0.0);
            assert_eq!(z, !keep[c], "channel {c}");
            if !keep[c] {
                assert_eq!(gb.data()[c], 0.0);
            }
        }

        let oqp = QParams::from_min_max(-1.0, 1.0);
        let mut ops_m2 = OpCounter::new();
        let mut ops_d2 = OpCounter::new();
        let mut scratch = Scratch::new();
        let km = Some(&keep[..]);
        let _ = qdwconv2d_bwd_input(&eq, &wq, &g, h, w, oqp, km, &mut scratch, &mut ops_m2);
        let _ = qdwconv2d_bwd_input(&eq, &wq, &g, h, w, oqp, None, &mut scratch, &mut ops_d2);
        assert_eq!(ops_m2.int_macs * 2, ops_d2.int_macs, "kept=50% must halve dX MACs");
    }

    /// The fused entry returns the same tensor as the plain forward plus a
    /// saturation count matching a post-hoc sweep, for relu on and off.
    #[test]
    fn fused_fwd_saturation_count_matches_sweep() {
        let mut rng = Pcg32::seeded(95);
        let g = dw_geom(4, 3, 1, 1);
        let (h, w) = (9, 9);
        let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, w);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        // Narrow range so saturation actually happens.
        let oqp = QParams::from_min_max(-0.05, 0.05);
        for relu in [false, true] {
            let mut ops_u = OpCounter::new();
            let mut ops_f = OpCounter::new();
            let yu = qdwconv2d_fwd(&xq, &wq, &bq, &g, oqp, relu, &mut ops_u);
            let (yf, sat) = qdwconv2d_fwd_fused(&xq, &wq, &bq, &g, oqp, relu, &mut ops_f);
            assert_eq!(yu.values.data(), yf.values.data());
            assert_eq!(ops_u, ops_f);
            let want = yu
                .values
                .data()
                .iter()
                .filter(|&&v| v == 255 || (!relu && v == 0))
                .count() as u64;
            assert_eq!(sat, want, "relu={relu}");
            assert!(sat > 0, "narrow range should saturate (relu={relu})");
        }
    }

    /// Non-square depthwise kernels (the 1×k time-series mapping) run the
    /// same engine; spot-check bit-exactness against the scalar kernel.
    #[test]
    fn time_series_1xk_geometry_bit_exact() {
        let mut rng = Pcg32::seeded(94);
        let g = ConvGeom {
            cin: 4,
            cout: 4,
            kh: 1,
            kw: 3,
            stride: 1,
            pad_h: 0,
            pad_w: 1,
            depthwise: true,
        };
        let (h, w) = (1, 40);
        let (x, wt, b) = rand_dw_setup(&mut rng, &g, h, w);
        let xq = QTensor::quantize(&x);
        let wq = QTensor::quantize(&wt);
        let bq = crate::quant::quantize_bias(&b, xq.qp.scale, wq.qp.scale);
        let oqp = QParams::from_min_max(-2.0, 2.0);
        let mut ops = OpCounter::new();
        let ys = qconv::qconv2d_fwd(&xq, &wq, &bq, &g, oqp, false, &mut ops);
        let yb = qdwconv2d_fwd(&xq, &wq, &bq, &g, oqp, false, &mut ops);
        assert_eq!(ys.values.data(), yb.values.data());
    }
}
