//! Float32 twin of the quantized conv kernels, used by the `float32` DNN
//! configuration and by the float part of the `mixed` configuration
//! (classification head in float, §IV). Identical geometry and masking
//! semantics as `qconv`; arithmetic is f32 and counted as `float_macs` so
//! the device model prices it with the per-MCU float CPI (soft-float on the
//! Cortex-M0+, FPU on M4/M7).

use crate::kernels::{gemm, kept_count, ConvGeom, OpCounter};
use crate::memplan::Scratch;
use crate::tensor::{idx3, idx4, TensorF32};

/// Forward: `y = relu?(conv(x, w) + b)` in f32.
pub fn fconv2d_fwd(
    x: &TensorF32,
    w: &TensorF32,
    bias: &[f32],
    geom: &ConvGeom,
    relu: bool,
    ops: &mut OpCounter,
) -> TensorF32 {
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    let cf = if geom.depthwise { 1 } else { geom.cin };
    let mut out = TensorF32::zeros(&[geom.cout, oh, ow]);
    let od = out.data_mut();
    for co in 0..geom.cout {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = bias[co];
                for c in 0..cf {
                    let ci = if geom.depthwise { co } else { c };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            acc += x.data()[idx3(ci, iy as usize, ix as usize, h, wd)]
                                * w.data()[idx4(co, c, ky, kx, cf, geom.kh, geom.kw)];
                        }
                    }
                }
                od[idx3(co, oy, ox, oh, ow)] = if relu { acc.max(0.0) } else { acc };
            }
        }
    }
    ops.float_macs += geom.fwd_macs(h, wd);
    ops.bytes += ((x.len() + w.len() + geom.cout * oh * ow) * 4) as u64;
    out
}

/// GEMM-routed float forward (the `float32`/`mixed` twin of
/// [`crate::kernels::qconv::qconv2d_fwd_gemm`]). Value-identical to
/// [`fconv2d_fwd`]: per output element the GEMM accumulates products in
/// the same ascending `(ci, ky, kx)` order as the scalar loops, and padded
/// im2col entries contribute an exact `w·0.0`. Non-depthwise only.
pub fn fconv2d_fwd_gemm(
    x: &TensorF32,
    w: &TensorF32,
    bias: &[f32],
    geom: &ConvGeom,
    relu: bool,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> TensorF32 {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = geom.out_hw(h, wd);
    assert_eq!(x.shape()[0], geom.cin, "input channels mismatch");
    assert_eq!(bias.len(), geom.cout, "bias length mismatch");

    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    let pointwise = geom.is_pointwise();

    let mut out = TensorF32::zeros(&[geom.cout, oh, ow]);
    {
        let col_buf = scratch.fconv_col(if pointwise { 0 } else { kdim * n });
        if pointwise {
            gemm::gemm_f32(w.data(), x.data(), bias, geom.cout, kdim, n, out.data_mut());
        } else {
            gemm::im2col_f32(x.data(), h, wd, geom, oh, ow, col_buf);
            gemm::gemm_f32(w.data(), col_buf, bias, geom.cout, kdim, n, out.data_mut());
        }
    }
    if relu {
        for v in out.data_mut().iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    ops.float_macs += geom.fwd_macs(h, wd);
    ops.bytes += ((x.len() + w.len() + geom.cout * n) * 4) as u64;
    out
}

/// Error backprop (float): transposed conv, with optional channel mask.
pub fn fconv2d_bwd_input(
    e: &TensorF32,
    w: &TensorF32,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> TensorF32 {
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let cf = if geom.depthwise { 1 } else { geom.cin };
    let mut out = TensorF32::zeros(&[geom.cin, in_h, in_w]);
    let od = out.data_mut();
    let mut kept = 0u64;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept += 1;
        for oy in 0..oh {
            for ox in 0..ow {
                let ev = e.data()[idx3(co, oy, ox, oh, ow)];
                if ev == 0.0 {
                    continue;
                }
                for c in 0..cf {
                    let ci = if geom.depthwise { co } else { c };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            od[idx3(ci, iy as usize, ix as usize, in_h, in_w)] +=
                                ev * w.data()[idx4(co, c, ky, kx, cf, geom.kh, geom.kw)];
                        }
                    }
                }
            }
        }
    }
    ops.float_macs += kept * (oh * ow * cf * geom.kh * geom.kw) as u64;
    ops.bytes += ((e.len() + w.len() + geom.cin * in_h * in_w) * 4) as u64;
    out
}

/// GEMM-routed float error backprop, value-identical to
/// [`fconv2d_bwd_input`]: `dX[Cin, H·W] = wt_flip × colE`. The flipped
/// packing makes the GEMM's ascending-k accumulation visit contributions in
/// the scalar kernel's `(co, oy, ox)` order, and stride-gap/edge positions
/// hold 0.0 (an exact `w·0.0` addition), so per-element sums are identical.
///
/// `keep` drops masked output channels from both packings — whole GEMM rows
/// are skipped, shrinking the reduction depth proportionally. Non-depthwise
/// only; op accounting matches the scalar kernel.
#[allow(clippy::too_many_arguments)]
pub fn fconv2d_bwd_input_gemm(
    e: &TensorF32,
    w: &TensorF32,
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> TensorF32 {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let kc = kept_count(keep, geom.cout);
    let krow = kc * geom.kh * geom.kw;
    let n = in_h * in_w;
    let mut out = TensorF32::zeros(&[geom.cin, in_h, in_w]);
    {
        // Reserve the flipped-weight buffer at its dense bound so sparse
        // runs grow the arena once, not per new high-water kept count
        // (see the quantized twin).
        let dense_wt = geom.cin * geom.cout * geom.kh * geom.kw;
        let (wt_full, col_buf, init) = scratch.fconv_bwd_bufs(dense_wt, krow * n, geom.cin);
        let wt_buf = &mut wt_full[..geom.cin * krow];
        gemm::pack_wt_flip_f32(w.data(), geom, keep, wt_buf);
        gemm::im2col_bwd_f32(e.data(), oh, ow, geom, in_h, in_w, keep, col_buf);
        gemm::gemm_f32(wt_buf, col_buf, init, geom.cin, krow, n, out.data_mut());
    }
    ops.float_macs += kc as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.bytes += ((e.len() + w.len() + geom.cin * n) * 4) as u64;
    out
}

/// Dense float error backprop against a **pre-packed** flipped-transposed
/// weight matrix `wt_pack[Cin, Cout·Kh·Kw]` (the plan-owned pack cache):
/// value-identical to [`fconv2d_bwd_input_gemm`] at `keep == None` — same
/// backward column matrix, same GEMM, and the cached pack is exactly what
/// `pack_wt_flip_f32` would produce for the current weights (guaranteed by
/// the cache's version check). Op accounting matches the unpacked dense
/// call (`wt_pack.len() == w.len()` for dense convs).
pub fn fconv2d_bwd_input_gemm_packed(
    e: &TensorF32,
    wt_pack: &[f32],
    geom: &ConvGeom,
    in_h: usize,
    in_w: usize,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> TensorF32 {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let krow = geom.cout * geom.kh * geom.kw;
    assert_eq!(wt_pack.len(), geom.cin * krow, "packed weight size");
    let n = in_h * in_w;
    let mut out = TensorF32::zeros(&[geom.cin, in_h, in_w]);
    {
        let (_, col_buf, init) = scratch.fconv_bwd_bufs(0, krow * n, geom.cin);
        gemm::im2col_bwd_f32(e.data(), oh, ow, geom, in_h, in_w, None, col_buf);
        gemm::gemm_f32(wt_pack, col_buf, init, geom.cin, krow, n, out.data_mut());
    }
    ops.float_macs += geom.cout as u64 * (oh * ow * geom.cin * geom.kh * geom.kw) as u64;
    ops.bytes += ((e.len() + wt_pack.len() + geom.cin * n) * 4) as u64;
    out
}

/// Weight + bias gradient (float), optional channel mask.
pub fn fconv2d_bwd_weight(
    e: &TensorF32,
    x: &TensorF32,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let cf = if geom.depthwise { 1 } else { geom.cin };
    let mut gw = TensorF32::zeros(&[geom.cout, cf, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    let mut kept = 0u64;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept += 1;
        let mut bacc = 0f32;
        for oy in 0..oh {
            for ox in 0..ow {
                let ev = e.data()[idx3(co, oy, ox, oh, ow)];
                bacc += ev;
                if ev == 0.0 {
                    continue;
                }
                for c in 0..cf {
                    let ci = if geom.depthwise { co } else { c };
                    for ky in 0..geom.kh {
                        let iy = (oy * geom.stride + ky) as isize - geom.pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..geom.kw {
                            let ix = (ox * geom.stride + kx) as isize - geom.pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            gw.data_mut()[idx4(co, c, ky, kx, cf, geom.kh, geom.kw)] += ev
                                * x.data()[idx3(ci, iy as usize, ix as usize, h, wd)];
                        }
                    }
                }
            }
        }
        gb.data_mut()[co] = bacc;
    }
    ops.float_macs += kept * (oh * ow * cf * geom.kh * geom.kw) as u64;
    ops.bytes += ((e.len() + x.len() + gw.len()) * 4) as u64;
    (gw, gb)
}

/// GEMM-routed float weight gradient, value-identical to
/// [`fconv2d_bwd_weight`]: each `∇W` element is one contiguous dot product
/// of an error row with a forward-im2col row ([`gemm::gemm_abt_f32`]),
/// accumulated in the scalar kernel's ascending `(oy, ox)` order (padded
/// positions hold 0.0 and add an exact `e·0.0`). `keep` skips masked output
/// channels as whole GEMM rows. Non-depthwise only.
pub fn fconv2d_bwd_weight_gemm(
    e: &TensorF32,
    x: &TensorF32,
    geom: &ConvGeom,
    keep: Option<&[bool]>,
    scratch: &mut Scratch,
    ops: &mut OpCounter,
) -> (TensorF32, TensorF32) {
    assert!(!geom.depthwise, "GEMM path does not cover depthwise convolutions");
    let (h, wd) = (x.shape()[1], x.shape()[2]);
    let (oh, ow) = (e.shape()[1], e.shape()[2]);
    let n = oh * ow;
    let kdim = geom.cin * geom.kh * geom.kw;
    let pointwise = geom.is_pointwise();

    let mut gw = TensorF32::zeros(&[geom.cout, geom.cin, geom.kh, geom.kw]);
    let mut gb = TensorF32::zeros(&[geom.cout]);
    {
        let col_buf = scratch.fconv_col(if pointwise { 0 } else { kdim * n });
        let col: &[f32] = if pointwise {
            x.data()
        } else {
            gemm::im2col_f32(x.data(), h, wd, geom, oh, ow, col_buf);
            col_buf
        };
        gemm::gemm_abt_f32(e.data(), col, geom.cout, kdim, n, keep, gw.data_mut());
    }

    let ed = e.data();
    let gbd = gb.data_mut();
    let mut kept = 0u64;
    for co in 0..geom.cout {
        if let Some(k) = keep {
            if !k[co] {
                continue;
            }
        }
        kept += 1;
        let mut bacc = 0f32;
        for &ev in &ed[co * n..(co + 1) * n] {
            bacc += ev;
        }
        gbd[co] = bacc;
    }

    ops.float_macs += kept * (n * geom.cin * geom.kh * geom.kw) as u64;
    ops.bytes += ((e.len() + x.len() + gw.len()) * 4) as u64;
    (gw, gb)
}

/// ReLU backward in float: zero the error where the forward output was 0.
pub fn relu_bwd_mask_f(e: &mut TensorF32, y_fwd: &TensorF32, ops: &mut OpCounter) {
    assert_eq!(e.shape(), y_fwd.shape());
    for (ev, &yv) in e.data_mut().iter_mut().zip(y_fwd.data().iter()) {
        if yv <= 0.0 {
            *ev = 0.0;
        }
    }
    ops.float_ops += e.len() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    /// Finite-difference check: the analytic weight gradient of a scalar
    /// loss `L = Σ y` must match numeric differentiation.
    #[test]
    fn weight_grad_matches_finite_difference() {
        let mut rng = Pcg32::seeded(31);
        let g = ConvGeom {
            cin: 2,
            cout: 2,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let (h, w) = (5, 5);
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, g.cin, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);
        let b = vec![0.0; g.cout];
        let mut ops = OpCounter::new();

        // L = sum(y), no relu -> dL/dy = 1 everywhere
        let (oh, ow) = g.out_hw(h, w);
        let e = TensorF32::full(&[g.cout, oh, ow], 1.0);
        let (gw, gb) = fconv2d_bwd_weight(&e, &x, &g, None, &mut ops);

        let loss = |wt: &TensorF32| -> f32 {
            let mut o = OpCounter::new();
            fconv2d_fwd(&x, wt, &b, &g, false, &mut o).data().iter().sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 7, 17, 35] {
            let mut wp = wt.clone();
            wp.data_mut()[idx] += eps;
            let mut wm = wt.clone();
            wm.data_mut()[idx] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps);
            assert!((num - gw.data()[idx]).abs() < 1e-2, "{num} vs {}", gw.data()[idx]);
        }
        assert!((gb.data()[0] - (oh * ow) as f32).abs() < 1e-4);
    }

    /// Input gradient via finite differences.
    #[test]
    fn input_grad_matches_finite_difference() {
        let mut rng = Pcg32::seeded(32);
        let g = ConvGeom {
            cin: 2,
            cout: 3,
            kh: 3,
            kw: 3,
            stride: 2,
            pad_h: 1,
            pad_w: 1,
            depthwise: false,
        };
        let (h, w) = (6, 6);
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, g.cin, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);
        let b = vec![0.0; g.cout];
        let (oh, ow) = g.out_hw(h, w);
        let e = TensorF32::full(&[g.cout, oh, ow], 1.0);
        let mut ops = OpCounter::new();
        let gx = fconv2d_bwd_input(&e, &wt, &g, h, w, None, &mut ops);

        let loss = |x: &TensorF32| -> f32 {
            let mut o = OpCounter::new();
            fconv2d_fwd(x, &wt, &b, &g, false, &mut o).data().iter().sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 11, 30, 71] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!((num - gx.data()[idx]).abs() < 1e-2, "{num} vs {}", gx.data()[idx]);
        }
    }

    #[test]
    fn depthwise_grads_match_fd() {
        let mut rng = Pcg32::seeded(33);
        let g = ConvGeom {
            cin: 3,
            cout: 3,
            kh: 3,
            kw: 3,
            stride: 1,
            pad_h: 1,
            pad_w: 1,
            depthwise: true,
        };
        let (h, w) = (4, 4);
        let mut x = TensorF32::zeros(&[g.cin, h, w]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut wt = TensorF32::zeros(&[g.cout, 1, g.kh, g.kw]);
        rng.fill_normal(wt.data_mut(), 0.3);
        let b = vec![0.0; g.cout];
        let (oh, ow) = g.out_hw(h, w);
        let e = TensorF32::full(&[g.cout, oh, ow], 1.0);
        let mut ops = OpCounter::new();
        let (gw, _) = fconv2d_bwd_weight(&e, &x, &g, None, &mut ops);
        let loss = |wt: &TensorF32| -> f32 {
            let mut o = OpCounter::new();
            fconv2d_fwd(&x, wt, &b, &g, false, &mut o).data().iter().sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 10, 26] {
            let mut wp = wt.clone();
            wp.data_mut()[idx] += eps;
            let mut wm = wt.clone();
            wm.data_mut()[idx] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps);
            assert!((num - gw.data()[idx]).abs() < 1e-2);
        }
    }

    /// The GEMM-routed float forward must equal the scalar reference
    /// exactly (same per-element accumulation order — see module docs).
    #[test]
    fn gemm_fwd_equals_scalar_reference() {
        let mut rng = Pcg32::seeded(34);
        let mut scratch = crate::memplan::Scratch::new();
        for &(cin, cout, k, stride, pad, h) in &[
            (2usize, 3usize, 3usize, 1usize, 1usize, 6usize),
            (3, 4, 3, 2, 1, 9),
            (4, 8, 1, 1, 0, 5), // pointwise shortcut
            (1, 2, 3, 1, 0, 7),
        ] {
            let g = ConvGeom {
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                pad_h: pad,
                pad_w: pad,
                depthwise: false,
            };
            let mut x = TensorF32::zeros(&[cin, h, h]);
            rng.fill_normal(x.data_mut(), 1.0);
            let mut wt = TensorF32::zeros(&[cout, cin, k, k]);
            rng.fill_normal(wt.data_mut(), 0.3);
            let b: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
            let mut ops = OpCounter::new();
            let ys = fconv2d_fwd(&x, &wt, &b, &g, true, &mut ops);
            let yg = fconv2d_fwd_gemm(&x, &wt, &b, &g, true, &mut scratch, &mut ops);
            assert_eq!(ys.data(), yg.data(), "geom {cin}->{cout} k{k} s{stride}");
        }
    }

    /// The GEMM-routed float backward kernels must equal the scalar
    /// references exactly (same per-element accumulation order — see the
    /// kernel docs), across geometries and sparse masks.
    #[test]
    fn gemm_bwd_equals_scalar_reference() {
        let mut rng = Pcg32::seeded(35);
        let mut scratch = crate::memplan::Scratch::new();
        for &(cin, cout, k, stride, pad, h) in &[
            (2usize, 3usize, 3usize, 1usize, 1usize, 6usize),
            (3, 4, 3, 2, 1, 9),
            (4, 8, 1, 1, 0, 5), // pointwise shortcut
            (1, 2, 3, 1, 0, 7),
            (2, 5, 3, 2, 0, 8),
        ] {
            let g = ConvGeom {
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                pad_h: pad,
                pad_w: pad,
                depthwise: false,
            };
            let (oh, ow) = g.out_hw(h, h);
            let mut x = TensorF32::zeros(&[cin, h, h]);
            rng.fill_normal(x.data_mut(), 1.0);
            let mut wt = TensorF32::zeros(&[cout, cin, k, k]);
            rng.fill_normal(wt.data_mut(), 0.3);
            let mut e = TensorF32::zeros(&[cout, oh, ow]);
            rng.fill_normal(e.data_mut(), 1.0);
            let mask: Vec<bool> = (0..cout).map(|i| i % 2 == 0).collect();
            for keep in [None, Some(&mask[..])] {
                let mut ops_s = OpCounter::new();
                let mut ops_g = OpCounter::new();
                let (gws, gbs) = fconv2d_bwd_weight(&e, &x, &g, keep, &mut ops_s);
                let (gwg, gbg) =
                    fconv2d_bwd_weight_gemm(&e, &x, &g, keep, &mut scratch, &mut ops_g);
                assert_eq!(gws.data(), gwg.data(), "gw {cin}->{cout} k{k} s{stride}");
                assert_eq!(gbs.data(), gbg.data(), "gb {cin}->{cout} k{k} s{stride}");
                assert_eq!(ops_s, ops_g, "bwd_weight ops {cin}->{cout} k{k} s{stride}");

                let mut ops_s2 = OpCounter::new();
                let mut ops_g2 = OpCounter::new();
                let es = fconv2d_bwd_input(&e, &wt, &g, h, h, keep, &mut ops_s2);
                let eg = fconv2d_bwd_input_gemm(&e, &wt, &g, h, h, keep, &mut scratch, &mut ops_g2);
                assert_eq!(es.data(), eg.data(), "dx {cin}->{cout} k{k} s{stride}");
                assert_eq!(ops_s2, ops_g2, "bwd_input ops {cin}->{cout} k{k} s{stride}");
            }
        }
    }

    #[test]
    fn relu_mask_f_zeroes() {
        let y = TensorF32::from_vec(&[4], vec![0.0, 1.0, -2.0, 3.0]);
        let mut e = TensorF32::from_vec(&[4], vec![5.0, 5.0, 5.0, 5.0]);
        let mut ops = OpCounter::new();
        relu_bwd_mask_f(&mut e, &y, &mut ops);
        assert_eq!(e.data(), &[0.0, 5.0, 0.0, 5.0]);
    }
}
